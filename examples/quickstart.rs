//! Quickstart: run DSD-Sim on a small edge–cloud deployment and print the
//! analyzer report — the 30-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use dsd::config::{RoutingKind, SimConfig, WindowKind};
use dsd::sim::Simulator;

fn main() {
    // 4 cloud targets (Llama2-70B on 4xA100), 120 edge drafters
    // (Llama2-7B on A40), 10 ms RTT, GSM8K-profile workload.
    let cfg = SimConfig::builder()
        .seed(42)
        .targets(4)
        .drafters(120)
        .rtt_ms(10.0)
        .dataset("gsm8k")
        .requests(300)
        .rate_per_s(25.0)
        .routing(RoutingKind::Jsq)
        .window(WindowKind::Static(4))
        .build();

    let report = Simulator::new(cfg).run();
    println!("{}", report.summary());
    println!(
        "steady throughput {:.1} req/s | p99 TTFT {:.0} ms | p99 TPOT {:.1} ms | mean gamma {:.2}",
        report.system.throughput_rps,
        report.p_ttft(99.0),
        report.p_tpot(99.0),
        report.mean_gamma(),
    );

    // Swap one policy and re-run: the whole point of the policy families.
    let cfg_awc = SimConfig::builder()
        .seed(42)
        .targets(4)
        .drafters(120)
        .rtt_ms(10.0)
        .dataset("gsm8k")
        .requests(300)
        .rate_per_s(25.0)
        .routing(RoutingKind::Jsq)
        .window(WindowKind::Awc { weights_path: None })
        .build();
    let awc = Simulator::new(cfg_awc).run();
    println!("with AWC: {}", awc.summary());
}
