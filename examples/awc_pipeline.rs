//! The full AWC lifecycle (paper §4) in one binary:
//!   1. exhaustive (γ, mode) sweeps on a small grid -> labeled dataset;
//!   2. (training runs in python: `make train-awc`);
//!   3. evaluate the shipped pretrained controller against the Static
//!      and Dynamic baselines on a held-out configuration.
//!
//!     cargo run --release --example awc_pipeline

use dsd::awc::{generate_dataset, SweepGrid};
use dsd::config::{BatchingKind, RoutingKind, WindowKind};
use dsd::experiments::common::{mean_of, paper_config, run_seeds, Scale};

fn main() {
    // 1. Sweep a reduced grid (the full grid is `dsd sweep-dataset`).
    let grid = SweepGrid::tiny();
    let rows = generate_dataset(&grid);
    println!(
        "sweep: {} scenarios x {} probes -> {} labeled rows",
        grid.n_scenarios(),
        grid.gammas.len() + 1,
        rows.len()
    );
    let path = std::path::Path::new("data/awc_sweep_demo.jsonl");
    std::fs::create_dir_all("data").ok();
    dsd::awc::dataset::write_jsonl(&rows, path).expect("write dataset");
    println!("wrote {} (train with `make train-awc`)", path.display());

    // 3. Evaluate the shipped controller.
    println!("\nAWC vs baselines (gsm8k, 20T/600D, 10 ms RTT):");
    println!("{:<10} {:>8} {:>8} {:>8}", "policy", "tput", "TTFT", "TPOT");
    for (name, w) in [
        ("static", WindowKind::Static(4)),
        ("dynamic", WindowKind::Dynamic { init: 4, lo: 0.25, hi: 0.75 }),
        ("awc", WindowKind::Awc { weights_path: None }),
    ] {
        let cfg = paper_config(
            "gsm8k", 600, 10.0, RoutingKind::Jsq, BatchingKind::Lab, w, Scale(0.5), 1,
        );
        let reps = run_seeds(&cfg, &[1, 2]);
        println!(
            "{name:<10} {:>8.1} {:>8.0} {:>8.1}",
            mean_of(&reps, |r| r.system.throughput_rps),
            mean_of(&reps, |r| r.mean_ttft()),
            mean_of(&reps, |r| r.mean_tpot()),
        );
    }
}
