//! The Figure-5 experiment: accumulate the paper's policy stack
//! (routing -> batching -> window control) and watch throughput/latency
//! improve, per dataset.
//!
//!     cargo run --release --example policy_sweep

use dsd::experiments::{fig5, Scale};

fn main() {
    for dataset in ["gsm8k", "cnndm", "humaneval"] {
        println!("== {dataset} ==");
        println!("{:<10} {:>10} {:>9} {:>9}", "stack", "tput", "TTFT", "TPOT");
        for (name, tput, ttft, tpot) in fig5::sweep(dataset, Scale(0.5), &[1, 2]) {
            println!("{name:<10} {tput:>10.1} {ttft:>9.0} {tpot:>9.1}");
        }
        println!();
    }
}
