//! END-TO-END DRIVER: real edge–cloud speculative decoding over the AOT
//! artifacts — the serving-paper validation required by DESIGN.md §9.
//!
//! Loads the distilled draft (2L/128d) and target (4L/256d) byte-level
//! GPTs through PJRT, spins edge drafter threads and cloud verifier
//! threads joined by delay-injected channels, and drives a batch of
//! GSM8K-style prompts through genuine draft->ship->verify->correct
//! rounds. Reports latency, acceptance, throughput, and the speedup vs
//! cloud-only (fused) decoding, plus the output-invariance check that
//! greedy SD must produce the target's own greedy text.
//!
//!     make artifacts && cargo run --release --example edge_cloud_serving

use dsd::coordinator::{Coordinator, ServeConfig, ServeRequest, ServeWindow};
use std::path::Path;

fn prompts(n: usize, toks: usize) -> Vec<ServeRequest> {
    (0..n)
        .map(|i| {
            let a = 3 + (i * 11) % 50;
            let b = 2 + (i * 3) % 30;
            ServeRequest {
                id: i,
                prompt: format!(
                    "question: tom has {a} apples and buys {b} more. \
                     how many apples does tom have?\nanswer:"
                )
                .into_bytes(),
                max_new_tokens: toks,
            }
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let n_requests = 8;
    let max_tokens = 32;

    // --- Distributed speculative decoding (the paper's system) ---
    let sd_cfg = ServeConfig {
        n_drafters: 2,
        n_verifiers: 1,
        rtt_ms: 10.0,
        window: ServeWindow::Static(4),
        max_new_tokens: max_tokens,
    };
    let co = Coordinator::new(dir, sd_cfg)?;
    let (sd_responses, sd) = co.serve(prompts(n_requests, max_tokens))?;
    println!("--- distributed speculative decoding (gamma=4, RTT 10 ms) ---");
    for r in sd_responses.iter().take(2) {
        println!(
            "  req {}: {:?} (acc {:.2}, {} rounds)",
            r.id,
            String::from_utf8_lossy(&r.output),
            r.acceptance(),
            r.rounds
        );
    }
    println!(
        "  completed {} | {:.2} req/s | {:.1} tok/s | TTFT {:.0} ms | TPOT {:.0} ms | acceptance {:.2}",
        sd.completed, sd.throughput_rps, sd.token_throughput,
        sd.mean_ttft_ms, sd.mean_tpot_ms, sd.mean_acceptance
    );

    // --- Cloud-only (fused) baseline ---
    let fused_cfg = ServeConfig {
        n_drafters: 2,
        n_verifiers: 1,
        rtt_ms: 10.0,
        window: ServeWindow::FusedOnly,
        max_new_tokens: max_tokens,
    };
    let co_fused = Coordinator::new(dir, fused_cfg)?;
    let (fused_responses, fused) = co_fused.serve(prompts(n_requests, max_tokens))?;
    println!("--- cloud-only (fused) baseline ---");
    println!(
        "  completed {} | {:.2} req/s | {:.1} tok/s | TTFT {:.0} ms | TPOT {:.0} ms",
        fused.completed, fused.throughput_rps, fused.token_throughput,
        fused.mean_ttft_ms, fused.mean_tpot_ms
    );

    // --- Invariance + speedup ---
    let mut mismatches = 0;
    for (a, b) in sd_responses.iter().zip(&fused_responses) {
        if a.output != b.output {
            mismatches += 1;
        }
    }
    println!("--- summary ---");
    println!(
        "  output invariance: {}/{} identical to target greedy decode",
        n_requests - mismatches,
        n_requests
    );
    println!(
        "  speculative speedup: {:.2}x tokens/s ({:.1} vs {:.1})",
        sd.token_throughput / fused.token_throughput,
        sd.token_throughput,
        fused.token_throughput
    );
    assert_eq!(mismatches, 0, "greedy SD must reproduce the target's output");
    Ok(())
}
