//! Fleet-scale scenario sweep: expand an RTT × rate × window grid over a
//! heterogeneous edge fleet (one drafter pool on a fast fiber link, one
//! behind a slow cellular link) and run every cell in parallel with
//! streaming metrics.
//!
//!     cargo run --release --example fleet_sweep
//!
//! The same grid runs from the CLI via:
//!
//!     dsd sweep --grid examples/sweep_grid.yaml --table

use dsd::sweep::{default_threads, run_grid, SweepGrid, SweepSummary};

const GRID: &str = "\
base:
  workload:
    requests: 400
    rate_per_s: 30
  cluster:
    targets:
      - count: 4
        gpu: a100
        tp: 4
        model: llama2-70b
    drafters:
      - count: 40            # fiber-attached edge racks
        gpu: a40
        model: llama2-7b
      - count: 40            # cellular devices: slow, jittery, narrow
        gpu: v100
        model: qwen-7b
        rtt_ms: 90
        jitter_ms: 8
        bandwidth_mbps: 10
sweep:
  rtt_ms: [5, 20, 60]        # fiber-pool RTT (the override pins the rest)
  rate_per_s: [20, 40]
  window: [static, fused]
  seeds: [1]
streaming: true
";

fn main() {
    let grid = SweepGrid::from_yaml(GRID).expect("grid parses");
    let threads = default_threads();
    eprintln!("expanding {} cells on {} threads ...", grid.n_cells(), threads);
    let cells = run_grid(&grid, threads).expect("grid expands");
    let summary = SweepSummary::new(cells, grid.streaming);
    println!("{}", summary.render_table());
    // The JSON form is byte-stable across runs and thread counts.
    println!("{}", summary.to_json().to_string_pretty());
}
