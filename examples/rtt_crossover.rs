//! The Figure-6 experiment as a library call: sweep the edge–cloud RTT
//! and print where distributed speculative decoding stops paying off.
//!
//!     cargo run --release --example rtt_crossover

use dsd::experiments::{fig6, Scale};

fn main() {
    let (dist, fused) = fig6::sweep(Scale(0.5), &[1, 2]);
    println!("RTT ms   distributed TPOT   fused TPOT");
    for (d, f) in dist.iter().zip(&fused) {
        println!("{:>6.0}   {:>16.1}   {:>10.1}", d.0, d.3, f.3);
    }
    match fig6::crossover_rtt(&dist, &fused) {
        Some(x) => println!("\ncrossover at ~{x:.0} ms (paper: 50-60 ms)"),
        None => println!("\nno crossover inside the sweep"),
    }
}
