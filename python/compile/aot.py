"""AOT lowering: JAX -> HLO **text** artifacts consumed by the rust runtime.

Python's entire job ends here (build time). The pipeline:

  1. Train (or load cached) draft/target tiny-GPT weights.
  2. Lower each serving entry point — prefill / decode_step / verify(γ) —
     with the weights **baked in as constants** (closure capture), so the
     rust side passes only tokens/positions/KV caches.
  3. Lower the WC-DNN forward from the pretrained JSON weights.
  4. Write ``artifacts/manifest.json`` describing every artifact's
     operands and result shapes.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import wcdnn
from .train_lm import flatten_params, train_pair, unflatten_params

# Window sizes with a pre-lowered verify artifact. The coordinator clamps
# AWC decisions to the nearest available γ on the real path.
VERIFY_GAMMAS = [1, 2, 3, 4, 6, 8]

# Fixed padded prompt length for the prefill artifacts.
PROMPT_PAD = 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path).

    Two print options matter:
      * ``print_large_constants=True`` — the default printer elides big
        constants as ``{...}``, which silently zeroes the baked-in model
        weights when the text is re-parsed;
      * ``print_metadata=False`` — jax >= 0.7 emits ``source_end_line``
        metadata attributes the 0.5.1 HLO parser rejects.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_model_artifacts(params, cfg: M.GptConfig, tag: str, out_dir: str,
                          manifest: dict, with_verify: bool = True):
    """Lower prefill / decode / (optionally) verify(γ) for one model.

    Draft models never verify, so their γ-windows are skipped to keep the
    artifact set small (each target verify artifact carries the full
    weight constants, ~50 MB of HLO text)."""
    kv_shape = (cfg.n_layer, 2, cfg.n_head, cfg.max_len, cfg.head_dim)
    kv_spec = jax.ShapeDtypeStruct(kv_shape, jnp.float32)
    i32 = jnp.int32

    # --- prefill(tokens[PROMPT_PAD], length) ---
    def prefill_fn(tokens, length):
        logits, kv = M.prefill(params, cfg, tokens, length)
        return (logits, kv)

    lowered = jax.jit(prefill_fn).lower(
        jax.ShapeDtypeStruct((PROMPT_PAD,), i32),
        jax.ShapeDtypeStruct((), i32),
    )
    path = f"{tag}_prefill.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"][f"{tag}_prefill"] = {
        "path": path,
        "operands": [
            {"name": "tokens", "shape": [PROMPT_PAD], "dtype": "s32"},
            {"name": "length", "shape": [], "dtype": "s32"},
        ],
        "results": [
            {"name": "logits", "shape": [M.VOCAB], "dtype": "f32"},
            {"name": "kv", "shape": list(kv_shape), "dtype": "f32"},
        ],
    }

    # --- decode_step(token, pos, kv) ---
    def decode_fn(token, pos, kv):
        logits, kv = M.decode_step(params, cfg, token, pos, kv)
        return (logits, kv)

    lowered = jax.jit(decode_fn).lower(
        jax.ShapeDtypeStruct((), i32),
        jax.ShapeDtypeStruct((), i32),
        kv_spec,
    )
    path = f"{tag}_decode.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"][f"{tag}_decode"] = {
        "path": path,
        "operands": [
            {"name": "token", "shape": [], "dtype": "s32"},
            {"name": "pos", "shape": [], "dtype": "s32"},
            {"name": "kv", "shape": list(kv_shape), "dtype": "f32"},
        ],
        "results": [
            {"name": "logits", "shape": [M.VOCAB], "dtype": "f32"},
            {"name": "kv", "shape": list(kv_shape), "dtype": "f32"},
        ],
    }

    # --- verify_g{γ}(tokens[γ+1], pos, kv) ---
    for g in VERIFY_GAMMAS if with_verify else []:
        g1 = g + 1

        def verify_fn(tokens, pos, kv):
            logits, kv = M.verify(params, cfg, tokens, pos, kv)
            return (logits, kv)

        lowered = jax.jit(verify_fn).lower(
            jax.ShapeDtypeStruct((g1,), i32),
            jax.ShapeDtypeStruct((), i32),
            kv_spec,
        )
        path = f"{tag}_verify_g{g}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"][f"{tag}_verify_g{g}"] = {
            "path": path,
            "operands": [
                {"name": "tokens", "shape": [g1], "dtype": "s32"},
                {"name": "pos", "shape": [], "dtype": "s32"},
                {"name": "kv", "shape": list(kv_shape), "dtype": "f32"},
            ],
            "results": [
                {"name": "logits", "shape": [g1, M.VOCAB], "dtype": "f32"},
                {"name": "kv", "shape": list(kv_shape), "dtype": "f32"},
            ],
        }


def lower_wcdnn(weights_json: str, out_dir: str, manifest: dict):
    """Lower the WC-DNN forward (weights baked in) to wcdnn.hlo.txt."""
    params, feat_mean, feat_std = wcdnn.from_json_file(weights_json)

    def fwd(x):
        return (wcdnn.apply(params, x, feat_mean, feat_std, use_kernel=True),)

    lowered = jax.jit(fwd).lower(jax.ShapeDtypeStruct((5,), jnp.float32))
    path = "wcdnn.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["wcdnn"] = {
        "path": path,
        "operands": [{"name": "features", "shape": [5], "dtype": "f32"}],
        "results": [{"name": "gamma", "shape": [], "dtype": "f32"}],
    }


def get_or_train_weights(out_dir: str, quick: bool):
    """Load cached LM weights or train the pair."""
    cache = os.path.join(out_dir, "lm_weights.npz")
    if os.path.exists(cache):
        flat = dict(np.load(cache))
        draft = unflatten_params(flat, M.DRAFT_CONFIG, "draft_")
        target = unflatten_params(flat, M.TARGET_CONFIG, "target_")
        print(f"[aot] loaded cached LM weights from {cache}")
        return draft, target
    # The drafter needs more steps than the target to become a useful
    # speculator (its 2-layer capacity converges slowly; acceptance rate
    # on the serving path tracks its loss closely).
    draft, target, meta = train_pair(
        draft_steps=100 if quick else 900,
        target_steps=60 if quick else 240,
    )
    flat = {}
    flat.update(flatten_params(draft, "draft_"))
    flat.update(flatten_params(target, "target_"))
    np.savez(cache, **{k: np.asarray(v) for k, v in flat.items()})
    print(f"[aot] trained LM pair: {meta}")
    return draft, target


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--wcdnn-weights", default="pretrained/wcdnn_weights.json")
    ap.add_argument("--quick", action="store_true",
                    help="fewer training steps (CI smoke)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {
        "vocab": M.VOCAB,
        "prompt_pad": PROMPT_PAD,
        "verify_gammas": VERIFY_GAMMAS,
        "draft": {
            "n_layer": M.DRAFT_CONFIG.n_layer,
            "n_head": M.DRAFT_CONFIG.n_head,
            "d_model": M.DRAFT_CONFIG.d_model,
            "max_len": M.DRAFT_CONFIG.max_len,
        },
        "target": {
            "n_layer": M.TARGET_CONFIG.n_layer,
            "n_head": M.TARGET_CONFIG.n_head,
            "d_model": M.TARGET_CONFIG.d_model,
            "max_len": M.TARGET_CONFIG.max_len,
        },
        "artifacts": {},
    }

    draft, target = get_or_train_weights(args.out, args.quick)
    print("[aot] lowering draft model ...", flush=True)
    lower_model_artifacts(draft, M.DRAFT_CONFIG, "draft", args.out, manifest,
                          with_verify=False)
    print("[aot] lowering target model ...", flush=True)
    lower_model_artifacts(target, M.TARGET_CONFIG, "target", args.out, manifest)
    print("[aot] lowering wcdnn ...", flush=True)
    lower_wcdnn(args.wcdnn_weights, args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    n = len(manifest["artifacts"])
    print(f"[aot] wrote {n} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
