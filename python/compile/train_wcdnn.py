"""Train the WC-DNN on sweep data from DSD-Sim (paper §4.2-4.3).

Reads the JSONL produced by ``dsd sweep-dataset`` (rows of
``{features: [5], label_gamma, ...}``), normalizes features, and trains
the residual MLP with **L1 loss / AdamW / 100 epochs** exactly as the
paper specifies. Writes the rust-compatible weight JSON.

Usage:
    python -m compile.train_wcdnn --data ../data/awc_sweep.jsonl \
        --out ../python/pretrained/wcdnn_weights.json
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from . import wcdnn


def load_dataset(path: str):
    feats, labels = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            feats.append(row["features"])
            labels.append(row["label_gamma"])
    x = np.asarray(feats, np.float32)
    y = np.asarray(labels, np.float32)
    return x, y


def adamw_step(params, grads, state, lr, wd=1e-4, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    ms = 1.0 / (1 - b1**t)
    vs = 1.0 / (1 - b2**t)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * ((m_ * ms) / (jnp.sqrt(v_ * vs) + eps) + wd * p),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def train(x, y, epochs: int = 100, batch: int = 256, lr: float = 1e-3, seed: int = 0,
          verbose: bool = True):
    """Train; returns (params, feat_mean, feat_std, final_val_mae)."""
    rng = np.random.default_rng(seed)
    n = len(x)
    n_val = max(1, n // 10)
    perm = rng.permutation(n)
    val_idx, tr_idx = perm[:n_val], perm[n_val:]
    xt, yt = x[tr_idx], y[tr_idx]
    xv, yv = x[val_idx], y[val_idx]

    feat_mean = jnp.asarray(xt.mean(axis=0))
    feat_std = jnp.asarray(xt.std(axis=0) + 1e-6)

    params = wcdnn.init_params(jax.random.PRNGKey(seed))
    opt = {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "t": 0,
    }

    batched_apply = jax.vmap(
        lambda p, xi: wcdnn.apply(p, xi, feat_mean, feat_std, use_kernel=False),
        in_axes=(None, 0),
    )

    @jax.jit
    def step(params, opt, bx, by):
        def l1(p):
            pred = batched_apply(p, bx)
            return jnp.mean(jnp.abs(pred - by))

        loss, grads = jax.value_and_grad(l1)(params)
        params, opt = adamw_step(params, grads, opt, lr)
        return params, opt, loss

    @jax.jit
    def val_mae(params):
        return jnp.mean(jnp.abs(batched_apply(params, jnp.asarray(xv)) - jnp.asarray(yv)))

    for epoch in range(epochs):
        order = rng.permutation(len(xt))
        for s in range(0, len(xt), batch):
            idx = order[s : s + batch]
            params, opt, _ = step(params, opt, jnp.asarray(xt[idx]), jnp.asarray(yt[idx]))
        if verbose and (epoch % 10 == 0 or epoch == epochs - 1):
            print(f"[train_wcdnn] epoch {epoch:3d} val-MAE {float(val_mae(params)):.3f}",
                  flush=True)
    return params, feat_mean, feat_std, float(val_mae(params))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--epochs", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, y = load_dataset(args.data)
    print(f"[train_wcdnn] {len(x)} rows, label range [{y.min():.0f}, {y.max():.0f}]")
    params, feat_mean, feat_std, mae = train(x, y, epochs=args.epochs, seed=args.seed)
    out = wcdnn.to_json_dict(params, feat_mean, feat_std)
    with open(args.out, "w") as f:
        json.dump(out, f)
    print(f"[train_wcdnn] wrote {args.out} (val MAE {mae:.3f})")


if __name__ == "__main__":
    main()
