"""Tiny built-in training corpus for the stand-in draft/target LM pair.

The paper's workloads (GSM8K / CNN-DailyMail / HumanEval) are not
available offline, so the real-serving demo trains both models on a small
synthetic corpus mixing the three task *shapes*: arithmetic word-problem
reasoning, news-style summaries, and python function bodies. What matters
for the reproduction is not linguistic quality but that (a) the models
share a distribution so the draft attains a non-trivial acceptance rate,
and (b) prompts look like the three benchmark families.
"""

from __future__ import annotations

_MATH = """\
question: tom has {a} apples and buys {b} more. how many apples does tom have?
answer: tom starts with {a} apples. he buys {b} more. {a} + {b} = {c}. the answer is {c}.
question: a train travels {a} miles each hour for {b} hours. how far does it go?
answer: the train covers {a} miles per hour. over {b} hours it travels {a} * {b} = {d}. the answer is {d}.
"""

_NEWS = """\
article: the city council voted on tuesday to approve the new transit plan. officials said the project will add {a} miles of track and create {b} jobs over the next decade.
summary: council approves transit plan adding {a} miles of track and {b} jobs.
article: researchers announced a study of {a} patients showing improved outcomes. the trial ran for {b} months across several hospitals.
summary: study of {a} patients over {b} months shows improved outcomes.
"""

_CODE = """\
def add(a, b):
    return a + b

def scale(xs, k):
    out = []
    for x in xs:
        out.append(x * k)
    return out

def count_words(text):
    words = text.split()
    total = len(words)
    return total

def fib(n):
    if n < 2:
        return n
    return fib(n - 1) + fib(n - 2)
"""


def build_corpus() -> bytes:
    """Deterministic ~64 KiB byte corpus."""
    parts = []
    for i in range(40):
        a, b = 3 + (i * 7) % 50, 2 + (i * 5) % 30
        parts.append(_MATH.format(a=a, b=b, c=a + b, d=a * b))
        parts.append(_NEWS.format(a=a, b=b))
        parts.append(_CODE)
    text = "\n".join(parts)
    return text.encode("utf-8")


def sample_prompts(kind: str, n: int):
    """Prompts shaped like the three benchmark families (byte strings)."""
    prompts = []
    for i in range(n):
        a, b = 3 + (i * 11) % 50, 2 + (i * 3) % 30
        if kind == "gsm8k":
            p = f"question: tom has {a} apples and buys {b} more. how many apples does tom have?\nanswer:"
        elif kind == "cnndm":
            p = (
                f"article: the city council voted on tuesday to approve the new transit plan. "
                f"officials said the project will add {a} miles of track and create {b} jobs over the next decade.\nsummary:"
            )
        else:  # humaneval
            p = "def add(a, b):\n"
        prompts.append(p.encode("utf-8"))
    return prompts
