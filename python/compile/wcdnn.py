"""L2: the WC-DNN window-control network in JAX (paper §4.3).

A residual MLP — 5 features -> hidden(64) -> 2 residual blocks (SiLU) ->
scalar γ. The forward pass routes each block through the L1 fused
``residual_mlp_block`` Pallas kernel so the shipped ``wcdnn.hlo.txt``
artifact contains the kernel; weights are exchanged with the rust
coordinator through the JSON schema of ``rust/src/awc/mlp.rs`` (bit-exact
layout match asserted in tests).
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.mlp import residual_mlp_block

INPUT_DIM = 5
HIDDEN = 64
BLOCKS = 2


def init_params(rng, hidden: int = HIDDEN, blocks: int = BLOCKS):
    """Initialize WC-DNN parameters (matches the rust JSON schema)."""
    keys = jax.random.split(rng, 2 + 2 * blocks)
    k = iter(keys)

    def mat(key, r, c):
        return jax.random.normal(key, (r, c)) / np.sqrt(c)

    params = {
        "in_w": mat(next(k), hidden, INPUT_DIM),
        "in_b": jnp.zeros((hidden,)),
        "blocks": [
            {
                "w1": mat(next(k), hidden, hidden),
                "b1": jnp.zeros((hidden,)),
                "w2": mat(next(k), hidden, hidden) * 0.1,
                "b2": jnp.zeros((hidden,)),
            }
            for _ in range(blocks)
        ],
        "out_w": mat(next(k), 1, hidden) * 0.1,
        "out_b": jnp.full((1,), 4.0),  # bias toward a sane default window
    }
    return params


def silu(x):
    return x * jax.nn.sigmoid(x)


def apply(params, x, feat_mean, feat_std, use_kernel: bool = True):
    """Forward pass: raw features (5,) -> raw γ prediction ().

    ``use_kernel=True`` routes residual blocks through the Pallas kernel
    (the lowering path); ``False`` uses plain jnp (training path — the
    interpret-mode kernel is slow under autodiff).
    """
    z = (x - feat_mean) / jnp.where(jnp.abs(feat_std) < 1e-9, 1.0, feat_std)
    h = silu(z @ params["in_w"].T + params["in_b"])[None, :]  # (1, H)
    for blk in params["blocks"]:
        if use_kernel:
            h = residual_mlp_block(
                h, blk["w1"], blk["b1"][None, :], blk["w2"], blk["b2"][None, :]
            )
        else:
            t = silu(h @ blk["w1"].T + blk["b1"])
            h = h + t @ blk["w2"].T + blk["b2"]
    y = h @ params["out_w"].T + params["out_b"]
    return y[0, 0]


def to_json_dict(params, feat_mean, feat_std):
    """Serialize to the rust `AwcWeights` JSON schema."""
    def mat(a):
        return np.asarray(a, dtype=np.float64).tolist()

    return {
        "arch": {"in": INPUT_DIM, "hidden": params["in_w"].shape[0],
                 "blocks": len(params["blocks"])},
        "in_w": mat(params["in_w"]),
        "in_b": mat(params["in_b"]),
        "blocks": [
            {"w1": mat(b["w1"]), "b1": mat(b["b1"]),
             "w2": mat(b["w2"]), "b2": mat(b["b2"])}
            for b in params["blocks"]
        ],
        "out_w": mat(params["out_w"]),
        "out_b": mat(params["out_b"]),
        "feat_mean": mat(feat_mean),
        "feat_std": mat(feat_std),
    }


def from_json_file(path: str):
    """Load (params, feat_mean, feat_std) from the JSON schema."""
    with open(path) as f:
        d = json.load(f)
    params = {
        "in_w": jnp.asarray(d["in_w"], jnp.float32),
        "in_b": jnp.asarray(d["in_b"], jnp.float32),
        "blocks": [
            {
                "w1": jnp.asarray(b["w1"], jnp.float32),
                "b1": jnp.asarray(b["b1"], jnp.float32),
                "w2": jnp.asarray(b["w2"], jnp.float32),
                "b2": jnp.asarray(b["b2"], jnp.float32),
            }
            for b in d["blocks"]
        ],
        "out_w": jnp.asarray(d["out_w"], jnp.float32),
        "out_b": jnp.asarray(d["out_b"], jnp.float32),
    }
    return (
        params,
        jnp.asarray(d["feat_mean"], jnp.float32),
        jnp.asarray(d["feat_std"], jnp.float32),
    )
