"""Build-time training of the draft/target tiny-GPT pair.

Both models train on the same byte corpus (``corpus.py``) with a plain
Adam loop; the shared distribution is what gives the drafter a useful
acceptance rate against the target at serving time. Weights are cached as
``artifacts/lm_weights.npz`` so ``make artifacts`` is idempotent.

This runs ONCE at build time — never on the request path.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .corpus import build_corpus

SEQ_LEN = 128
BATCH = 16


def _batches(data: np.ndarray, rng: np.random.Generator, steps: int):
    n = len(data) - SEQ_LEN - 1
    for _ in range(steps):
        idx = rng.integers(0, n, size=BATCH)
        yield np.stack([data[i : i + SEQ_LEN + 1] for i in idx])


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr=3e-4, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def train_model(cfg: M.GptConfig, steps: int, seed: int, data: np.ndarray, tag: str):
    """Train one GPT; returns (params, loss_history)."""
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)
        params, opt = adam_step(params, grads, opt)
        return params, opt, loss

    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.time()
    for i, batch in enumerate(_batches(data, rng, steps)):
        params, opt, loss = step(params, opt, jnp.asarray(batch))
        if i % 25 == 0 or i == steps - 1:
            losses.append(float(loss))
            print(f"[train_lm:{tag}] step {i:4d} loss {float(loss):.3f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    return params, losses


def flatten_params(params, prefix=""):
    """Flatten the param pytree to {name: array} for npz storage."""
    flat = {}
    flat[f"{prefix}wte"] = params["wte"]
    flat[f"{prefix}wpe"] = params["wpe"]
    flat[f"{prefix}ln_f_g"] = params["ln_f_g"]
    flat[f"{prefix}ln_f_b"] = params["ln_f_b"]
    for i, lp in enumerate(params["layers"]):
        for k, v in lp.items():
            flat[f"{prefix}l{i}_{k}"] = v
    return flat


def unflatten_params(flat, cfg: M.GptConfig, prefix=""):
    """Inverse of ``flatten_params``."""
    params = {
        "wte": jnp.asarray(flat[f"{prefix}wte"]),
        "wpe": jnp.asarray(flat[f"{prefix}wpe"]),
        "ln_f_g": jnp.asarray(flat[f"{prefix}ln_f_g"]),
        "ln_f_b": jnp.asarray(flat[f"{prefix}ln_f_b"]),
        "layers": [],
    }
    for i in range(cfg.n_layer):
        keys = [
            "ln1_g", "ln1_b", "qkv_w", "qkv_b", "proj_w", "proj_b",
            "ln2_g", "ln2_b", "fc_w", "fc_b", "fc2_w", "fc2_b",
        ]
        params["layers"].append(
            {k: jnp.asarray(flat[f"{prefix}l{i}_{k}"]) for k in keys}
        )
    return params


def train_pair(draft_steps: int = 900, target_steps: int = 240, seed: int = 0):
    """Train both models; returns (draft_params, target_params, meta)."""
    data = np.frombuffer(build_corpus(), dtype=np.uint8).astype(np.int32)
    target_params, target_losses = train_model(
        M.TARGET_CONFIG, target_steps, seed + 1, data, "target"
    )
    draft_params, draft_losses = train_model(
        M.DRAFT_CONFIG, draft_steps, seed + 2, data, "draft"
    )
    meta = {
        "draft_final_loss": draft_losses[-1],
        "target_final_loss": target_losses[-1],
    }
    return draft_params, target_params, meta
