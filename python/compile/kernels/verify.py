"""L1 Pallas kernel: parallel speculative verification.

Given the target model's logits for the `G+1` positions of a speculation
window and the drafter's `G` proposed tokens, compute (greedy acceptance,
paper Fig. 1(c)):

  * ``argmax_tokens[i]`` — the target's own choice at each position,
  * ``accept_mask[i]``  — whether draft token i matches the target.

The rust coordinator folds the mask to the first mismatch and picks the
correction/bonus token from ``argmax_tokens``; the kernel does the
data-parallel heavy part (a blocked argmax over the vocabulary — a pure
VPU reduction on TPU, tiled so each (position, vocab-block) stripe sits in
VMEM).

Shapes:
    logits : (G1, V)  float32, G1 = G + 1 rows
    draft  : (G1,)    int32, draft tokens padded with -1 in row G
    -> argmax_tokens : (G1,) int32
    -> accept_mask   : (G1,) int32   (1 = match; row G always 0)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Vocabulary slice per grid step (lane-width multiple).
BLOCK_V = 128

NEG_INF = -1e30


def _verify_kernel(draft_ref, logits_ref, tok_ref, acc_ref, best_ref, arg_ref):
    """Grid: (G1, V // BLOCK_V): blocked argmax with VMEM scratch carry."""
    row = pl.program_id(0)
    vb = pl.program_id(1)

    x = logits_ref[...]  # (1, BLOCK_V)

    @pl.when(vb == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, NEG_INF)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    cur_best = best_ref[0, 0]
    cur_arg = arg_ref[0, 0]

    blk_best = jnp.max(x)
    blk_off = jnp.argmax(x[0]).astype(jnp.int32)
    blk_arg = vb * BLOCK_V + blk_off

    take = blk_best > cur_best
    best_ref[0, 0] = jnp.where(take, blk_best, cur_best)
    arg_ref[0, 0] = jnp.where(take, blk_arg, cur_arg)

    @pl.when(vb == pl.num_programs(1) - 1)
    def _emit():
        winner = arg_ref[0, 0]
        tok_ref[0] = winner
        acc_ref[0] = jnp.where(draft_ref[row] == winner, 1, 0).astype(jnp.int32)


def verify_tokens(draft, logits):
    """Blocked greedy-verification kernel (Pallas, interpret mode).

    Args:
        draft: (G1,) int32 draft tokens (row G padded with -1 — it can
            never match, so its mask is 0 and its argmax row supplies the
            bonus token).
        logits: (G1, V) float32 target logits; V a multiple of
            ``BLOCK_V``.
    Returns:
        (argmax_tokens, accept_mask): each (G1,) int32.
    """
    g1, v = logits.shape
    assert v % BLOCK_V == 0, f"vocab {v} must be a multiple of {BLOCK_V}"
    grid = (g1, v // BLOCK_V)
    return pl.pallas_call(
        _verify_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # draft tokens
            pl.BlockSpec((1, BLOCK_V), lambda i, j: (i, j)),       # logit tile
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),                 # argmax token
            pl.BlockSpec((1,), lambda i, j: (i,)),                 # accept bit
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g1,), jnp.int32),
            jax.ShapeDtypeStruct((g1,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),  # best logit so far
            pltpu.VMEM((1, 1), jnp.int32),    # its index
        ],
        interpret=True,
    )(draft, logits)
