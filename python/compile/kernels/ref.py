"""Pure-jnp oracles for every L1 kernel.

These are the correctness ground truth: pytest checks each Pallas kernel
against its oracle with ``assert_allclose`` over hypothesis-swept shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(length, q, k_cache, v_cache):
    """Reference single-query attention with length masking.

    Args/returns mirror ``attention.decode_attention``.
    """
    h, d = q.shape
    _, l, _ = k_cache.shape
    scores = jnp.einsum("hd,hld->hl", q, k_cache) / (d ** 0.5)
    pos = jnp.arange(l)[None, :]
    scores = jnp.where(pos < length[0], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hl,hld->hd", w, v_cache)


def verify_tokens_ref(draft, logits):
    """Reference greedy verification.

    Args/returns mirror ``verify.verify_tokens``.
    """
    arg = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    acc = (draft == arg).astype(jnp.int32)
    return arg, acc


def residual_mlp_block_ref(h, w1, b1, w2, b2):
    """Reference residual MLP block (mirrors ``mlp.residual_mlp_block``)."""
    z = h @ w1.T + b1
    z = z * jax.nn.sigmoid(z)
    return h + z @ w2.T + b2


def fold_acceptance(accept_mask, argmax_tokens, gamma):
    """Reduce kernel outputs to the paper's acceptance rule: number of
    accepted draft tokens (stop at first mismatch) and the target token
    emitted after them (correction on mismatch, bonus on all-accept).

    Args:
        accept_mask: (G+1,) int array (row G is always 0).
        argmax_tokens: (G+1,) int array.
        gamma: int window size G.
    Returns:
        (n_accepted, next_token) python ints.
    """
    n = 0
    for i in range(gamma):
        if int(accept_mask[i]) == 1:
            n += 1
        else:
            break
    return n, int(argmax_tokens[n])
