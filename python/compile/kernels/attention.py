"""L1 Pallas kernel: single-query flash-decode attention over a KV cache.

The hot-spot of speculative decoding's *drafting* loop: one new query
attends to every cached position. On TPU this is a bandwidth-bound
workload; the kernel expresses the HBM->VMEM schedule with a BlockSpec
grid over KV blocks and an online-softmax accumulator in VMEM scratch —
the TPU analogue of a CUDA flash-decode threadblock staging tiles through
shared memory (DESIGN.md §Hardware-Adaptation).

Shapes (single sequence; the rust coordinator batches at the scheduling
layer):
    length   : (1,) int32    number of valid cache positions (SMEM)
    q        : (H, D)        new token's query per head
    k_cache  : (H, L, D)     keys,   L = max sequence length
    v_cache  : (H, L, D)     values
    -> out   : (H, D)

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO (see /opt/xla-example).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# KV positions processed per grid step: one 128-lane VMEM stripe.
BLOCK_L = 128

NEG_INF = -1e30


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    """One (head, kv-block) grid step of online-softmax attention.

    Grid: (H, L // BLOCK_L); the kv-block axis is innermost and
    sequential, so the VMEM scratch (acc, m, l) carries the standard
    flash recurrence across blocks:
        m' = max(m, max(s));  l' = l*exp(m-m') + sum(exp(s-m'))
        acc' = acc*exp(m-m') + exp(s-m') @ V
    """
    kv_block = pl.program_id(1)
    length = len_ref[0]

    q = q_ref[...]    # (1, D)  — head-sliced
    k = k_ref[0]      # (BLOCK_L, D)
    v = v_ref[0]      # (BLOCK_L, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, BLOCK_L)
    d = q.shape[-1]
    s = s * (1.0 / (d ** 0.5))

    pos = kv_block * BLOCK_L + jax.lax.broadcasted_iota(jnp.int32, (1, BLOCK_L), 1)
    s = jnp.where(pos < length, s, NEG_INF)

    @pl.when(kv_block == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_prev = m_ref[...]       # (1, 1)
    l_prev = l_ref[...]       # (1, 1)
    acc_prev = acc_ref[...]   # (1, D)

    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    scale = jnp.exp(m_prev - m_new)
    l_new = l_prev * scale + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * scale + jnp.dot(p, v, preferred_element_type=jnp.float32)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(kv_block == pl.num_programs(1) - 1)
    def _emit():
        o_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def decode_attention(length, q, k_cache, v_cache):
    """Single-query flash-decode attention (Pallas, interpret mode).

    Args:
        length: (1,) int32 — valid cache positions (>= 1).
        q: (H, D) float32 query.
        k_cache: (H, L, D) float32 keys; L a multiple of ``BLOCK_L``.
        v_cache: (H, L, D) float32 values.
    Returns:
        (H, D) float32 attention output.
    """
    h, d = q.shape
    _, l, _ = k_cache.shape
    assert l % BLOCK_L == 0, f"cache length {l} must be a multiple of {BLOCK_L}"
    grid = (h, l // BLOCK_L)
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                    # length
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),                # q head row
            pl.BlockSpec((1, BLOCK_L, d), lambda i, j: (i, j, 0)),    # K tile
            pl.BlockSpec((1, BLOCK_L, d), lambda i, j: (i, j, 0)),    # V tile
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),  # acc
            pltpu.VMEM((1, 1), jnp.float32),  # running max
            pltpu.VMEM((1, 1), jnp.float32),  # running denom
        ],
        interpret=True,
    )(length, q, k_cache, v_cache)


def vmem_footprint_bytes(h: int, l: int, d: int) -> int:
    """Estimated per-step VMEM residency of the kernel (for §Perf):
    q tile + K tile + V tile + scratch, in float32 bytes."""
    per_head = d + 2 * BLOCK_L * d + d + 2
    return 4 * per_head  # one head in flight per grid step
