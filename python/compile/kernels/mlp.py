"""L1 Pallas kernel: fused residual-MLP block (the WC-DNN building block).

One block of the window-control network (paper §4.3):

    out = h + W2 @ silu(W1 @ h + b1) + b2

Fusing both GEMVs and the activation into a single kernel keeps the
intermediate in VMEM (no HBM round-trip between the two layers) — the
same fusion a CUDA implementation would do with a persistent threadblock.
The hidden width (64) is small enough that everything fits in one VMEM
block, so the grid is trivial; the value of the kernel is the fusion, not
the tiling.

Shapes:
    h  : (1, H)  float32
    w1 : (H, H)  float32 (row-major, y = x @ W.T + b convention)
    b1 : (1, H)
    w2 : (H, H)
    b2 : (1, H)
    -> (1, H)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resblock_kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    h = h_ref[...]
    z = jnp.dot(h, w1_ref[...].T, preferred_element_type=jnp.float32) + b1_ref[...]
    z = z * jax.nn.sigmoid(z)  # SiLU
    y = jnp.dot(z, w2_ref[...].T, preferred_element_type=jnp.float32) + b2_ref[...]
    o_ref[...] = h + y


def residual_mlp_block(h, w1, b1, w2, b2):
    """Fused residual MLP block (Pallas, interpret mode).

    Args:
        h: (1, H) activations.
        w1, w2: (H, H) weights (``y = x @ W.T + b``).
        b1, b2: (1, H) biases.
    Returns:
        (1, H) block output.
    """
    _, hidden = h.shape
    return pl.pallas_call(
        _resblock_kernel,
        out_shape=jax.ShapeDtypeStruct((1, hidden), jnp.float32),
        interpret=True,
    )(h, w1, b1, w2, b2)
