"""L2: the draft/target tiny-GPT language models in JAX.

The paper serves Qwen/Llama pairs; this repo's *real* serving path uses a
distilled stand-in pair (DESIGN.md §4): byte-level GPTs sharing a
tokenizer (vocab = 256), the draft small (2 layers, d=128) and the target
larger (4 layers, d=256), both trained on the same tiny corpus by
``train_lm.py`` so the draft actually tracks the target (non-trivial
acceptance rate).

Three entry points per model are AOT-lowered to HLO text and driven from
rust (KV caches are explicit operands — state lives in the rust
coordinator, never in python):

  * ``prefill(params, tokens[P], length) -> (logits[V], kv)``
  * ``decode_step(params, token, pos, kv) -> (logits[V], kv)``
  * ``verify(params, tokens[G1], pos, kv) -> (logits[G1, V], kv)``

``decode_step`` routes its attention through the L1 Pallas flash-decode
kernel so the kernel lowers into the shipped artifact; prefill/verify use
dense masked attention (a prefill-style compute pattern).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention

VOCAB = 256  # byte-level


class GptConfig(NamedTuple):
    """Architecture hyper-parameters."""

    n_layer: int
    n_head: int
    d_model: int
    max_len: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_head


# The serving pair. max_len bounds prompt + output; multiples of 128 keep
# the Pallas BLOCK_L tiling exact.
DRAFT_CONFIG = GptConfig(n_layer=2, n_head=4, d_model=128, max_len=384)
TARGET_CONFIG = GptConfig(n_layer=4, n_head=8, d_model=256, max_len=384)


def init_params(rng, cfg: GptConfig):
    """Initialize GPT parameters (dict pytree)."""
    keys = jax.random.split(rng, 4 + 6 * cfg.n_layer)
    k = iter(keys)
    scale = 0.02
    p = {
        "wte": jax.random.normal(next(k), (VOCAB, cfg.d_model)) * scale,
        "wpe": jax.random.normal(next(k), (cfg.max_len, cfg.d_model)) * scale,
        "ln_f_g": jnp.ones((cfg.d_model,)),
        "ln_f_b": jnp.zeros((cfg.d_model,)),
        "layers": [],
    }
    for _ in range(cfg.n_layer):
        d = cfg.d_model
        p["layers"].append(
            {
                "ln1_g": jnp.ones((d,)),
                "ln1_b": jnp.zeros((d,)),
                "qkv_w": jax.random.normal(next(k), (d, 3 * d)) * scale,
                "qkv_b": jnp.zeros((3 * d,)),
                "proj_w": jax.random.normal(next(k), (d, d)) * scale,
                "proj_b": jnp.zeros((d,)),
                "ln2_g": jnp.ones((d,)),
                "ln2_b": jnp.zeros((d,)),
                "fc_w": jax.random.normal(next(k), (d, 4 * d)) * scale,
                "fc_b": jnp.zeros((4 * d,)),
                "fc2_w": jax.random.normal(next(k), (4 * d, d)) * scale,
                "fc2_b": jnp.zeros((d,)),
            }
        )
    return p


def _ln(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def empty_kv(cfg: GptConfig):
    """Fresh KV cache: (n_layer, 2, n_head, max_len, head_dim) zeros."""
    return jnp.zeros(
        (cfg.n_layer, 2, cfg.n_head, cfg.max_len, cfg.head_dim), jnp.float32
    )


def _split_heads(x, cfg: GptConfig):
    # (T, d) -> (H, T, hd)
    t = x.shape[0]
    return x.reshape(t, cfg.n_head, cfg.head_dim).transpose(1, 0, 2)


def _merge_heads(x, cfg: GptConfig):
    # (H, T, hd) -> (T, d)
    return x.transpose(1, 0, 2).reshape(-1, cfg.d_model)


def _block_dense(p, cfg: GptConfig, x, kv_layer, start, t_valid):
    """Dense (training/prefill/verify) transformer block over T positions
    starting at absolute position `start`; writes K/V into the cache.

    Causal mask within the chunk + full visibility of cache positions
    < start. Returns (x_out, new_kv_layer).
    """
    t = x.shape[0]
    h = _ln(x, p["ln1_g"], p["ln1_b"])
    qkv = h @ p["qkv_w"] + p["qkv_b"]
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    qh = _split_heads(q, cfg)            # (H, T, hd)
    kh = _split_heads(k_new, cfg)
    vh = _split_heads(v_new, cfg)

    # Write new K/V into the cache at [start, start+T).
    kc = jax.lax.dynamic_update_slice(kv_layer[0], kh, (0, start, 0))
    vc = jax.lax.dynamic_update_slice(kv_layer[1], vh, (0, start, 0))

    # Attend over the full cache with a validity+causal mask.
    scores = jnp.einsum("htd,hld->htl", qh, kc) / (cfg.head_dim ** 0.5)
    l_pos = jnp.arange(cfg.max_len)[None, None, :]          # cache position
    q_pos = (start + jnp.arange(t))[None, :, None]          # query position
    mask = (l_pos <= q_pos) & (l_pos < start + t_valid)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    att = jnp.einsum("htl,hld->htd", w, vc)
    x = x + _merge_heads(att, cfg) @ p["proj_w"] + p["proj_b"]

    h2 = _ln(x, p["ln2_g"], p["ln2_b"])
    ff = jax.nn.gelu(h2 @ p["fc_w"] + p["fc_b"])
    x = x + ff @ p["fc2_w"] + p["fc2_b"]
    return x, jnp.stack([kc, vc])


def _block_decode(p, cfg: GptConfig, x, kv_layer, pos):
    """Single-token decode block: attention via the L1 Pallas kernel."""
    h = _ln(x, p["ln1_g"], p["ln1_b"])
    qkv = h @ p["qkv_w"] + p["qkv_b"]     # (1, 3d)
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    qh = q.reshape(cfg.n_head, cfg.head_dim)                     # (H, hd)
    kh = k_new.reshape(1, cfg.n_head, cfg.head_dim).transpose(1, 0, 2)
    vh = v_new.reshape(1, cfg.n_head, cfg.head_dim).transpose(1, 0, 2)

    kc = jax.lax.dynamic_update_slice(kv_layer[0], kh, (0, pos, 0))
    vc = jax.lax.dynamic_update_slice(kv_layer[1], vh, (0, pos, 0))

    # L1 kernel: query attends to positions [0, pos].
    length = (pos + 1).reshape(1).astype(jnp.int32)
    att = decode_attention(length, qh, kc, vc)                   # (H, hd)
    x = x + att.reshape(1, cfg.d_model) @ p["proj_w"] + p["proj_b"]

    h2 = _ln(x, p["ln2_g"], p["ln2_b"])
    ff = jax.nn.gelu(h2 @ p["fc_w"] + p["fc_b"])
    x = x + ff @ p["fc2_w"] + p["fc2_b"]
    return x, jnp.stack([kc, vc])


def prefill(params, cfg: GptConfig, tokens, length):
    """Prefill a (padded) prompt.

    Args:
        tokens: (P,) int32, padded with zeros past `length`.
        length: () int32 true prompt length (1 <= length <= P).
    Returns:
        (logits_last, kv): logits at the final valid position, full cache.
    """
    p = tokens.shape[0]
    x = params["wte"][tokens] + params["wpe"][:p]
    kv = empty_kv(cfg)
    new_layers = []
    for li, lp in enumerate(params["layers"]):
        x, kv_l = _block_dense(lp, cfg, x, kv[li], 0, length)
        new_layers.append(kv_l)
    kv = jnp.stack(new_layers)
    x = _ln(x, params["ln_f_g"], params["ln_f_b"])
    logits = x @ params["wte"].T                                  # (P, V)
    last = logits[jnp.maximum(length - 1, 0)]
    return last, kv


def decode_step(params, cfg: GptConfig, token, pos, kv):
    """One autoregressive decode step at absolute position `pos`.

    Args:
        token: () int32 the token at `pos`.
        pos: () int32.
        kv: the cache (valid through pos-1).
    Returns:
        (logits, kv): next-token logits (V,), cache now valid through pos.
    """
    x = params["wte"][token][None, :] + params["wpe"][pos][None, :]
    new_layers = []
    for li, lp in enumerate(params["layers"]):
        x, kv_l = _block_decode(lp, cfg, x, kv[li], pos)
        new_layers.append(kv_l)
    kv = jnp.stack(new_layers)
    x = _ln(x, params["ln_f_g"], params["ln_f_b"])
    return (x @ params["wte"].T)[0], kv


def verify(params, cfg: GptConfig, tokens, pos, kv):
    """Score a speculation window in one pass (paper Fig. 1(c), step 2).

    Args:
        tokens: (G1,) int32 — the last accepted token followed by the G
            draft tokens; they occupy absolute positions [pos, pos+G1).
        pos: () int32 start position.
        kv: cache valid through pos-1.
    Returns:
        (logits, kv): (G1, V) logits (row i predicts position pos+i+1),
        cache with the window written (rust rolls back by position).
    """
    g1 = tokens.shape[0]
    pos_idx = pos + jnp.arange(g1)
    x = params["wte"][tokens] + params["wpe"][pos_idx]
    new_layers = []
    for li, lp in enumerate(params["layers"]):
        x, kv_l = _block_dense(lp, cfg, x, kv[li], pos, jnp.int32(g1))
        new_layers.append(kv_l)
    kv = jnp.stack(new_layers)
    x = _ln(x, params["ln_f_g"], params["ln_f_b"])
    return x @ params["wte"].T, kv


def _block_train(p, cfg: GptConfig, x):
    """Cache-free causal block for training (batched over leading dim)."""
    t = x.shape[-2]
    h = _ln(x, p["ln1_g"], p["ln1_b"])
    qkv = h @ p["qkv_w"] + p["qkv_b"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):  # (..., T, d) -> (..., H, T, hd)
        return z.reshape(*z.shape[:-1], t, -1) if False else z

    # (B, T, d) -> (B, H, T, hd)
    def sh(z):
        b = z.shape[0]
        return z.reshape(b, t, cfg.n_head, cfg.head_dim).transpose(0, 2, 1, 3)

    qh, kh, vh = sh(q), sh(k), sh(v)
    scores = jnp.einsum("bhtd,bhld->bhtl", qh, kh) / (cfg.head_dim ** 0.5)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    att = jnp.einsum("bhtl,bhld->bhtd", jax.nn.softmax(scores, axis=-1), vh)
    att = att.transpose(0, 2, 1, 3).reshape(*x.shape)
    x = x + att @ p["proj_w"] + p["proj_b"]
    h2 = _ln(x, p["ln2_g"], p["ln2_b"])
    ff = jax.nn.gelu(h2 @ p["fc_w"] + p["fc_b"])
    return x + ff @ p["fc2_w"] + p["fc2_b"]


def loss_fn(params, cfg: GptConfig, batch):
    """Next-token cross-entropy over a (B, T+1) token batch (training).

    Uses the cache-free causal path (identical math to the serving path;
    the equivalence is asserted by ``tests/test_model.py``).
    """
    tokens = batch[:, :-1]
    targets = batch[:, 1:]
    t = tokens.shape[1]
    x = params["wte"][tokens] + params["wpe"][:t][None]
    for lp in params["layers"]:
        x = _block_train(lp, cfg, x)
    x = _ln(x, params["ln_f_g"], params["ln_f_b"])
    logits = x @ params["wte"].T
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---- Convenience jitted closures over a config ----


def make_fns(cfg: GptConfig):
    """Bind a config; returns (prefill_fn, decode_fn, verify_fn) suitable
    for both eager use (tests, training eval) and AOT lowering."""
    return (
        functools.partial(prefill, cfg=cfg),
        functools.partial(decode_step, cfg=cfg),
        functools.partial(verify, cfg=cfg),
    )
