"""WC-DNN: jax forward vs the rust JSON schema; kernel vs jnp path;
training on synthetic labels actually learns."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import wcdnn
from compile.train_wcdnn import train


@pytest.fixture(scope="module")
def params():
    return wcdnn.init_params(jax.random.PRNGKey(3))


def test_kernel_and_jnp_paths_agree(params):
    fm = jnp.zeros((5,))
    fs = jnp.ones((5,))
    for seed in range(5):
        x = jnp.asarray(np.random.default_rng(seed).normal(size=(5,)), jnp.float32)
        a = wcdnn.apply(params, x, fm, fs, use_kernel=True)
        b = wcdnn.apply(params, x, fm, fs, use_kernel=False)
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


def test_json_roundtrip_preserves_outputs(params, tmp_path):
    fm = jnp.asarray([0.5, 0.7, 20.0, 50.0, 4.0])
    fs = jnp.asarray([0.5, 0.2, 15.0, 30.0, 3.0])
    d = wcdnn.to_json_dict(params, fm, fs)
    p = tmp_path / "w.json"
    p.write_text(json.dumps(d))
    params2, fm2, fs2 = wcdnn.from_json_file(str(p))
    x = jnp.asarray([0.4, 0.8, 10.0, 40.0, 4.0], jnp.float32)
    a = wcdnn.apply(params, x, fm, fs, use_kernel=False)
    b = wcdnn.apply(params2, x, fm2, fs2, use_kernel=False)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_schema_matches_rust_expectations(params):
    d = wcdnn.to_json_dict(params, jnp.zeros(5), jnp.ones(5))
    assert d["arch"] == {"in": 5, "hidden": 64, "blocks": 2}
    assert len(d["in_w"]) == 64 and len(d["in_w"][0]) == 5
    assert len(d["blocks"]) == 2
    assert len(d["out_w"]) == 1 and len(d["out_w"][0]) == 64
    assert len(d["feat_mean"]) == 5 and len(d["feat_std"]) == 5


def test_training_learns_synthetic_rule():
    # Label rule: optimal gamma grows with acceptance, shrinks with RTT.
    rng = np.random.default_rng(0)
    n = 2000
    x = np.zeros((n, 5), np.float32)
    x[:, 0] = rng.uniform(0, 2, n)        # queue depth util
    x[:, 1] = rng.uniform(0.2, 1.0, n)    # acceptance
    x[:, 2] = rng.uniform(2, 100, n)      # rtt
    x[:, 3] = rng.uniform(20, 120, n)     # tpot
    x[:, 4] = rng.integers(1, 12, n)      # gamma prev
    y = np.clip(1.0 + 10.0 * x[:, 1] - 0.06 * x[:, 2], 1, 12).astype(np.float32)
    params, fm, fs, mae = train(x, y, epochs=30, verbose=False)
    assert mae < 1.0, f"val MAE {mae} too high"
    # Qualitative: higher acceptance -> larger predicted window.
    lo = wcdnn.apply(params, jnp.asarray([0.5, 0.3, 20.0, 60.0, 4.0]), fm, fs,
                     use_kernel=False)
    hi = wcdnn.apply(params, jnp.asarray([0.5, 0.95, 20.0, 60.0, 4.0]), fm, fs,
                     use_kernel=False)
    assert float(hi) > float(lo) + 1.0
