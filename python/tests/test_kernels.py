"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes/dtypes with hypothesis (the CORE correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import BLOCK_L, decode_attention
from compile.kernels.mlp import residual_mlp_block
from compile.kernels.verify import BLOCK_V, verify_tokens


def _rng(seed):
    return np.random.default_rng(seed)


# ---------- decode attention ----------


@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4, 8]),
    l_blocks=st.integers(1, 4),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref_swept(h, l_blocks, d, seed):
    r = _rng(seed)
    l = l_blocks * BLOCK_L
    q = jnp.asarray(r.normal(size=(h, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(h, l, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(h, l, d)), jnp.float32)
    length = jnp.asarray([int(r.integers(1, l + 1))], jnp.int32)
    out = decode_attention(length, q, k, v)
    want = ref.decode_attention_ref(length, q, k, v)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_attention_length_one():
    r = _rng(0)
    q = jnp.asarray(r.normal(size=(2, 16)), jnp.float32)
    k = jnp.asarray(r.normal(size=(2, BLOCK_L, 16)), jnp.float32)
    v = jnp.asarray(r.normal(size=(2, BLOCK_L, 16)), jnp.float32)
    length = jnp.asarray([1], jnp.int32)
    out = decode_attention(length, q, k, v)
    # Only position 0 is valid: output must be exactly v[:, 0, :].
    np.testing.assert_allclose(out, v[:, 0, :], rtol=1e-5, atol=1e-5)


def test_attention_full_length():
    r = _rng(1)
    h, l, d = 4, 2 * BLOCK_L, 32
    q = jnp.asarray(r.normal(size=(h, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(h, l, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(h, l, d)), jnp.float32)
    length = jnp.asarray([l], jnp.int32)
    out = decode_attention(length, q, k, v)
    want = ref.decode_attention_ref(length, q, k, v)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_attention_ignores_garbage_beyond_length():
    # Positions >= length must not influence the output at all.
    r = _rng(2)
    h, l, d = 2, BLOCK_L, 16
    q = jnp.asarray(r.normal(size=(h, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(h, l, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(h, l, d)), jnp.float32)
    length = jnp.asarray([40], jnp.int32)
    base = decode_attention(length, q, k, v)
    k2 = k.at[:, 40:, :].set(1e6)
    v2 = v.at[:, 40:, :].set(-1e6)
    poisoned = decode_attention(length, q, k2, v2)
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


# ---------- speculative verification ----------


@settings(max_examples=20, deadline=None)
@given(
    g=st.integers(1, 8),
    v_blocks=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_verify_matches_ref_swept(g, v_blocks, seed):
    r = _rng(seed)
    g1, v = g + 1, v_blocks * BLOCK_V
    logits = jnp.asarray(r.normal(size=(g1, v)), jnp.float32)
    draft = jnp.asarray(
        np.concatenate([r.integers(0, v, size=g), [-1]]), jnp.int32
    )
    tok, acc = verify_tokens(draft, logits)
    wt, wa = ref.verify_tokens_ref(draft, logits)
    np.testing.assert_array_equal(tok, wt)
    np.testing.assert_array_equal(acc, wa)


def test_verify_all_accept():
    v = BLOCK_V
    g = 4
    logits = np.full((g + 1, v), -5.0, np.float32)
    draft = np.zeros(g + 1, np.int32)
    for i in range(g + 1):
        winner = i * 7 % v
        logits[i, winner] = 5.0
        draft[i] = winner
    draft[g] = -1  # pad row
    tok, acc = verify_tokens(jnp.asarray(draft), jnp.asarray(logits))
    assert list(acc[:g]) == [1] * g
    assert int(acc[g]) == 0
    n, nxt = ref.fold_acceptance(np.asarray(acc), np.asarray(tok), g)
    assert n == g
    assert nxt == g * 7 % v  # bonus token from row g


def test_verify_first_mismatch_folds():
    v = BLOCK_V
    g = 4
    logits = np.full((g + 1, v), -5.0, np.float32)
    winners = [3, 9, 27, 81, 100]
    for i, w in enumerate(winners):
        logits[i, w] = 5.0
    draft = np.asarray([3, 9, 50, 81, -1], np.int32)  # mismatch at i=2
    tok, acc = verify_tokens(jnp.asarray(draft), jnp.asarray(logits))
    n, nxt = ref.fold_acceptance(np.asarray(acc), np.asarray(tok), g)
    assert n == 2
    assert nxt == 27  # the target's correction at the mismatch position


def test_verify_argmax_tie_behaviour():
    # Ties: both kernel and oracle use first-max; they must agree.
    v = BLOCK_V * 2
    logits = np.zeros((2, v), np.float32)  # everything ties at 0
    draft = np.asarray([0, -1], np.int32)
    tok, acc = verify_tokens(jnp.asarray(draft), jnp.asarray(logits))
    wt, wa = ref.verify_tokens_ref(jnp.asarray(draft), jnp.asarray(logits))
    np.testing.assert_array_equal(tok, wt)
    np.testing.assert_array_equal(acc, wa)


# ---------- fused residual MLP ----------


@settings(max_examples=20, deadline=None)
@given(hidden=st.sampled_from([16, 32, 64, 128]), seed=st.integers(0, 2**31 - 1))
def test_mlp_matches_ref_swept(hidden, seed):
    r = _rng(seed)
    h = jnp.asarray(r.normal(size=(1, hidden)), jnp.float32)
    w1 = jnp.asarray(r.normal(size=(hidden, hidden)) * 0.2, jnp.float32)
    b1 = jnp.asarray(r.normal(size=(1, hidden)) * 0.1, jnp.float32)
    w2 = jnp.asarray(r.normal(size=(hidden, hidden)) * 0.2, jnp.float32)
    b2 = jnp.asarray(r.normal(size=(1, hidden)) * 0.1, jnp.float32)
    out = residual_mlp_block(h, w1, b1, w2, b2)
    want = ref.residual_mlp_block_ref(h, w1, b1, w2, b2)
    np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


def test_mlp_zero_weights_is_identity():
    hidden = 32
    h = jnp.asarray(_rng(3).normal(size=(1, hidden)), jnp.float32)
    z = jnp.zeros((hidden, hidden), jnp.float32)
    zb = jnp.zeros((1, hidden), jnp.float32)
    out = residual_mlp_block(h, z, zb, z, zb)
    np.testing.assert_allclose(out, h, rtol=1e-6, atol=1e-6)


# ---------- fold_acceptance (pure) ----------


@settings(max_examples=50, deadline=None)
@given(g=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_fold_acceptance_invariants(g, seed):
    r = _rng(seed)
    mask = r.integers(0, 2, size=g + 1)
    mask[g] = 0
    toks = r.integers(0, 256, size=g + 1)
    n, nxt = ref.fold_acceptance(mask, toks, g)
    assert 0 <= n <= g
    assert nxt == toks[n]
    # n is the run-length of leading ones.
    for i in range(n):
        assert mask[i] == 1
    if n < g:
        assert mask[n] == 0
