"""L2 model consistency: prefill / decode_step / verify must be three
views of one function — and the serving path must agree with the
training path bit-for-bit (up to float tolerance)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.GptConfig(n_layer=2, n_head=4, d_model=64, max_len=256)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def prompt():
    return jnp.asarray(
        np.random.default_rng(1).integers(1, 255, size=(24,)), jnp.int32
    )


def _padded(prompt, p=48):
    return jnp.zeros((p,), jnp.int32).at[: prompt.shape[0]].set(prompt)


def test_prefill_matches_training_path(params, prompt):
    n = prompt.shape[0]
    logits_last, _ = M.prefill(params, CFG, _padded(prompt), jnp.int32(n))
    x = params["wte"][prompt] + params["wpe"][:n]
    x = x[None]
    for lp in params["layers"]:
        x = M._block_train(lp, CFG, x)
    x = M._ln(x[0], params["ln_f_g"], params["ln_f_b"])
    train_logits = x @ params["wte"].T
    np.testing.assert_allclose(logits_last, train_logits[-1], rtol=1e-4, atol=1e-4)


def test_prefill_padding_is_invisible(params, prompt):
    n = prompt.shape[0]
    a, _ = M.prefill(params, CFG, _padded(prompt, 48), jnp.int32(n))
    # Same prompt, different padding garbage.
    padded = jnp.full((48,), 99, jnp.int32).at[:n].set(prompt)
    b, _ = M.prefill(params, CFG, padded, jnp.int32(n))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_decode_continues_prefill(params, prompt):
    n = prompt.shape[0]
    _, kv = M.prefill(params, CFG, _padded(prompt), jnp.int32(n))
    tok = jnp.int32(65)
    logits, _ = M.decode_step(params, CFG, tok, jnp.int32(n), kv)
    ext = _padded(jnp.concatenate([prompt, tok[None]]), 48)
    want, _ = M.prefill(params, CFG, ext, jnp.int32(n + 1))
    np.testing.assert_allclose(logits, want, rtol=1e-3, atol=1e-3)


def test_verify_equals_decode_chain(params, prompt):
    n = prompt.shape[0]
    _, kv = M.prefill(params, CFG, _padded(prompt), jnp.int32(n))
    window = jnp.asarray([65, 66, 67, 68], jnp.int32)
    vlogits, _ = M.verify(params, CFG, window, jnp.int32(n), kv)
    # Row i of verify == decode_step after consuming window[:i+1].
    cur_kv = kv
    for i in range(window.shape[0]):
        logits, cur_kv = M.decode_step(
            params, CFG, window[i], jnp.int32(n + i), cur_kv
        )
        np.testing.assert_allclose(
            vlogits[i], logits, rtol=2e-3, atol=2e-3,
            err_msg=f"row {i} diverges",
        )


def test_verify_kv_rollback_by_position(params, prompt):
    # After a partial acceptance, re-verifying from an earlier position
    # must overwrite the stale cache rows: the result only depends on the
    # accepted prefix, not on previously written speculative K/V.
    n = prompt.shape[0]
    _, kv = M.prefill(params, CFG, _padded(prompt), jnp.int32(n))
    w1 = jnp.asarray([65, 200, 201], jnp.int32)
    _, kv_after = M.verify(params, CFG, w1, jnp.int32(n), kv)
    # Suppose only token 65 at position n was accepted. Continue from
    # position n+1 with a fresh window; compare against continuing from
    # the pristine cache with the same accepted history.
    w2 = jnp.asarray([66, 70, 71], jnp.int32)
    a, _ = M.verify(params, CFG, w2, jnp.int32(n + 1), kv_after)
    _, kv_clean = M.decode_step(params, CFG, jnp.int32(65), jnp.int32(n), kv)
    b, _ = M.verify(params, CFG, w2, jnp.int32(n + 1), kv_clean)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_greedy_generation_deterministic(params, prompt):
    n = prompt.shape[0]
    logits, kv = M.prefill(params, CFG, _padded(prompt), jnp.int32(n))
    toks = []
    tok = jnp.argmax(logits).astype(jnp.int32)
    pos = n
    for _ in range(8):
        toks.append(int(tok))
        logits, kv = M.decode_step(params, CFG, tok, jnp.int32(pos), kv)
        tok = jnp.argmax(logits).astype(jnp.int32)
        pos += 1
    logits2, kv2 = M.prefill(params, CFG, _padded(prompt), jnp.int32(n))
    tok2 = jnp.argmax(logits2).astype(jnp.int32)
    toks2 = []
    pos = n
    for _ in range(8):
        toks2.append(int(tok2))
        logits2, kv2 = M.decode_step(params, CFG, tok2, jnp.int32(pos), kv2)
        tok2 = jnp.argmax(logits2).astype(jnp.int32)
        pos += 1
    assert toks == toks2


def test_loss_decreases_quickly():
    # Tiny sanity training run: loss must drop on a repetitive corpus.
    from compile.train_lm import adam_init, adam_step

    cfg = M.GptConfig(n_layer=1, n_head=2, d_model=32, max_len=128)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params)
    data = np.frombuffer(b"abcdefgh" * 400, dtype=np.uint8).astype(np.int32)
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)
        params, opt = adam_step(params, grads, opt, lr=1e-2)
        return params, opt, loss

    losses = []
    for _ in range(30):
        idx = rng.integers(0, len(data) - 33, size=8)
        batch = jnp.asarray(np.stack([data[i : i + 33] for i in idx]))
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, f"{losses[0]} -> {losses[-1]}"
