//! Policy-family microbenchmarks: routing snapshots, batch formation and
//! AWC inference (MLP forward + stabilizer) on the decision path.
#[path = "harness/mod.rs"]
mod harness;
use dsd::awc::{AwcPolicy, AwcWeights};
use dsd::policies::window::{WindowFeatures, WindowPolicy};
use dsd::policies::{BatchingPolicy, Fifo, Jsq, Lab, QueuedRequest, RoutingPolicy, TargetSnapshot};
use dsd::util::rng::Pcg64;
use std::hint::black_box;

fn main() {
    let snaps: Vec<TargetSnapshot> = (0..20)
        .map(|id| TargetSnapshot { id, prefill_queue: id % 7, active: id % 5, ..Default::default() })
        .collect();
    let mut jsq = Jsq;
    let mut rng = Pcg64::new(1);
    harness::bench("policies/jsq route x100k (20 targets)", 30, || {
        let mut acc = 0usize;
        for _ in 0..100_000 {
            acc += jsq.route(&snaps, &mut rng);
        }
        black_box(acc);
    });

    let queue: Vec<QueuedRequest> = (0..64)
        .map(|id| QueuedRequest { id, length: ((id * 37) % 800) as u32 + 10, enqueued_ms: id as f64 })
        .collect();
    harness::bench("policies/lab form_batch x10k (64-deep queue)", 30, || {
        for _ in 0..10_000 {
            black_box(Lab::default().form_batch(&queue, 32));
        }
    });
    harness::bench("policies/fifo form_batch x10k (64-deep queue)", 30, || {
        for _ in 0..10_000 {
            black_box(Fifo.form_batch(&queue, 32));
        }
    });

    let mut awc = AwcPolicy::new(AwcWeights::builtin());
    let f = WindowFeatures {
        queue_depth_util: 0.4,
        acceptance_recent: 0.85,
        rtt_recent_ms: 10.0,
        tpot_recent_ms: 48.0,
        gamma_prev: 4,
    };
    harness::bench("policies/awc decide x10k (64-hidden mlp)", 30, || {
        for i in 0..10_000u64 {
            black_box(awc.decide(i % 32, &f));
        }
    });
    harness::finish("policies");
}
