//! Hot-path microbenchmarks (ROADMAP speed program): the four paths
//! every million-cell sweep pays per cell — DES event queue traffic, the
//! streaming simulator loop, cell-key derivation, and cell
//! serialization — plus paired old-vs-lean cases so the emitted
//! `BENCH_hotpath.json` records the measured speedup of this PR's
//! allocation-free variants.
//!
//! The suite itself lives in `dsd::bench` so `dsd bench --suite hotpath`
//! and the `cargo test` smoke test run the same cases.

use dsd::bench::{default_out_dir, run_suite, Tier};

fn main() {
    let report = run_suite("hotpath", Tier::Full).expect("built-in suite");
    match report.write_to(&default_out_dir()) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("[bench] {e}"),
    }
}
