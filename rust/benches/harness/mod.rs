//! Mini-criterion: the offline registry has no criterion crate, so each
//! bench target links this harness. `bench("name", iters, f)` warms up,
//! times `iters` runs, and prints mean / p50 / p99 per iteration.

use std::time::Instant;

/// Run and report one benchmark case.
pub fn bench(name: &str, iters: usize, mut f: impl FnMut()) {
    // Warmup.
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p99 = samples[(samples.len() * 99 / 100).min(samples.len() - 1)];
    println!("bench {name:<44} mean {mean:>9.3} ms  p50 {p50:>9.3} ms  p99 {p99:>9.3} ms");
}

/// Report a derived throughput figure alongside benches.
#[allow(dead_code)]
pub fn report_rate(name: &str, value: f64, unit: &str) {
    println!("rate  {name:<44} {value:>12.0} {unit}");
}
