//! Mini-criterion shim for the `cargo bench` targets: the offline
//! registry has no criterion crate, so each target links this module.
//!
//! The timing loop and percentile math live in [`dsd::bench`] (shared
//! with `dsd bench` on the CLI and the `cargo test` smoke test);
//! percentiles go through the linear-interpolation
//! `util::stats::percentile`, not the biased direct indexing this shim
//! originally used. Cases accumulate in a process-global collector, and
//! [`finish`] writes them as machine-readable `BENCH_<suite>.json` at
//! the repository root so successive runs form a perf trajectory — call
//! it at the end of every bench `main`.

use dsd::bench::{case_line, default_out_dir, rate_line, time_case, BenchReport, Tier};
use std::sync::Mutex;

static COLLECTOR: Mutex<Option<BenchReport>> = Mutex::new(None);

fn with_report(f: impl FnOnce(&mut BenchReport)) {
    let mut guard = COLLECTOR.lock().expect("bench collector");
    // The suite name is only known at `finish`; collect under a
    // placeholder until then.
    f(guard.get_or_insert_with(|| BenchReport::new("", Tier::Full)));
}

/// Run, record, and report one benchmark case.
pub fn bench(name: &str, iters: usize, f: impl FnMut()) {
    let case = time_case(name, iters, f);
    println!("{}", case_line(&case));
    with_report(|r| r.cases.push(case));
}

/// Report a derived throughput figure alongside benches.
#[allow(dead_code)]
pub fn report_rate(name: &str, value: f64, unit: &str) {
    println!("{}", rate_line(name, value, unit));
    with_report(|r| {
        r.rates.push(dsd::bench::RateResult {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
        })
    });
}

/// Persist everything benched so far as `BENCH_<suite>.json` at the
/// repository root. Call once, at the end of the bench target's `main`.
pub fn finish(suite: &str) {
    let report = {
        let mut guard = COLLECTOR.lock().expect("bench collector");
        guard.take()
    };
    let Some(mut report) = report else {
        // Nothing ran (e.g. the target bailed out early on missing
        // artifacts): write no file rather than an empty trajectory
        // point.
        eprintln!("[bench] no cases recorded; not writing BENCH_{suite}.json");
        return;
    };
    report.suite = suite.to_string();
    match report.write_to(&default_out_dir()) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("[bench] {e}"),
    }
}
