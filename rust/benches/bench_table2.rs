//! End-to-end benchmark: regenerate Table 2 (AWC vs baselines) at reduced scale (the bench
//! measures harness cost; `dsd reproduce --exp table2` is the full run).
#[path = "harness/mod.rs"]
mod harness;
use dsd::experiments::{table2, Scale};
use std::hint::black_box;

fn main() {
    harness::bench("table2/sweep at scale 0.25", 5, || {
        black_box(table2::run(Scale(0.25), &[1]));
    });
    harness::bench("table2/sweep at paper scale", 3, || {
        black_box(table2::run(Scale(1.0), &[1]));
    });
    harness::finish("table2");
}
