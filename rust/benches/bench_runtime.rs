//! PJRT runtime benchmarks over the real AOT artifacts: per-call costs of
//! the serving path (prefill / decode step / verify window). Skipped when
//! `artifacts/` is not built.
#[path = "harness/mod.rs"]
mod harness;
use std::hint::black_box;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench runtime: artifacts/ not built (run `make artifacts`); skipping");
        return;
    }
    let rt = std::sync::Arc::new(dsd::runtime::Runtime::load(dir).expect("runtime"));
    let draft = dsd::coordinator::DraftEngine::new(rt.clone());
    let target = dsd::coordinator::TargetEngine::new(rt.clone());
    let prompt = b"question: tom has 3 apples and buys 2 more. how many apples does tom have?\nanswer:";

    harness::bench("runtime/draft prefill (82-token prompt)", 10, || {
        black_box(draft.prefill(prompt).expect("prefill"));
    });
    harness::bench("runtime/target prefill", 10, || {
        black_box(target.prefill(prompt).expect("prefill"));
    });

    let (_, dkv, n) = draft.prefill(prompt).unwrap();
    let (tl, tkv, _) = target.prefill(prompt).unwrap();
    let first = dsd::coordinator::argmax(&tl);

    let mut kv = Some(dkv.clone());
    harness::bench("runtime/draft decode step", 20, || {
        let (logits, nkv) = draft.decode(first, n, kv.take().unwrap()).expect("decode");
        black_box(logits);
        kv = Some(nkv);
    });

    let (drafts, _) = draft.draft_window(first, n, 4, dkv).unwrap();
    let mut window = vec![first];
    window.extend_from_slice(&drafts);
    let mut tkv_slot = Some(tkv);
    harness::bench("runtime/target verify window (gamma=4)", 10, || {
        let (acc, corr, nkv) = target
            .verify(&window, n, tkv_slot.take().unwrap())
            .expect("verify");
        black_box((acc, corr));
        tkv_slot = Some(nkv);
    });
    harness::finish("runtime");
}
