//! DES engine microbenchmarks: raw event throughput (the §Perf L3 target
//! is ≥1M events/s so every figure regenerates in seconds).
#[path = "harness/mod.rs"]
mod harness;
use dsd::sim::EventQueue;
use std::time::Instant;

fn main() {
    harness::bench("engine/schedule+pop 100k events", 20, || {
        let mut q = EventQueue::new();
        let mut x = 1u64;
        for i in 0..100_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            q.schedule((x % 1_000_000) as f64, i);
        }
        while q.pop().is_some() {}
    });
    // Events/second figure.
    let mut q = EventQueue::new();
    let t = Instant::now();
    let n = 1_000_000u64;
    let mut x = 1u64;
    for i in 0..n {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
        q.schedule((x % 1_000_000) as f64, i);
    }
    while q.pop().is_some() {}
    harness::report_rate(
        "engine/events per second (1M sched+pop)",
        2.0 * n as f64 / t.elapsed().as_secs_f64(),
        "events/s",
    );
    harness::finish("engine");
}
