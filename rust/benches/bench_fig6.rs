//! End-to-end benchmark: regenerate Figure 6 (RTT sweep) at reduced scale (the bench
//! measures harness cost; `dsd reproduce --exp fig6` is the full run).
#[path = "harness/mod.rs"]
mod harness;
use dsd::experiments::{fig6, Scale};
use std::hint::black_box;

fn main() {
    harness::bench("fig6/sweep at scale 0.25", 5, || {
        black_box(fig6::run(Scale(0.25), &[1]));
    });
    harness::bench("fig6/sweep at paper scale", 3, || {
        black_box(fig6::run(Scale(1.0), &[1]));
    });
    harness::finish("fig6");
}
