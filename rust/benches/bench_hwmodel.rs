//! Hardware-model predictor benchmarks: `predict()` sits on the sim's
//! innermost loop and must be effectively free.
#[path = "harness/mod.rs"]
mod harness;
use dsd::cluster::gpu::A100;
use dsd::cluster::model::LLAMA2_70B;
use dsd::hwmodel::{Hardware, Op, Predictor};
use std::hint::black_box;
use std::time::Instant;

fn main() {
    let p = Predictor::new();
    let hw = Hardware { gpu: &A100, tp: 4 };
    harness::bench("hwmodel/100k decode predictions", 30, || {
        let mut acc = 0.0;
        for i in 0..100_000u32 {
            acc += p.predict(
                Op::Decode { batch: 1 + i % 32, avg_ctx: 64 + i % 512 },
                &LLAMA2_70B,
                hw,
            );
        }
        black_box(acc);
    });
    let t = Instant::now();
    let mut acc = 0.0;
    let n = 1_000_000;
    for i in 0..n as u32 {
        acc += p.predict(Op::Verify { batch: 8, window: 1 + i % 8, avg_ctx: 128 }, &LLAMA2_70B, hw);
    }
    black_box(acc);
    harness::report_rate(
        "hwmodel/predictions per second",
        n as f64 / t.elapsed().as_secs_f64(),
        "pred/s",
    );
    harness::finish("hwmodel");
}
