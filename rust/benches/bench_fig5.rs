//! End-to-end benchmark: regenerate Figure 5 (policy stacks) at reduced scale (the bench
//! measures harness cost; `dsd reproduce --exp fig5` is the full run).
#[path = "harness/mod.rs"]
mod harness;
use dsd::experiments::{fig5, Scale};
use std::hint::black_box;

fn main() {
    harness::bench("fig5/sweep at scale 0.25", 5, || {
        black_box(fig5::run(Scale(0.25), &[1]));
    });
    harness::bench("fig5/sweep at paper scale", 3, || {
        black_box(fig5::run(Scale(1.0), &[1]));
    });
    harness::finish("fig5");
}
