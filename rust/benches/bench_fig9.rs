//! End-to-end benchmark: regenerate Figures 9/10 (FIFO vs LAB).
#[path = "harness/mod.rs"]
mod harness;
use dsd::experiments::{fig9_10, Scale};
use std::hint::black_box;

fn main() {
    harness::bench("fig9_10/batching sweep at scale 0.25", 3, || {
        black_box(fig9_10::run(Scale(0.25), &[1]));
    });
    harness::finish("fig9");
}
