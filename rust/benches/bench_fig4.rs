//! End-to-end benchmark: regenerate Figure 4 (GPU-level calibration).
#[path = "harness/mod.rs"]
mod harness;
use std::hint::black_box;

fn main() {
    harness::bench("fig4/full calibration study", 10, || {
        black_box(dsd::experiments::fig4::run(42));
    });
    harness::finish("fig4");
}
