//! End-to-end benchmark: regenerate Figures 7/8 (routing scaling).
#[path = "harness/mod.rs"]
mod harness;
use dsd::experiments::{fig7_8, Scale};
use std::hint::black_box;

fn main() {
    harness::bench("fig7_8/routing sweep at scale 0.25", 3, || {
        black_box(fig7_8::run(Scale(0.25), &[1]));
    });
    harness::finish("fig7");
}
