//! # DSD — Distributed Speculative Decoding for Edge–Cloud LLM Serving
//!
//! Reproduction of *"DSD: A Distributed Speculative Decoding Solution for
//! Edge-Cloud Agile Large Model Serving"* (2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the DSD-Sim discrete-event simulator, the
//!   pluggable routing/batching/window-control policy families, the AWC
//!   learned window controller with its stabilization pipeline, the
//!   metrics/SLO analyzer, a real edge–cloud serving coordinator driving
//!   AOT-compiled models through PJRT, and the experiment harness that
//!   regenerates every table and figure in the paper's evaluation.
//! * **L2 (python/compile, build time)** — JAX draft/target tiny-GPT
//!   models and the WC-DNN residual MLP, lowered once to HLO text.
//! * **L1 (python/compile/kernels, build time)** — Pallas kernels for
//!   decode attention, speculative verification, and the fused MLP block.
//!
//! Python never runs on the request path; `artifacts/` is loaded by
//! [`runtime`] and executed from Rust.
//!
//! Quick start:
//!
//! ```no_run
//! use dsd::config::SimConfig;
//! use dsd::sim::Simulator;
//!
//! let cfg = SimConfig::builder()
//!     .targets(4)
//!     .drafters(120)
//!     .rtt_ms(10.0)
//!     .dataset("gsm8k")
//!     .requests(200)
//!     .build();
//! let report = Simulator::new(cfg).run();
//! println!("{}", report.summary());
//! ```

pub mod autoscale;
pub mod awc;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod hwmodel;
pub mod metrics;
pub mod obs;
pub mod policies;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod specdec;
pub mod sweep;
pub mod trace;
pub mod util;
