//! LLM architecture spec sheets driving the roofline latency model.
//!
//! Shapes match the public model cards for the models the paper uses:
//! Qwen-7B / Llama2-7B / Llama3.1-8B as edge drafters, Llama2-70B /
//! Qwen-72B / Llama3-70B as cloud targets.

/// Static description of a transformer LLM's compute-relevant shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    /// Model name, e.g. `"llama2-70b"`.
    pub name: &'static str,
    /// Total parameter count.
    pub params: f64,
    /// Number of transformer layers.
    pub layers: u32,
    /// Hidden dimension.
    pub hidden: u32,
    /// Attention heads.
    pub heads: u32,
    /// KV heads (GQA; equals `heads` for MHA models).
    pub kv_heads: u32,
    /// Vocabulary size.
    pub vocab: u32,
    /// Weight precision in bytes (2 = fp16/bf16 serving).
    pub dtype_bytes: f64,
}

impl ModelSpec {
    /// Bytes of weights resident on the serving devices.
    pub fn weight_bytes(&self) -> f64 {
        self.params * self.dtype_bytes
    }

    /// KV-cache bytes per token per request.
    ///
    /// `2 (K and V) * layers * kv_heads * head_dim * dtype_bytes`.
    pub fn kv_bytes_per_token(&self) -> f64 {
        let head_dim = self.hidden as f64 / self.heads as f64;
        2.0 * self.layers as f64 * self.kv_heads as f64 * head_dim * self.dtype_bytes
    }

    /// FLOPs for one token of dense forward (the classic 2·params rule).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.params
    }

    /// Attention FLOPs for one new token against a context of length `ctx`
    /// (scores + weighted sum over the KV cache).
    pub fn attn_flops_per_token(&self, ctx: f64) -> f64 {
        let head_dim = self.hidden as f64 / self.heads as f64;
        // QK^T and PV: 2 * 2 * heads * head_dim * ctx per layer.
        4.0 * self.layers as f64 * self.heads as f64 * head_dim * ctx
    }
}

/// Qwen-7B (edge drafter tier).
pub const QWEN_7B: ModelSpec = ModelSpec {
    name: "qwen-7b",
    params: 7.7e9,
    layers: 32,
    hidden: 4096,
    heads: 32,
    kv_heads: 32,
    vocab: 151_936,
    dtype_bytes: 2.0,
};

/// Llama2-7B (edge drafter tier).
pub const LLAMA2_7B: ModelSpec = ModelSpec {
    name: "llama2-7b",
    params: 6.74e9,
    layers: 32,
    hidden: 4096,
    heads: 32,
    kv_heads: 32,
    vocab: 32_000,
    dtype_bytes: 2.0,
};

/// Llama-3.1-8B (edge drafter tier, GQA).
pub const LLAMA31_8B: ModelSpec = ModelSpec {
    name: "llama3.1-8b",
    params: 8.03e9,
    layers: 32,
    hidden: 4096,
    heads: 32,
    kv_heads: 8,
    vocab: 128_256,
    dtype_bytes: 2.0,
};

/// Llama2-70B (cloud target tier, GQA).
pub const LLAMA2_70B: ModelSpec = ModelSpec {
    name: "llama2-70b",
    params: 69.0e9,
    layers: 80,
    hidden: 8192,
    heads: 64,
    kv_heads: 8,
    vocab: 32_000,
    dtype_bytes: 2.0,
};

/// Qwen-72B (cloud target tier).
pub const QWEN_72B: ModelSpec = ModelSpec {
    name: "qwen-72b",
    params: 72.7e9,
    layers: 80,
    hidden: 8192,
    heads: 64,
    kv_heads: 64,
    vocab: 151_936,
    dtype_bytes: 2.0,
};

/// Llama3-70B (cloud target tier, GQA).
pub const LLAMA3_70B: ModelSpec = ModelSpec {
    name: "llama3-70b",
    params: 70.6e9,
    layers: 80,
    hidden: 8192,
    heads: 64,
    kv_heads: 8,
    vocab: 128_256,
    dtype_bytes: 2.0,
};

/// Look up a model spec by (case-insensitive) name.
pub fn model_by_name(name: &str) -> Option<&'static ModelSpec> {
    match name.to_ascii_lowercase().as_str() {
        "qwen-7b" => Some(&QWEN_7B),
        "llama2-7b" => Some(&LLAMA2_7B),
        "llama3.1-8b" | "llama31-8b" => Some(&LLAMA31_8B),
        "llama2-70b" => Some(&LLAMA2_70B),
        "qwen-72b" => Some(&QWEN_72B),
        "llama3-70b" => Some(&LLAMA3_70B),
        _ => None,
    }
}

/// All known model specs.
pub fn all_models() -> [&'static ModelSpec; 6] {
    [
        &QWEN_7B,
        &LLAMA2_7B,
        &LLAMA31_8B,
        &LLAMA2_70B,
        &QWEN_72B,
        &LLAMA3_70B,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_works() {
        assert_eq!(model_by_name("LLAMA2-70B").unwrap().layers, 80);
        assert!(model_by_name("gpt-5").is_none());
    }

    #[test]
    fn kv_bytes_reflect_gqa() {
        // Llama2-70B (8 kv heads) has 8x smaller KV than Qwen-72B (64).
        let gqa = LLAMA2_70B.kv_bytes_per_token();
        let mha = QWEN_72B.kv_bytes_per_token();
        assert!((mha / gqa - 8.0).abs() < 1e-9, "ratio={}", mha / gqa);
    }

    #[test]
    fn weight_bytes_fp16() {
        assert!((LLAMA2_7B.weight_bytes() - 6.74e9 * 2.0).abs() < 1.0);
    }

    #[test]
    fn flops_scale_with_params() {
        assert!(LLAMA2_70B.flops_per_token() > 10.0 * LLAMA2_7B.flops_per_token() / 2.0);
        assert!(LLAMA2_70B.attn_flops_per_token(1000.0) > 0.0);
    }
}
