//! Cluster description: GPU SKU spec sheets, LLM architecture specs, and
//! device pools — the substrate the hardware latency model and the
//! simulator's topology are built on.

pub mod device;
pub mod gpu;
pub mod model;

pub use device::{DeviceInstance, DevicePool, Role};
pub use gpu::{gpu_by_name, GpuSpec, A100, A40, A6000, H100, V100};
pub use model::{model_by_name, ModelSpec};
