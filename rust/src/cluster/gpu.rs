//! GPU specification sheets for the hardware latency model.
//!
//! These are public datasheet numbers (dense FP16/BF16 tensor throughput
//! and HBM bandwidth) for the accelerators the paper evaluates: A40, A100,
//! H100 on the cloud side; A40 and V100 on the edge side; A6000 in the
//! large heterogeneous cluster experiment.

/// Static description of a GPU SKU.
#[derive(Clone, Debug, PartialEq)]
pub struct GpuSpec {
    /// SKU name, e.g. `"A100"`.
    pub name: &'static str,
    /// Dense FP16/BF16 tensor-core throughput, TFLOP/s.
    pub tflops: f64,
    /// HBM/GDDR memory bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Device memory capacity, GiB.
    pub mem_gib: f64,
    /// Intra-node interconnect bandwidth per link (NVLink/PCIe), GB/s.
    /// Used for tensor-parallel all-reduce cost.
    pub link_bw_gbps: f64,
    /// Fixed per-kernel launch + framework overhead, microseconds.
    pub kernel_overhead_us: f64,
}

/// A40: edge-grade datacenter GPU (the paper profiles edge LLMs on A40).
pub const A40: GpuSpec = GpuSpec {
    name: "A40",
    tflops: 149.7,
    mem_bw_gbps: 696.0,
    mem_gib: 48.0,
    link_bw_gbps: 31.5, // PCIe gen4 x16
    kernel_overhead_us: 12.0,
};

/// V100: older edge-pool GPU in the large cluster experiment.
pub const V100: GpuSpec = GpuSpec {
    name: "V100",
    tflops: 125.0,
    mem_bw_gbps: 900.0,
    mem_gib: 32.0,
    link_bw_gbps: 150.0, // NVLink2
    kernel_overhead_us: 14.0,
};

/// A100 (SXM 80GB): cloud verification tier.
pub const A100: GpuSpec = GpuSpec {
    name: "A100",
    tflops: 312.0,
    mem_bw_gbps: 2039.0,
    mem_gib: 80.0,
    link_bw_gbps: 300.0, // NVLink3
    kernel_overhead_us: 10.0,
};

/// H100 (SXM): cloud verification tier.
pub const H100: GpuSpec = GpuSpec {
    name: "H100",
    tflops: 989.0,
    mem_bw_gbps: 3350.0,
    mem_gib: 80.0,
    link_bw_gbps: 450.0, // NVLink4
    kernel_overhead_us: 8.0,
};

/// A6000: workstation GPU present in the paper's cloud pool.
pub const A6000: GpuSpec = GpuSpec {
    name: "A6000",
    tflops: 155.0,
    mem_bw_gbps: 768.0,
    mem_gib: 48.0,
    link_bw_gbps: 31.5, // PCIe gen4
    kernel_overhead_us: 12.0,
};

/// Look up a GPU spec by (case-insensitive) name.
pub fn gpu_by_name(name: &str) -> Option<&'static GpuSpec> {
    match name.to_ascii_lowercase().as_str() {
        "a40" => Some(&A40),
        "v100" => Some(&V100),
        "a100" => Some(&A100),
        "h100" => Some(&H100),
        "a6000" => Some(&A6000),
        _ => None,
    }
}

/// All known GPU SKUs.
pub fn all_gpus() -> [&'static GpuSpec; 5] {
    [&A40, &V100, &A100, &H100, &A6000]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_is_case_insensitive() {
        assert_eq!(gpu_by_name("h100").unwrap().name, "H100");
        assert_eq!(gpu_by_name("H100").unwrap().name, "H100");
        assert!(gpu_by_name("tpu-v4").is_none());
    }

    #[test]
    fn specs_are_sane() {
        for g in all_gpus() {
            assert!(g.tflops > 0.0 && g.mem_bw_gbps > 0.0 && g.mem_gib > 0.0);
            assert!(g.kernel_overhead_us > 0.0);
        }
        // Relative ordering sanity: H100 > A100 > A40 on compute.
        assert!(H100.tflops > A100.tflops);
        assert!(A100.tflops > A40.tflops);
    }
}
