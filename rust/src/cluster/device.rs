//! Device instances and pools: the expanded form of a deployment after the
//! `auto_topology` pass (paper §3.1) — explicit drafter and target device
//! lists with their hosted models and GPU configurations.

use super::gpu::GpuSpec;
use super::model::ModelSpec;

/// Role a device plays in the DSD deployment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Edge drafter running a small LLM.
    Drafter,
    /// Cloud target running a large LLM (verification + fused decode).
    Target,
}

/// One provisioned device (possibly multi-GPU via tensor parallelism).
#[derive(Clone, Debug)]
pub struct DeviceInstance {
    /// Unique id within its pool.
    pub id: usize,
    /// Drafter or target.
    pub role: Role,
    /// GPU SKU.
    pub gpu: &'static GpuSpec,
    /// Number of GPUs ganged with tensor parallelism.
    pub tp_degree: u32,
    /// Hosted model.
    pub model: &'static ModelSpec,
}

impl DeviceInstance {
    /// Whether the model's weights fit in aggregate device memory (with a
    /// 20% headroom for activations and KV cache).
    pub fn fits(&self) -> bool {
        let capacity = self.gpu.mem_gib * self.tp_degree as f64 * 1024.0 * 1024.0 * 1024.0;
        self.model.weight_bytes() * 1.2 <= capacity
    }
}

/// A pool of same-role devices (the Cloud Pool or the Edge Pool).
#[derive(Clone, Debug, Default)]
pub struct DevicePool {
    /// Devices, indexed by id.
    pub devices: Vec<DeviceInstance>,
}

impl DevicePool {
    /// Number of devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// Whether the pool is empty.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Add a device, assigning the next id. Returns the id.
    pub fn add(
        &mut self,
        role: Role,
        gpu: &'static GpuSpec,
        tp_degree: u32,
        model: &'static ModelSpec,
    ) -> usize {
        let id = self.devices.len();
        self.devices.push(DeviceInstance {
            id,
            role,
            gpu,
            tp_degree,
            model,
        });
        id
    }

    /// Validate that every device's model fits in memory.
    pub fn validate(&self) -> Result<(), String> {
        for d in &self.devices {
            if !d.fits() {
                return Err(format!(
                    "device {} ({}x{}): model {} ({:.0} GiB) does not fit",
                    d.id,
                    d.tp_degree,
                    d.gpu.name,
                    d.model.name,
                    d.model.weight_bytes() / (1024.0 * 1024.0 * 1024.0)
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::{A100, A40};
    use crate::cluster::model::{LLAMA2_70B, LLAMA2_7B};

    #[test]
    fn fits_checks_capacity() {
        let mut pool = DevicePool::default();
        pool.add(Role::Target, &A100, 4, &LLAMA2_70B); // 138 GiB on 320 GiB
        pool.add(Role::Drafter, &A40, 1, &LLAMA2_7B); // 13.5 GiB on 48 GiB
        assert!(pool.validate().is_ok());

        let mut bad = DevicePool::default();
        bad.add(Role::Target, &A40, 1, &LLAMA2_70B); // 138 GiB on 48 GiB
        assert!(bad.validate().is_err());
    }

    #[test]
    fn ids_are_sequential() {
        let mut pool = DevicePool::default();
        assert_eq!(pool.add(Role::Drafter, &A40, 1, &LLAMA2_7B), 0);
        assert_eq!(pool.add(Role::Drafter, &A40, 1, &LLAMA2_7B), 1);
        assert_eq!(pool.len(), 2);
    }
}
