//! `dsd` — the DSD leader binary.
//!
//! Subcommands:
//!   simulate       run DSD-Sim on a YAML deployment config
//!   sweep          expand a scenario grid and run every cell in parallel
//!   reproduce      regenerate a paper table/figure (fig4..fig10, table2, all)
//!   sweep-dataset  generate the AWC training dataset (paper §4.2)
//!   trace-gen      emit a synthetic workload trace (Table 1 schema)
//!   serve          run the real edge-cloud serving path on AOT artifacts
//!   awc-eval       compare AWC vs baselines on one configuration
//!
//! `dsd <cmd> --help` lists options.

use dsd::config::SimConfig;
use dsd::coordinator::{Coordinator, ServeConfig, ServeRequest, ServeWindow};
use dsd::experiments::{run_experiment, Scale};
use dsd::sim::Simulator;
use dsd::util::cli::Command;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!(
            "usage: dsd <simulate|sweep|reproduce|sweep-dataset|trace-gen|serve|awc-eval> [options]"
        );
        std::process::exit(2);
    };
    let result = match cmd.as_str() {
        "simulate" => cmd_simulate(rest),
        "sweep" => cmd_sweep(rest),
        "reproduce" => cmd_reproduce(rest),
        "sweep-dataset" => cmd_sweep_dataset(rest),
        "trace-gen" => cmd_trace_gen(rest),
        "serve" => cmd_serve(rest),
        "awc-eval" => cmd_awc_eval(rest),
        other => Err(format!("unknown subcommand '{other}'")),
    };
    if let Err(e) = result {
        eprintln!("{e}");
        std::process::exit(1);
    }
}

fn cmd_simulate(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("simulate", "run DSD-Sim on a deployment config")
        .opt("config", "YAML deployment file", None)
        .opt("seed", "override RNG seed", None)
        .flag("json", "emit the full JSON report");
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let mut cfg = match a.get("config") {
        Some(path) => SimConfig::from_yaml_file(path)?,
        None => SimConfig::builder().build(),
    };
    if let Some(seed) = a.get_u64("seed").map_err(|e| e.to_string())? {
        cfg.seed = seed;
    }
    let report = Simulator::try_new(cfg)?.run();
    if a.flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("{}", report.summary());
    }
    Ok(())
}

fn cmd_sweep(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("sweep", "expand a scenario grid and run every cell in parallel")
        .opt("grid", "sweep grid YAML file (base config + axes)", None)
        .opt("threads", "worker threads (0 = one per core)", Some("0"))
        .opt("out", "also write the JSON summary to this path", None)
        .flag("table", "print an ASCII table instead of JSON")
        .flag("streaming", "force streaming metrics regardless of the grid file");
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let mut grid = dsd::sweep::SweepGrid::from_yaml_file(a.require("grid").map_err(|e| e.to_string())?)?;
    if a.flag("streaming") {
        grid.streaming = true;
    }
    let mut threads = a.get_usize("threads").map_err(|e| e.to_string())?.unwrap();
    if threads == 0 {
        threads = dsd::sweep::default_threads();
    }
    eprintln!(
        "[sweep] {} cells on {} threads{} ...",
        grid.n_cells(),
        threads.clamp(1, grid.n_cells().max(1)),
        if grid.streaming { " (streaming)" } else { "" }
    );
    let cells = dsd::sweep::run_grid(&grid, threads)?;
    let summary = dsd::sweep::SweepSummary::new(cells, grid.streaming);
    let json = summary.to_json().to_string_pretty();
    if let Some(path) = a.get("out") {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
            }
        }
        std::fs::write(path, format!("{json}\n")).map_err(|e| e.to_string())?;
        eprintln!("[sweep] wrote {path}");
    }
    if a.flag("table") {
        println!("{}", summary.render_table());
    } else {
        println!("{json}");
    }
    if summary.n_failed() > 0 {
        return Err(format!("{} of {} cells failed", summary.n_failed(), summary.cells.len()));
    }
    Ok(())
}

fn cmd_reproduce(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("reproduce", "regenerate a paper table/figure")
        .opt("exp", "fig4|fig5|fig6|fig7|fig9|table2|all", Some("all"))
        .opt("scale", "request-count scale factor (1.0 = paper)", Some("1.0"))
        .opt("seeds", "number of seeds to average", Some("3"));
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let scale = Scale(a.get_f64("scale").map_err(|e| e.to_string())?.unwrap_or(1.0));
    let n_seeds = a.get_u64("seeds").map_err(|e| e.to_string())?.unwrap_or(3);
    let seeds: Vec<u64> = (1..=n_seeds).collect();
    let out = run_experiment(a.get("exp").unwrap_or("all"), scale, &seeds)?;
    println!("{out}");
    Ok(())
}

fn cmd_sweep_dataset(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("sweep-dataset", "generate the AWC training dataset")
        .opt("out", "output JSONL path", Some("data/awc_sweep.jsonl"))
        .flag("tiny", "reduced grid (tests)");
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let grid = if a.flag("tiny") {
        dsd::awc::SweepGrid::tiny()
    } else {
        dsd::awc::SweepGrid::default()
    };
    eprintln!(
        "[sweep] {} scenarios x {} probes ...",
        grid.n_scenarios(),
        grid.gammas.len() + 1
    );
    let rows = dsd::awc::generate_dataset(&grid);
    let path = std::path::Path::new(a.get("out").unwrap());
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    }
    dsd::awc::dataset::write_jsonl(&rows, path).map_err(|e| e.to_string())?;
    println!("wrote {} rows to {}", rows.len(), path.display());
    Ok(())
}

fn cmd_trace_gen(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("trace-gen", "emit a synthetic workload trace")
        .opt("dataset", "gsm8k|cnndm|humaneval", Some("gsm8k"))
        .opt("requests", "number of requests", Some("400"))
        .opt("rate", "arrival rate, req/s", Some("30"))
        .opt("drafters", "drafter pool size", Some("600"))
        .opt("seed", "rng seed", Some("42"))
        .opt("out", "output JSONL path", None);
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let ds = dsd::trace::dataset_by_name(a.get("dataset").unwrap())
        .ok_or("unknown dataset")?;
    let trace = ds.generate(
        a.get_usize("requests").map_err(|e| e.to_string())?.unwrap(),
        a.get_f64("rate").map_err(|e| e.to_string())?.unwrap(),
        a.get_usize("drafters").map_err(|e| e.to_string())?.unwrap(),
        a.get_u64("seed").map_err(|e| e.to_string())?.unwrap(),
    );
    let out = a.require("out").map_err(|e| e.to_string())?;
    dsd::trace::io::write_jsonl(&trace, std::path::Path::new(out))
        .map_err(|e| e.to_string())?;
    println!(
        "wrote {} records (mean prompt {:.0}, mean output {:.0}, acceptance {:.2})",
        trace.len(),
        trace.mean_prompt(),
        trace.mean_output(),
        trace.mean_acceptance()
    );
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("serve", "real edge-cloud serving on AOT artifacts")
        .opt("artifacts", "artifacts directory", Some("artifacts"))
        .opt("requests", "number of requests", Some("8"))
        .opt("tokens", "output tokens per request", Some("32"))
        .opt("drafters", "edge worker threads", Some("4"))
        .opt("verifiers", "cloud worker threads", Some("2"))
        .opt("rtt", "emulated RTT, ms", Some("10"))
        .opt("window", "static:<g> | awc | fused", Some("static:4"))
        .opt("dataset", "prompt family: gsm8k|cnndm|humaneval", Some("gsm8k"));
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let window = parse_serve_window(a.get("window").unwrap())?;
    let cfg = ServeConfig {
        n_drafters: a.get_usize("drafters").map_err(|e| e.to_string())?.unwrap(),
        n_verifiers: a.get_usize("verifiers").map_err(|e| e.to_string())?.unwrap(),
        rtt_ms: a.get_f64("rtt").map_err(|e| e.to_string())?.unwrap(),
        window,
        max_new_tokens: a.get_usize("tokens").map_err(|e| e.to_string())?.unwrap(),
    };
    let n = a.get_usize("requests").map_err(|e| e.to_string())?.unwrap();
    let requests = demo_prompts(a.get("dataset").unwrap(), n, cfg.max_new_tokens);
    let co = Coordinator::new(std::path::Path::new(a.get("artifacts").unwrap()), cfg)
        .map_err(|e| e.to_string())?;
    let (rs, stats) = co.serve(requests).map_err(|e| e.to_string())?;
    for r in rs.iter().take(3) {
        println!(
            "req {}: acc={:.2} rounds={} tpot={:.0}ms | {:?}",
            r.id,
            r.acceptance(),
            r.rounds,
            r.tpot_ms,
            String::from_utf8_lossy(&r.output)
        );
    }
    println!(
        "completed={} tput={:.2} req/s tokens/s={:.1} ttft={:.0}ms tpot={:.0}ms acc={:.2}",
        stats.completed,
        stats.throughput_rps,
        stats.token_throughput,
        stats.mean_ttft_ms,
        stats.mean_tpot_ms,
        stats.mean_acceptance
    );
    Ok(())
}

fn cmd_awc_eval(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("awc-eval", "AWC vs baselines on one configuration")
        .opt("dataset", "gsm8k|cnndm|humaneval", Some("gsm8k"))
        .opt("drafters", "edge pool size", Some("600"))
        .opt("rtt", "RTT ms", Some("10"))
        .opt("scale", "request scale", Some("0.5"))
        .opt("seeds", "seeds to average", Some("3"));
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let scale = Scale(a.get_f64("scale").map_err(|e| e.to_string())?.unwrap());
    let seeds: Vec<u64> =
        (1..=a.get_u64("seeds").map_err(|e| e.to_string())?.unwrap()).collect();
    use dsd::config::{BatchingKind, RoutingKind, WindowKind};
    use dsd::experiments::common::{mean_of, paper_config, run_seeds};
    let mut table = dsd::util::table::Table::new(&["policy", "tput", "ttft", "tpot"])
        .with_title("AWC vs baselines");
    for (name, w) in [
        ("static", WindowKind::Static(4)),
        ("dynamic", WindowKind::Dynamic { init: 4, lo: 0.25, hi: 0.75 }),
        ("awc", WindowKind::Awc { weights_path: None }),
    ] {
        let cfg = paper_config(
            a.get("dataset").unwrap(),
            a.get_usize("drafters").map_err(|e| e.to_string())?.unwrap(),
            a.get_f64("rtt").map_err(|e| e.to_string())?.unwrap(),
            RoutingKind::Jsq,
            BatchingKind::Lab,
            w,
            scale,
            seeds[0],
        );
        let reps = run_seeds(&cfg, &seeds);
        table.row(vec![
            name.into(),
            format!("{:.1}", mean_of(&reps, |r| r.system.throughput_rps)),
            format!("{:.0}", mean_of(&reps, |r| r.mean_ttft())),
            format!("{:.1}", mean_of(&reps, |r| r.mean_tpot())),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn parse_serve_window(s: &str) -> Result<ServeWindow, String> {
    if let Some(g) = s.strip_prefix("static:") {
        return Ok(ServeWindow::Static(
            g.parse().map_err(|_| format!("bad gamma '{g}'"))?,
        ));
    }
    match s {
        "awc" => Ok(ServeWindow::Awc),
        "fused" => Ok(ServeWindow::FusedOnly),
        other => Err(format!("unknown window '{other}'")),
    }
}

/// Prompts shaped like the three benchmark families (mirrors
/// `python/compile/corpus.py::sample_prompts`).
fn demo_prompts(dataset: &str, n: usize, max_new: usize) -> Vec<ServeRequest> {
    (0..n)
        .map(|i| {
            let a = 3 + (i * 11) % 50;
            let b = 2 + (i * 3) % 30;
            let prompt = match dataset {
                "cnndm" => format!(
                    "article: the city council voted on tuesday to approve the new transit plan. \
                     officials said the project will add {a} miles of track and create {b} jobs over the next decade.\nsummary:"
                ),
                "humaneval" => "def add(a, b):\n".to_string(),
                _ => format!(
                    "question: tom has {a} apples and buys {b} more. how many apples does tom have?\nanswer:"
                ),
            };
            ServeRequest {
                id: i,
                prompt: prompt.into_bytes(),
                max_new_tokens: max_new,
            }
        })
        .collect()
}
