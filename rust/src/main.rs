//! `dsd` — the DSD leader binary.
//!
//! Subcommands:
//!   simulate       run DSD-Sim on a YAML deployment config (--scenario adds
//!                  scripted dynamics: flash crowds, link churn, failures;
//!                  --autoscale adds an elastic target pool with cost
//!                  accounting; --classes adds multi-tenant request classes
//!                  with priority-aware admission; --execution picks the
//!                  round engine: sequential | pipelined; --trace-out writes
//!                  a Chrome trace-event JSON of per-request phase spans)
//!   trace          inspect a --trace-out file (summarize: per-phase latency
//!                  breakdown + slowest requests)
//!   sweep          expand a scenario grid and run every cell in parallel
//!                  (--shard i/n partitions the grid deterministically across
//!                  N workers; --merge splices shard run dirs back into the
//!                  byte-identical single-process summary)
//!   reproduce      regenerate a paper table/figure (fig4..fig10, table2,
//!                  agility, elasticity, fairness, pipeline, all)
//!   sweep-dataset  generate the AWC training dataset (paper §4.2)
//!   trace-gen      emit a synthetic workload trace (Table 1 schema)
//!   serve          run the real edge-cloud serving path on AOT artifacts;
//!                  with --listen, run the long-lived grid service instead
//!                  (line-delimited JSON protocol: submit-grid,
//!                  poll-progress, fetch-summary, cancel, stats, shutdown)
//!   submit         client for a --listen grid service (submit a grid, wait,
//!                  fetch the summary; also status/cancel/stats/shutdown/ping)
//!   awc-eval       compare AWC vs baselines on one configuration
//!   bench          run a named benchmark suite and write BENCH_<suite>.json
//!
//! `dsd <cmd> --help` lists options.

use dsd::config::SimConfig;
use dsd::coordinator::{Coordinator, ServeConfig, ServeRequest, ServeWindow};
use dsd::experiments::Scale;
use dsd::sim::Simulator;
use dsd::util::cli::Command;
use dsd::log_info;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!(
            "usage: dsd <simulate|sweep|reproduce|sweep-dataset|trace|trace-gen|serve|submit|\
             awc-eval|bench> [options]"
        );
        std::process::exit(2);
    };
    let result = match cmd.as_str() {
        "simulate" => cmd_simulate(rest),
        "sweep" => cmd_sweep(rest),
        "reproduce" => cmd_reproduce(rest),
        "sweep-dataset" => cmd_sweep_dataset(rest),
        "trace" => cmd_trace(rest),
        "trace-gen" => cmd_trace_gen(rest),
        "serve" => cmd_serve(rest),
        "submit" => cmd_submit(rest),
        "awc-eval" => cmd_awc_eval(rest),
        "bench" => cmd_bench(rest),
        other => Err(format!("unknown subcommand '{other}'")),
    };
    if let Err(e) = result {
        eprintln!("{e}");
        std::process::exit(1);
    }
}

fn cmd_simulate(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("simulate", "run DSD-Sim on a deployment config")
        .opt("config", "YAML deployment file", None)
        .opt(
            "scenario",
            "scenario YAML file (scripted dynamics: time-varying arrivals, link \
             churn, device failures — overrides any scenario in --config)",
            None,
        )
        .opt(
            "autoscale",
            "autoscale YAML file (elastic target pool: scaling policy, capacity \
             bounds, cold-start delay, cost rate — overrides any autoscale block \
             in --config)",
            None,
        )
        .opt(
            "classes",
            "request-classes YAML file (multi-tenant SLO tiers: per-class arrival \
             processes, priority admission, batch deferral — overrides any classes \
             block in --config)",
            None,
        )
        .opt(
            "execution",
            "round execution mode: sequential (default; draft, ship, wait for the \
             verdict) or pipelined (draft the next window against the in-flight \
             verdict; rejections invalidate it and meter wasted work) — overrides \
             any execution key in --config",
            None,
        )
        .opt("seed", "override RNG seed", None)
        .opt(
            "trace-out",
            "write a Chrome trace-event JSON file of per-request, per-round phase \
             spans in simulated time (load in Perfetto, or run `dsd trace \
             summarize --in <file>`); the printed report stays byte-identical \
             to an untraced run",
            None,
        )
        .flag(
            "streaming",
            "bounded-memory streaming metrics: folded percentiles, per-target and \
             per-drafter-pool breakdowns, γ histogram, SLO counters",
        )
        .flag("json", "emit the full JSON report");
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let mut cfg = match a.get("config") {
        Some(path) => SimConfig::from_yaml_file(path)?,
        None => SimConfig::builder().build(),
    };
    // Apply ALL overrides before validating: a scenario with
    // target_pool_* events is only valid together with an autoscale
    // block (and class_rate_override events only with a classes block),
    // and the flags commonly arrive together.
    if let Some(path) = a.get("scenario") {
        cfg.scenario = Some(dsd::scenario::Scenario::from_yaml_file(path)?);
    }
    if let Some(path) = a.get("autoscale") {
        cfg.autoscale = Some(dsd::autoscale::AutoscaleConfig::from_yaml_file(path)?);
    }
    if let Some(path) = a.get("classes") {
        cfg.classes = Some(dsd::config::ClassesConfig::from_yaml_file(path)?);
    }
    if a.get("scenario").is_some() || a.get("autoscale").is_some() || a.get("classes").is_some()
    {
        cfg.validate()?;
    }
    if let Some(mode) = a.get("execution") {
        cfg.execution = dsd::specdec::ExecutionMode::parse(mode)?;
    }
    if let Some(seed) = a.get_u64("seed").map_err(|e| e.to_string())? {
        cfg.seed = seed;
    }
    let trace_out = a.get("trace-out");
    if a.flag("streaming") {
        let report = match trace_out {
            Some(path) => {
                let (report, trace) = Simulator::try_new(cfg)?.try_run_streaming_traced()?;
                trace.write_chrome_trace(path)?;
                log_info!("[simulate] wrote trace {path}");
                report
            }
            None => Simulator::try_new(cfg)?.try_run_streaming()?,
        };
        if a.flag("json") {
            println!("{}", report.to_json().to_string_pretty());
        } else {
            println!("{}", report.summary());
        }
        return Ok(());
    }
    let report = match trace_out {
        Some(path) => {
            let (report, trace) = Simulator::try_new(cfg)?.try_run_traced()?;
            trace.write_chrome_trace(path)?;
            log_info!("[simulate] wrote trace {path}");
            report
        }
        None => Simulator::try_new(cfg)?.run(),
    };
    if a.flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("{}", report.summary());
    }
    Ok(())
}

/// `dsd trace summarize --in run.trace.json [--top N]`: phase-latency
/// breakdown and slowest-request timelines from a `--trace-out` file.
fn cmd_trace(rest: &[String]) -> Result<(), String> {
    let Some((action, rest)) = rest.split_first() else {
        return Err("usage: dsd trace summarize --in <run.trace.json> [--top <k>]".into());
    };
    match action.as_str() {
        "summarize" => {
            let spec = Command::new(
                "trace summarize",
                "per-phase latency breakdown + slowest requests from a --trace-out file",
            )
            .opt("in", "Chrome trace-event JSON written by `dsd simulate --trace-out`", None)
            .opt("top", "how many slowest requests to expand with span timelines", Some("5"));
            let a = spec.parse(rest).map_err(|e| e.to_string())?;
            let path = a.require("in").map_err(|e| e.to_string())?;
            let top = a.get_usize("top").map_err(|e| e.to_string())?.unwrap();
            let doc = dsd::obs::trace::read_chrome_trace(path)?;
            println!("{}", dsd::obs::trace::summarize_chrome_trace(&doc, top)?);
            Ok(())
        }
        other => Err(format!("unknown trace action '{other}' (known: summarize)")),
    }
}

fn cmd_sweep(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("sweep", "expand a scenario grid and run every cell in parallel")
        .opt("grid", "sweep grid YAML file (base config + axes)", None)
        .opt("threads", "worker threads (0 = one per core)", Some("0"))
        .opt("out", "also write the JSON summary to this path", None)
        .opt(
            "out-dir",
            "cached run directory: cells persist to <dir>/cells as they finish, \
             summary to <dir>/summary.json, grid copy to <dir>/grid.yaml",
            None,
        )
        .opt(
            "resume",
            "continue a killed --out-dir run from its cell directory \
             (reads <dir>/grid.yaml unless --grid is also given)",
            None,
        )
        .opt(
            "filter",
            "axis selection key=value[,key=value] (e.g. rtt_ms=5,window=static4); \
             summary is labeled partial",
            None,
        )
        .opt(
            "gc",
            "garbage-collect a cell directory (or run dir with cells/): prune entries \
             orphaned by a SIM_VERSION_TAG bump, corrupt files, and stale tmp files; \
             with --grid (optionally narrowed by --filter), also prune cells outside \
             that selection. Runs standalone.",
            None,
        )
        .opt(
            "shard",
            "run only shard i of an n-way deterministic cell partition (0-based, \
             e.g. 0/4): cells with index ≡ i (mod n) execute here, persist to the \
             run directory's cells/, and a summary-shard-i-of-n.json manifest \
             records the grid hash and counts. Requires --out-dir or --resume; \
             reassemble with --merge.",
            None,
        )
        .opt(
            "merge",
            "comma-separated shard run directories (or one shared directory): \
             validate grid-hash/mode/filter agreement and shard completeness, \
             splice the cached cells into a summary byte-identical to the \
             single-process run. Runs standalone; writes summary.json to \
             --out-dir (or the single shared directory) and honors --out/--table.",
            None,
        )
        .opt(
            "log-level",
            "stderr log threshold: error|warn|info|debug (overrides DSD_LOG)",
            None,
        )
        .flag("table", "print an ASCII table instead of JSON")
        .flag("streaming", "force streaming metrics regardless of the grid file");
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    dsd::obs::log::set_level_str(a.get("log-level").unwrap_or(""))?;
    if let Some(dirs) = a.get("merge") {
        if a.get("grid").is_some()
            || a.get("filter").is_some()
            || a.get("resume").is_some()
            || a.get("shard").is_some()
            || a.get("gc").is_some()
        {
            return Err(
                "sweep: --merge runs standalone (no --grid/--filter/--resume/--shard/--gc; \
                 the grid and filter come from the shard directories)"
                    .into(),
            );
        }
        return cmd_sweep_merge(dirs, a.get("out"), a.get("out-dir"), a.flag("table"));
    }
    if let Some(dir) = a.get("gc") {
        if a.get("out-dir").is_some() || a.get("resume").is_some() || a.get("shard").is_some() {
            return Err("sweep: --gc runs standalone (no --out-dir/--resume/--shard)".into());
        }
        if a.get("filter").is_some() && a.get("grid").is_none() {
            return Err("sweep: --gc --filter needs --grid to expand cells".into());
        }
        return cmd_sweep_gc(std::path::Path::new(dir), a.get("grid"), a.get("filter"));
    }
    // A cached run directory comes from --out-dir (fresh) or --resume
    // (continue); both mean the same layout, and cells are
    // content-addressed so resuming is just re-running against the
    // directory.
    let run_dir: Option<std::path::PathBuf> = match (a.get("out-dir"), a.get("resume")) {
        (Some(_), Some(_)) => {
            return Err("sweep: --out-dir and --resume are mutually exclusive".into())
        }
        (Some(d), None) => Some(d.into()),
        (None, Some(d)) => Some(d.into()),
        (None, None) => None,
    };
    let grid_text = match a.get("grid") {
        Some(path) => {
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?
        }
        None => match (a.get("resume"), &run_dir) {
            (Some(_), Some(dir)) => {
                let p = dir.join("grid.yaml");
                std::fs::read_to_string(&p).map_err(|e| {
                    format!("resume: cannot read {} ({e}); pass --grid explicitly", p.display())
                })?
            }
            _ => return Err("missing required option --grid".into()),
        },
    };
    let mut grid = dsd::sweep::SweepGrid::from_yaml(&grid_text)?;
    // The run dir remembers a `--streaming` override (the grid copy is
    // raw text, and mode is part of every cell key): a resumed sweep
    // must run in the same mode it was killed in, or every cached cell
    // would silently miss.
    let forced_marker = run_dir.as_ref().map(|d| d.join("streaming-forced"));
    if a.flag("streaming")
        || forced_marker.as_ref().is_some_and(|m| a.get("resume").is_some() && m.exists())
    {
        grid.streaming = true;
    }
    let mut threads = a.get_usize("threads").map_err(|e| e.to_string())?.unwrap();
    if threads == 0 {
        threads = dsd::sweep::default_threads();
    }
    let cache = match &run_dir {
        Some(dir) => {
            std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
            // Atomic (tmp + rename) and skipped when unchanged: resume
            // depends on this file, and a kill mid-`fs::write` (which
            // truncates first) could otherwise leave a grid copy that
            // parses as the wrong — e.g. 1-cell — grid.
            let grid_copy = dir.join("grid.yaml");
            if std::fs::read_to_string(&grid_copy).ok().as_deref() != Some(&grid_text) {
                let tmp = dir.join(format!("grid.yaml.tmp.{}", std::process::id()));
                std::fs::write(&tmp, &grid_text)
                    .map_err(|e| format!("write grid copy: {e}"))?;
                std::fs::rename(&tmp, &grid_copy)
                    .map_err(|e| format!("write grid copy: {e}"))?;
            }
            if a.flag("streaming") {
                std::fs::write(forced_marker.as_ref().unwrap(), "")
                    .map_err(|e| format!("write streaming marker: {e}"))?;
            } else if a.get("out-dir").is_some() {
                // Fresh --out-dir without the flag: clear any stale
                // marker from a previous run of this directory.
                let _ = std::fs::remove_file(forced_marker.as_ref().unwrap());
            }
            Some(dsd::sweep::CellCache::open(&dir.join("cells"))?)
        }
        None => None,
    };
    let shard = match a.get("shard") {
        Some(s) => {
            if run_dir.is_none() {
                return Err(
                    "sweep: --shard needs --out-dir (or --resume): shard cells must \
                     persist somewhere --merge can find them"
                        .into(),
                );
            }
            Some(dsd::sweep::ShardSpec::parse(s)?)
        }
        None => None,
    };
    let mut cells = grid.expand()?;
    let filter = match a.get("filter") {
        Some(f) => {
            let pairs = dsd::sweep::parse_filter(f)?;
            cells = dsd::sweep::filter_cells(cells, &pairs)?;
            Some(dsd::sweep::filter_label(&pairs))
        }
        None => None,
    };
    // The fingerprint covers the FULL (filtered) grid, pre-partition:
    // every shard of one grid records the same hash, which is what
    // --merge cross-checks.
    let cells_total = cells.len();
    let grid_hash = shard
        .as_ref()
        .map(|_| dsd::sweep::grid_fingerprint(&cells, grid.streaming));
    if let Some(spec) = &shard {
        cells = dsd::sweep::shard_cells(cells, spec);
    }
    log_info!(
        "[sweep] {} cells on {} threads{}{}{} ...",
        cells.len(),
        threads.clamp(1, cells.len().max(1)),
        if grid.streaming { " (streaming)" } else { "" },
        match &filter {
            Some(f) => format!(" (filter: {f})"),
            None => String::new(),
        },
        match &shard {
            Some(s) => format!(" (shard {} of {} total cells)", s.label(), cells_total),
            None => String::new(),
        }
    );
    let (results, stats) =
        dsd::sweep::run_cells_cached(&cells, grid.streaming, threads, cache.as_ref());
    if cache.is_some() {
        log_info!("[sweep] {}", stats.describe());
    }
    if let Some(spec) = shard {
        // Shard runs write their manifest, never summary.json: a shard
        // summary would be a partial result wearing a full result's
        // name. The merged summary comes from `--merge`.
        let n_failed = results.iter().filter(|r| r.outcome.is_err()).count();
        let manifest = dsd::sweep::ShardManifest {
            shard: spec,
            grid_hash: grid_hash.expect("sharded runs carry a fingerprint"),
            streaming: grid.streaming,
            filter,
            cells_total,
            cells_in_shard: results.len(),
            failed_cells: n_failed,
            stats,
        };
        let path = manifest.write_to(run_dir.as_ref().expect("--shard requires a run dir"))?;
        log_info!("[sweep] wrote {}", path.display());
        if n_failed > 0 {
            return Err(format!(
                "{n_failed} of {} shard cells failed (markers persisted; merge will \
                 surface them)",
                results.len()
            ));
        }
        return Ok(());
    }
    let summary =
        dsd::sweep::SweepSummary::new(results, grid.streaming).with_filter(filter.clone());
    let json = summary.to_json().to_string_pretty();
    let write_to = |path: &std::path::Path| -> Result<(), String> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
            }
        }
        std::fs::write(path, format!("{json}\n")).map_err(|e| e.to_string())?;
        log_info!("[sweep] wrote {}", path.display());
        Ok(())
    };
    if let Some(path) = a.get("out") {
        write_to(std::path::Path::new(path))?;
    }
    if let Some(dir) = &run_dir {
        // Filtered runs land beside the full summary, never over it: a
        // partial result must not clobber a complete one.
        let name = if filter.is_some() { "summary-partial.json" } else { "summary.json" };
        write_to(&dir.join(name))?;
    }
    if a.flag("table") {
        println!("{}", summary.render_table());
    } else {
        println!("{json}");
    }
    if summary.n_failed() > 0 {
        return Err(format!("{} of {} cells failed", summary.n_failed(), summary.cells.len()));
    }
    Ok(())
}

/// `dsd sweep --gc <dir> [--grid g.yaml [--filter k=v,...]]`: prune a
/// cell directory. Accepts either a raw cells directory or a
/// `--out-dir` run directory (whose cells live under `<dir>/cells`).
/// With a grid, the keys of the (optionally filtered, same semantics as
/// a `--filter` run) expansion in *both* metric modes stay valid — a
/// directory may hold full-mode and streaming cells for the same grid.
fn cmd_sweep_gc(
    dir: &std::path::Path,
    grid_path: Option<&str>,
    filter: Option<&str>,
) -> Result<(), String> {
    let cells_dir = if dir.join("cells").is_dir() {
        dir.join("cells")
    } else {
        dir.to_path_buf()
    };
    if !cells_dir.is_dir() {
        return Err(format!("gc: no such cell directory {}", cells_dir.display()));
    }
    let cache = dsd::sweep::CellCache::open(&cells_dir)?;
    let valid = match grid_path {
        Some(path) => {
            let grid = dsd::sweep::SweepGrid::from_yaml_file(path)?;
            let mut cells = grid.expand()?;
            if let Some(f) = filter {
                let pairs = dsd::sweep::parse_filter(f)?;
                cells = dsd::sweep::filter_cells(cells, &pairs)?;
            }
            let mut keys = std::collections::HashSet::new();
            for cell in cells {
                keys.insert(dsd::sweep::cell_key(&cell.cfg, false));
                keys.insert(dsd::sweep::cell_key(&cell.cfg, true));
            }
            Some(keys)
        }
        None => None,
    };
    let stats = cache.gc(valid.as_ref());
    log_info!("[sweep] gc {}: {}", cells_dir.display(), stats.describe());
    if stats.failed > 0 {
        return Err(format!("gc: {} files could not be removed", stats.failed));
    }
    Ok(())
}

/// `dsd sweep --merge d1,d2,... [--out f] [--out-dir d] [--table]`:
/// splice shard run directories into the single-process summary. All
/// validation (grid-hash agreement, overlap/missing shards, cell
/// completeness) lives in [`dsd::sweep::merge_shard_dirs`].
fn cmd_sweep_merge(
    dirs_arg: &str,
    out: Option<&str>,
    out_dir: Option<&str>,
    table: bool,
) -> Result<(), String> {
    let dirs: Vec<std::path::PathBuf> = dirs_arg
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(std::path::PathBuf::from)
        .collect();
    if dirs.is_empty() {
        return Err("merge: no shard directories given".into());
    }
    let report = dsd::sweep::merge_shard_dirs(&dirs)?;
    log_info!(
        "[sweep] merged {} shards (grid {}): {}",
        report.shard_count,
        report.grid_hash,
        report.stats.describe()
    );
    let summary = &report.summary;
    let json = summary.to_json().to_string_pretty();
    let write_to = |path: &std::path::Path| -> Result<(), String> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
            }
        }
        std::fs::write(path, format!("{json}\n")).map_err(|e| e.to_string())?;
        log_info!("[sweep] wrote {}", path.display());
        Ok(())
    };
    if let Some(path) = out {
        write_to(std::path::Path::new(path))?;
    }
    // The merged summary lands like a single-process run's would:
    // in --out-dir when given, or — when all shards shared one run
    // directory — beside their cells. Per-shard directories without
    // --out-dir print only (no directory is "the" run dir).
    match (out_dir, dirs.len()) {
        (Some(d), _) => {
            let dir = std::path::PathBuf::from(d);
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("create {}: {e}", dir.display()))?;
            write_to(&dir.join("summary.json"))?;
        }
        (None, 1) => write_to(&dirs[0].join("summary.json"))?,
        (None, _) => {}
    }
    if table {
        println!("{}", summary.render_table());
    } else {
        println!("{json}");
    }
    if summary.n_failed() > 0 {
        return Err(format!(
            "{} of {} merged cells failed",
            summary.n_failed(),
            summary.cells.len()
        ));
    }
    Ok(())
}

fn cmd_reproduce(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("reproduce", "regenerate a paper table/figure")
        .opt(
            "exp",
            "fig4|fig5|fig6|fig7|fig9|table2|agility|elasticity|fairness|pipeline|all",
            Some("all"),
        )
        .opt("scale", "request-count scale factor (1.0 = paper)", Some("1.0"))
        .opt("seeds", "number of seeds to average", Some("3"))
        .opt(
            "cache-dir",
            "sweep cell-cache directory: every runner-backed figure persists cells \
             under <dir>/<exp> and a re-run (or kill-and-resume) executes only misses",
            None,
        )
        .opt("threads", "worker threads (0 = one per core, capped at 8)", Some("0"))
        .flag(
            "streaming",
            "bounded-memory streaming metrics per cell (1M+ request scales; \
             throughput is the naive completions/duration ratio)",
        );
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let scale = Scale(a.get_f64("scale").map_err(|e| e.to_string())?.unwrap_or(1.0));
    let n_seeds = a.get_u64("seeds").map_err(|e| e.to_string())?.unwrap_or(3);
    let seeds: Vec<u64> = (1..=n_seeds).collect();
    let cache_dir = a.get("cache-dir").map(std::path::PathBuf::from);
    let opts = dsd::experiments::RunOptions {
        threads: a.get_usize("threads").map_err(|e| e.to_string())?.unwrap(),
        streaming: a.flag("streaming"),
    };
    let out = dsd::experiments::run_experiment_opts(
        a.get("exp").unwrap_or("all"),
        scale,
        &seeds,
        cache_dir.as_deref(),
        opts,
    )?;
    println!("{out}");
    Ok(())
}

fn cmd_sweep_dataset(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("sweep-dataset", "generate the AWC training dataset")
        .opt("out", "output JSONL path", Some("data/awc_sweep.jsonl"))
        .opt("threads", "worker threads (0 = one per core)", Some("0"))
        .opt(
            "cache-dir",
            "cell-cache directory: probe runs persist as they finish and a \
             re-invocation resumes from them",
            None,
        )
        .flag("tiny", "reduced grid (tests)");
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let grid = if a.flag("tiny") {
        dsd::awc::SweepGrid::tiny()
    } else {
        dsd::awc::SweepGrid::default()
    };
    let mut threads = a.get_usize("threads").map_err(|e| e.to_string())?.unwrap();
    if threads == 0 {
        threads = dsd::sweep::default_threads();
    }
    let cache = match a.get("cache-dir") {
        Some(dir) => Some(dsd::sweep::CellCache::open(std::path::Path::new(dir))?),
        None => None,
    };
    log_info!(
        "[sweep] {} scenarios x {} probes ...",
        grid.n_scenarios(),
        grid.gammas.len() + 1
    );
    let (rows, stats) = dsd::awc::generate_dataset_cached(&grid, cache.as_ref(), threads);
    if cache.is_some() {
        log_info!("[sweep] {}", stats.describe());
    }
    let path = std::path::Path::new(a.get("out").unwrap());
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    }
    dsd::awc::dataset::write_jsonl(&rows, path).map_err(|e| e.to_string())?;
    println!("wrote {} rows to {}", rows.len(), path.display());
    Ok(())
}

fn cmd_trace_gen(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("trace-gen", "emit a synthetic workload trace")
        .opt("dataset", "gsm8k|cnndm|humaneval", Some("gsm8k"))
        .opt("requests", "number of requests", Some("400"))
        .opt("rate", "arrival rate, req/s", Some("30"))
        .opt("drafters", "drafter pool size", Some("600"))
        .opt("seed", "rng seed", Some("42"))
        .opt("out", "output JSONL path", None);
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let ds = dsd::trace::dataset_by_name(a.get("dataset").unwrap())
        .ok_or("unknown dataset")?;
    let trace = ds.generate(
        a.get_usize("requests").map_err(|e| e.to_string())?.unwrap(),
        a.get_f64("rate").map_err(|e| e.to_string())?.unwrap(),
        a.get_usize("drafters").map_err(|e| e.to_string())?.unwrap(),
        a.get_u64("seed").map_err(|e| e.to_string())?.unwrap(),
    );
    let out = a.require("out").map_err(|e| e.to_string())?;
    dsd::trace::io::write_jsonl(&trace, std::path::Path::new(out))
        .map_err(|e| e.to_string())?;
    println!(
        "wrote {} records (mean prompt {:.0}, mean output {:.0}, acceptance {:.2})",
        trace.len(),
        trace.mean_prompt(),
        trace.mean_output(),
        trace.mean_acceptance()
    );
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<(), String> {
    // Two serving paths share the subcommand: the original AOT/PJRT
    // edge-cloud path (default), and the long-lived grid service
    // selected by --listen. Dispatch on the flag's presence so every
    // historical `dsd serve` invocation behaves exactly as before.
    if rest
        .iter()
        .any(|a| a == "--listen" || a.starts_with("--listen="))
    {
        return cmd_serve_grid(rest);
    }
    let spec = Command::new("serve", "real edge-cloud serving on AOT artifacts")
        .opt("artifacts", "artifacts directory", Some("artifacts"))
        .opt("requests", "number of requests", Some("8"))
        .opt("tokens", "output tokens per request", Some("32"))
        .opt("drafters", "edge worker threads", Some("4"))
        .opt("verifiers", "cloud worker threads", Some("2"))
        .opt("rtt", "emulated RTT, ms", Some("10"))
        .opt("window", "static:<g> | awc | fused", Some("static:4"))
        .opt("dataset", "prompt family: gsm8k|cnndm|humaneval", Some("gsm8k"));
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let window = parse_serve_window(a.get("window").unwrap())?;
    let cfg = ServeConfig {
        n_drafters: a.get_usize("drafters").map_err(|e| e.to_string())?.unwrap(),
        n_verifiers: a.get_usize("verifiers").map_err(|e| e.to_string())?.unwrap(),
        rtt_ms: a.get_f64("rtt").map_err(|e| e.to_string())?.unwrap(),
        window,
        max_new_tokens: a.get_usize("tokens").map_err(|e| e.to_string())?.unwrap(),
    };
    let n = a.get_usize("requests").map_err(|e| e.to_string())?.unwrap();
    let requests = demo_prompts(a.get("dataset").unwrap(), n, cfg.max_new_tokens);
    let co = Coordinator::new(std::path::Path::new(a.get("artifacts").unwrap()), cfg)
        .map_err(|e| e.to_string())?;
    let (rs, stats) = co.serve(requests).map_err(|e| e.to_string())?;
    for r in rs.iter().take(3) {
        println!(
            "req {}: acc={:.2} rounds={} tpot={:.0}ms | {:?}",
            r.id,
            r.acceptance(),
            r.rounds,
            r.tpot_ms,
            String::from_utf8_lossy(&r.output)
        );
    }
    println!(
        "completed={} tput={:.2} req/s tokens/s={:.1} ttft={:.0}ms tpot={:.0}ms acc={:.2}",
        stats.completed,
        stats.throughput_rps,
        stats.token_throughput,
        stats.mean_ttft_ms,
        stats.mean_tpot_ms,
        stats.mean_acceptance
    );
    Ok(())
}

/// `dsd serve --listen <addr>`: the long-running grid service
/// (submit-grid / poll-progress / fetch-summary / cancel / shutdown
/// over line-delimited JSON — see `dsd::serve::protocol`).
fn cmd_serve_grid(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("serve", "long-running sweep grid service")
        .opt(
            "listen",
            "address to bind (port 0 picks a free port)",
            Some("127.0.0.1:7433"),
        )
        .opt(
            "cache-dir",
            "run directory backing execution: cells persist under <dir>/cells, so \
             repeat submissions (and externally sharded runs of the same grid) are \
             served from disk",
            None,
        )
        .opt("threads", "worker threads per job (0 = one per core)", Some("0"))
        .opt(
            "max-jobs",
            "bound on live (queued + running) jobs; submissions beyond it get a \
             queue-full backpressure error",
            Some("16"),
        )
        .opt(
            "max-request-bytes",
            "cap on one request line, bytes (oversized lines are rejected while \
             reading, never buffered)",
            Some("4194304"),
        )
        .opt("timeout-ms", "per-socket read/write timeout, ms", Some("30000"))
        .opt(
            "log-level",
            "stderr log threshold: error|warn|info|debug (overrides DSD_LOG)",
            None,
        );
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    dsd::obs::log::set_level_str(a.get("log-level").unwrap_or(""))?;
    let opts = dsd::serve::ServeOptions {
        threads: a.get_usize("threads").map_err(|e| e.to_string())?.unwrap(),
        cache_dir: a.get("cache-dir").map(std::path::PathBuf::from),
        max_jobs: a.get_usize("max-jobs").map_err(|e| e.to_string())?.unwrap(),
        max_request_bytes: a
            .get_usize("max-request-bytes")
            .map_err(|e| e.to_string())?
            .unwrap(),
        request_timeout_ms: a.get_u64("timeout-ms").map_err(|e| e.to_string())?.unwrap(),
    };
    let service = dsd::serve::GridService::start(a.get("listen").unwrap(), opts)?;
    // The banner stays on raw stderr: scripts (and the CI smoke step)
    // scrape the bound address from it regardless of log level.
    eprintln!(
        "[serve] grid service listening on {} (protocol v{}; shut down with \
         `dsd submit --addr {} --shutdown`)",
        service.addr(),
        dsd::serve::PROTOCOL_VERSION,
        service.addr()
    );
    service.join();
    log_info!("[serve] drained; exiting");
    Ok(())
}

/// `dsd submit`: client for a `dsd serve --listen` grid service.
fn cmd_submit(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("submit", "client for a --listen grid service")
        .opt("addr", "service address", Some("127.0.0.1:7433"))
        .opt("grid", "sweep grid YAML file to submit", None)
        .opt("job", "job id for --status/--fetch/--cancel", None)
        .opt("out", "write the fetched summary to this path instead of stdout", None)
        .opt("poll-ms", "poll interval while waiting", Some("500"))
        .opt("wait-ms", "give up waiting after this long", Some("600000"))
        .opt("timeout-ms", "per-request socket timeout, ms", Some("30000"))
        .opt(
            "log-level",
            "stderr log threshold: error|warn|info|debug (overrides DSD_LOG)",
            None,
        )
        .flag("streaming", "force streaming metrics regardless of the grid file")
        .flag("no-wait", "submit and print the job id without waiting")
        .flag("status", "poll one job (--job) and print its progress")
        .flag("fetch", "fetch the summary of a completed job (--job)")
        .flag("cancel", "cancel a job (--job)")
        .flag(
            "stats",
            "fetch the service's live introspection snapshot (metrics registry + \
             per-job phase timings) as pretty JSON",
        )
        .flag("shutdown", "ask the service to drain and exit")
        .flag("ping", "liveness probe");
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    dsd::obs::log::set_level_str(a.get("log-level").unwrap_or(""))?;
    let addr = a.get("addr").unwrap();
    let timeout_ms = a.get_u64("timeout-ms").map_err(|e| e.to_string())?.unwrap();
    let mut client = dsd::serve::GridClient::connect(addr, timeout_ms)?;
    let job_arg = || -> Result<u64, String> {
        a.get_u64("job")
            .map_err(|e| e.to_string())?
            .ok_or_else(|| "submit: this action needs --job <id>".into())
    };
    let print_summary = |text: &str| -> Result<(), String> {
        match a.get("out") {
            Some(path) => {
                let p = std::path::Path::new(path);
                if let Some(dir) = p.parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
                    }
                }
                // File form matches `dsd sweep --out` byte-for-byte:
                // exact summary text plus one trailing newline.
                std::fs::write(p, format!("{text}\n")).map_err(|e| e.to_string())?;
                log_info!("[submit] wrote {}", p.display());
            }
            None => println!("{text}"),
        }
        Ok(())
    };
    if a.flag("ping") {
        client.ping()?;
        println!("ok");
        return Ok(());
    }
    if a.flag("stats") {
        let snapshot = client.fetch_stats()?;
        println!("{}", snapshot.to_string_pretty());
        return Ok(());
    }
    if a.flag("shutdown") {
        client.shutdown_server()?;
        println!("draining");
        return Ok(());
    }
    if a.flag("status") {
        let (state, done, total, failed) = client.poll(job_arg()?)?;
        println!("{} {done}/{total} ({failed} failed)", state.label());
        return Ok(());
    }
    if a.flag("cancel") {
        let id = job_arg()?;
        client.cancel(id)?;
        println!("cancelled job {id}");
        return Ok(());
    }
    if a.flag("fetch") {
        let text = client.fetch_summary(job_arg()?)?;
        return print_summary(&text);
    }
    // Default flow: submit a grid, wait for completion, fetch.
    let grid_path = a
        .get("grid")
        .ok_or("submit: pass --grid <grid.yaml> (or one of --status/--fetch/--cancel/--shutdown/--ping)")?;
    let grid_yaml = std::fs::read_to_string(grid_path)
        .map_err(|e| format!("read {grid_path}: {e}"))?;
    let streaming = if a.flag("streaming") { Some(true) } else { None };
    let id = client.submit_grid_text(&grid_yaml, streaming)?;
    log_info!("[submit] job {id} accepted by {addr}");
    if a.flag("no-wait") {
        println!("{id}");
        return Ok(());
    }
    let poll_ms = a.get_u64("poll-ms").map_err(|e| e.to_string())?.unwrap();
    let wait_ms = a.get_u64("wait-ms").map_err(|e| e.to_string())?.unwrap();
    let (state, done, total, failed) = client.wait(id, poll_ms, wait_ms)?;
    match state {
        dsd::serve::JobState::Completed => {
            log_info!("[submit] job {id} completed: {done}/{total} cells ({failed} failed)");
            let text = client.fetch_summary(id)?;
            print_summary(&text)?;
            if failed > 0 {
                return Err(format!("{failed} of {total} cells failed"));
            }
            Ok(())
        }
        other => Err(format!("submit: job {id} ended {}", other.label())),
    }
}

fn cmd_awc_eval(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("awc-eval", "AWC vs baselines on one configuration")
        .opt("dataset", "gsm8k|cnndm|humaneval", Some("gsm8k"))
        .opt("drafters", "edge pool size", Some("600"))
        .opt("rtt", "RTT ms", Some("10"))
        .opt("scale", "request scale", Some("0.5"))
        .opt("seeds", "seeds to average", Some("3"));
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    let scale = Scale(a.get_f64("scale").map_err(|e| e.to_string())?.unwrap());
    let seeds: Vec<u64> =
        (1..=a.get_u64("seeds").map_err(|e| e.to_string())?.unwrap()).collect();
    use dsd::config::{BatchingKind, RoutingKind, WindowKind};
    use dsd::experiments::common::{mean_of, paper_config, run_seeds};
    let mut table = dsd::util::table::Table::new(&["policy", "tput", "ttft", "tpot"])
        .with_title("AWC vs baselines");
    for (name, w) in [
        ("static", WindowKind::Static(4)),
        ("dynamic", WindowKind::Dynamic { init: 4, lo: 0.25, hi: 0.75 }),
        ("awc", WindowKind::Awc { weights_path: None }),
    ] {
        let cfg = paper_config(
            a.get("dataset").unwrap(),
            a.get_usize("drafters").map_err(|e| e.to_string())?.unwrap(),
            a.get_f64("rtt").map_err(|e| e.to_string())?.unwrap(),
            RoutingKind::Jsq,
            BatchingKind::Lab,
            w,
            scale,
            seeds[0],
        );
        let reps = run_seeds(&cfg, &seeds);
        table.row(vec![
            name.into(),
            format!("{:.1}", mean_of(&reps, |r| r.system.throughput_rps)),
            format!("{:.0}", mean_of(&reps, |r| r.mean_ttft())),
            format!("{:.1}", mean_of(&reps, |r| r.mean_tpot())),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_bench(rest: &[String]) -> Result<(), String> {
    let spec = Command::new("bench", "run a benchmark suite, write BENCH_<suite>.json")
        .opt("suite", "suite name (see --list)", Some("hotpath"))
        .opt(
            "out-dir",
            "directory for BENCH_<suite>.json (default: the repository root)",
            None,
        )
        .flag(
            "quick",
            "smoke-test tier: tiny iteration counts and workloads; the emitted \
             JSON is tagged tier=quick and is not a trajectory point",
        )
        .flag("list", "list available suites and exit");
    let a = spec.parse(rest).map_err(|e| e.to_string())?;
    if a.flag("list") {
        for name in dsd::bench::suite_names() {
            println!("{name}");
        }
        return Ok(());
    }
    let tier = if a.flag("quick") {
        dsd::bench::Tier::Quick
    } else {
        dsd::bench::Tier::Full
    };
    let out_dir = match a.get("out-dir") {
        Some(d) => {
            let dir = std::path::PathBuf::from(d);
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("create {}: {e}", dir.display()))?;
            dir
        }
        None => dsd::bench::default_out_dir(),
    };
    let report = dsd::bench::run_suite(a.get("suite").unwrap(), tier)?;
    let path = report.write_to(&out_dir)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn parse_serve_window(s: &str) -> Result<ServeWindow, String> {
    if let Some(g) = s.strip_prefix("static:") {
        return Ok(ServeWindow::Static(
            g.parse().map_err(|_| format!("bad gamma '{g}'"))?,
        ));
    }
    match s {
        "awc" => Ok(ServeWindow::Awc),
        "fused" => Ok(ServeWindow::FusedOnly),
        other => Err(format!("unknown window '{other}'")),
    }
}

/// Prompts shaped like the three benchmark families (mirrors
/// `python/compile/corpus.py::sample_prompts`).
fn demo_prompts(dataset: &str, n: usize, max_new: usize) -> Vec<ServeRequest> {
    (0..n)
        .map(|i| {
            let a = 3 + (i * 11) % 50;
            let b = 2 + (i * 3) % 30;
            let prompt = match dataset {
                "cnndm" => format!(
                    "article: the city council voted on tuesday to approve the new transit plan. \
                     officials said the project will add {a} miles of track and create {b} jobs over the next decade.\nsummary:"
                ),
                "humaneval" => "def add(a, b):\n".to_string(),
                _ => format!(
                    "question: tom has {a} apples and buys {b} more. how many apples does tom have?\nanswer:"
                ),
            };
            ServeRequest {
                id: i,
                prompt: prompt.into_bytes(),
                max_new_tokens: max_new,
            }
        })
        .collect()
}
