//! Typed wrappers over the AOT artifacts: the draft engine (edge side)
//! and the target engine (cloud side). Both are stateless — KV caches are
//! values owned by the caller, which is what lets the coordinator manage
//! residency, rollback, and migration explicitly.

use crate::runtime::exec::{Runtime, Tensor};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Greedy argmax over a logits slice.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Edge-side draft model.
pub struct DraftEngine {
    rt: Arc<Runtime>,
}

/// Cloud-side target model.
pub struct TargetEngine {
    rt: Arc<Runtime>,
}

/// An opaque KV cache value (runtime tensor).
pub type KvCache = Tensor;

impl DraftEngine {
    /// Bind to a runtime.
    pub fn new(rt: Arc<Runtime>) -> Self {
        DraftEngine { rt }
    }

    /// Prefill a prompt; returns (next-token logits, kv, prompt_len).
    pub fn prefill(&self, prompt: &[u8]) -> Result<(Vec<f32>, KvCache, usize)> {
        prefill_common(&self.rt, "draft_prefill", prompt)
    }

    /// One decode step; returns (logits, kv).
    pub fn decode(&self, token: i32, pos: usize, kv: KvCache) -> Result<(Vec<f32>, KvCache)> {
        let exe = self.rt.executable("draft_decode")?;
        let mut out = exe.call(&[
            Tensor::scalar_i32(token),
            Tensor::scalar_i32(pos as i32),
            kv,
        ])?;
        let kv = out.pop().ok_or_else(|| anyhow!("missing kv"))?;
        let logits = out
            .pop()
            .and_then(|t| t.as_f32().map(|s| s.to_vec()))
            .ok_or_else(|| anyhow!("missing logits"))?;
        Ok((logits, kv))
    }

    /// Draft `gamma` greedy tokens starting from `last_token` at `pos`.
    /// Returns (draft_tokens, kv) with the cache advanced by `gamma`.
    pub fn draft_window(
        &self,
        last_token: i32,
        pos: usize,
        gamma: u32,
        mut kv: KvCache,
    ) -> Result<(Vec<i32>, KvCache)> {
        let mut tokens = Vec::with_capacity(gamma as usize);
        let mut tok = last_token;
        let mut p = pos;
        for _ in 0..gamma {
            let (logits, new_kv) = self.decode(tok, p, kv)?;
            kv = new_kv;
            tok = argmax(&logits);
            tokens.push(tok);
            p += 1;
        }
        Ok((tokens, kv))
    }

    /// Re-sync the draft cache with corrected tokens (after a rejection,
    /// the accepted prefix + correction must be fed through the drafter so
    /// its cache matches the canonical sequence). Returns the cache
    /// advanced over `tokens` starting at `pos`.
    pub fn resync(&self, tokens: &[i32], pos: usize, mut kv: KvCache) -> Result<KvCache> {
        let mut p = pos;
        for &t in tokens {
            let (_, new_kv) = self.decode(t, p, kv)?;
            kv = new_kv;
            p += 1;
        }
        Ok(kv)
    }

    /// Max sequence length of the draft cache.
    pub fn max_len(&self) -> usize {
        self.rt.manifest().draft_max_len
    }
}

impl TargetEngine {
    /// Bind to a runtime.
    pub fn new(rt: Arc<Runtime>) -> Self {
        TargetEngine { rt }
    }

    /// Prefill a prompt; returns (next-token logits, kv, prompt_len).
    pub fn prefill(&self, prompt: &[u8]) -> Result<(Vec<f32>, KvCache, usize)> {
        prefill_common(&self.rt, "target_prefill", prompt)
    }

    /// One fused decode step (cloud-only generation).
    pub fn decode(&self, token: i32, pos: usize, kv: KvCache) -> Result<(Vec<f32>, KvCache)> {
        let exe = self.rt.executable("target_decode")?;
        let mut out = exe.call(&[
            Tensor::scalar_i32(token),
            Tensor::scalar_i32(pos as i32),
            kv,
        ])?;
        let kv = out.pop().ok_or_else(|| anyhow!("missing kv"))?;
        let logits = out
            .pop()
            .and_then(|t| t.as_f32().map(|s| s.to_vec()))
            .ok_or_else(|| anyhow!("missing logits"))?;
        Ok((logits, kv))
    }

    /// Verify a speculation window (paper Fig. 1(c) step 2-3).
    ///
    /// `window` = last accepted token followed by γ draft tokens, at
    /// absolute positions `[pos, pos+γ]`. Uses the pre-lowered verify
    /// artifact for the largest available γ' ≤ γ... the caller must pass a
    /// γ with an exact artifact (see [`crate::runtime::Manifest::nearest_verify_gamma`]).
    ///
    /// Returns `(accepted, next_token, kv)`: number of draft tokens
    /// accepted, the target's correction/bonus token, and the cache (valid
    /// through `pos + accepted`; later rows are stale and are overwritten
    /// by subsequent windows — position-based rollback).
    pub fn verify(
        &self,
        window: &[i32],
        pos: usize,
        kv: KvCache,
    ) -> Result<(u32, i32, KvCache)> {
        let gamma = window.len() - 1;
        let exe = self.rt.executable(&format!("target_verify_g{gamma}"))?;
        let mut out = exe.call(&[
            Tensor::vec_i32(window.to_vec()),
            Tensor::scalar_i32(pos as i32),
            kv,
        ])?;
        let kv = out.pop().ok_or_else(|| anyhow!("missing kv"))?;
        let logits_t = out.pop().ok_or_else(|| anyhow!("missing logits"))?;
        let logits = logits_t.as_f32().ok_or_else(|| anyhow!("logits dtype"))?;
        let vocab = self.rt.manifest().vocab;
        // Greedy acceptance fold (the L1 verify kernel's semantics;
        // asserted equivalent in python tests): row i scores position
        // pos+i+1, draft token i+1 of the window.
        let mut accepted = 0u32;
        for i in 0..gamma {
            let row = &logits[i * vocab..(i + 1) * vocab];
            if argmax(row) == window[i + 1] {
                accepted += 1;
            } else {
                break;
            }
        }
        let next_row = &logits[(accepted as usize) * vocab..(accepted as usize + 1) * vocab];
        Ok((accepted, argmax(next_row), kv))
    }

    /// Max sequence length of the target cache.
    pub fn max_len(&self) -> usize {
        self.rt.manifest().target_max_len
    }

    /// Available verify window sizes.
    pub fn nearest_gamma(&self, wanted: u32) -> u32 {
        self.rt.manifest().nearest_verify_gamma(wanted)
    }
}

fn prefill_common(
    rt: &Arc<Runtime>,
    key: &str,
    prompt: &[u8],
) -> Result<(Vec<f32>, KvCache, usize)> {
    let pad = rt.manifest().prompt_pad;
    if prompt.is_empty() || prompt.len() > pad {
        return Err(anyhow!(
            "prompt length {} out of range [1, {pad}]",
            prompt.len()
        ));
    }
    let mut tokens = vec![0i32; pad];
    for (i, &b) in prompt.iter().enumerate() {
        tokens[i] = b as i32;
    }
    let exe = rt.executable(key)?;
    let mut out = exe.call(&[
        Tensor::I32(tokens, vec![pad]),
        Tensor::scalar_i32(prompt.len() as i32),
    ])?;
    let kv = out.pop().ok_or_else(|| anyhow!("missing kv"))?;
    let logits = out
        .pop()
        .and_then(|t| t.as_f32().map(|s| s.to_vec()))
        .ok_or_else(|| anyhow!("missing logits"))?;
    Ok((logits, kv, prompt.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        // Ties resolve to the first maximum (matches jnp.argmax).
        assert_eq!(argmax(&[1.0, 1.0, 1.0]), 0);
    }
}
