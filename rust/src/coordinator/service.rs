//! The real edge–cloud serving path (paper Fig. 1(a)/(b)) on AOT
//! artifacts: edge drafter threads speculate with the draft model, cloud
//! verifier threads batch-verify with the target model, a channel pair
//! with injected delay plays the network.
//!
//! Python never runs here — every model call goes through PJRT-compiled
//! HLO. The speculation semantics (window verify, first-mismatch
//! correction, bonus token, position-based KV rollback) are exactly those
//! of [`crate::specdec`], now against *real* logits rather than trace
//! bits.

use super::api::{ServeRequest, ServeResponse, ServeStats};
use super::engine::{argmax, DraftEngine, KvCache, TargetEngine};
use crate::awc::{AwcPolicy, AwcWeights};
use crate::policies::window::{ExecMode, WindowFeatures, WindowPolicy};
use crate::runtime::exec::Runtime;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Window policy selector for the real path.
#[derive(Clone, Debug)]
pub enum ServeWindow {
    /// Fixed γ.
    Static(u32),
    /// AWC with the embedded pretrained weights.
    Awc,
    /// Cloud-only decoding (no speculation) — the fused baseline.
    FusedOnly,
}

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Edge drafter worker threads.
    pub n_drafters: usize,
    /// Cloud verifier worker threads.
    pub n_verifiers: usize,
    /// Emulated edge–cloud RTT, ms (sleep-injected, half per direction).
    pub rtt_ms: f64,
    /// Window policy.
    pub window: ServeWindow,
    /// Max output tokens per request (bounded by cache capacity).
    pub max_new_tokens: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            n_drafters: 4,
            n_verifiers: 2,
            rtt_ms: 10.0,
            window: ServeWindow::Static(4),
            max_new_tokens: 64,
        }
    }
}

/// Jobs sent from edge workers to the cloud.
enum CloudJob {
    Prefill {
        prompt: Vec<u8>,
        reply: mpsc::Sender<Result<(Vec<f32>, KvCache, usize)>>,
    },
    Verify {
        window: Vec<i32>,
        pos: usize,
        kv: KvCache,
        reply: mpsc::Sender<Result<(u32, i32, KvCache)>>,
    },
    Decode {
        token: i32,
        pos: usize,
        kv: KvCache,
        reply: mpsc::Sender<Result<(Vec<f32>, KvCache)>>,
    },
}

/// The coordinator: artifact location + thread topology.
///
/// PJRT clients are **per worker thread** (the `xla` crate's client is not
/// `Send`); this also mirrors the paper's deployment — every edge device
/// and every cloud server owns its own model runtime.
pub struct Coordinator {
    artifacts_dir: std::path::PathBuf,
    cfg: ServeConfig,
}

impl Coordinator {
    /// Validate the artifacts and build the coordinator.
    pub fn new(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> Result<Coordinator> {
        // Fail fast on a missing/inconsistent manifest.
        let _ = crate::runtime::Manifest::load(artifacts_dir)
            .map_err(anyhow::Error::msg)?;
        Ok(Coordinator {
            artifacts_dir: artifacts_dir.to_path_buf(),
            cfg,
        })
    }

    /// Serve a batch of requests through the full edge–cloud topology;
    /// blocks until every request completes.
    ///
    /// Workers warm (parse + PJRT-compile) their role's artifacts before
    /// the serving clock starts — a barrier separates deployment cost
    /// from serving latency, exactly as a real launch would.
    pub fn serve(&self, requests: Vec<ServeRequest>) -> Result<(Vec<ServeResponse>, ServeStats)> {
        let n_workers = self.cfg.n_drafters.max(1) + self.cfg.n_verifiers.max(1);
        let ready = Arc::new(std::sync::Barrier::new(n_workers + 1));
        let queue = Arc::new(Mutex::new(VecDeque::from(requests)));
        let results = Arc::new(Mutex::new(Vec::<ServeResponse>::new()));
        let (job_tx, job_rx) = mpsc::channel::<CloudJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let inflight = Arc::new(AtomicUsize::new(0));

        // ---- Cloud pool: verifier workers (one PJRT client each) ----
        let mut cloud_handles = Vec::new();
        for _ in 0..self.cfg.n_verifiers.max(1) {
            let rx = job_rx.clone();
            let dir = self.artifacts_dir.clone();
            let inflight = inflight.clone();
            let ready = ready.clone();
            cloud_handles.push(std::thread::spawn(move || {
                let rt = Arc::new(Runtime::load(&dir).expect("cloud runtime"));
                rt.warmup_prefix("target_").expect("cloud warmup");
                ready.wait();
                let target = TargetEngine::new(rt);
                loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(job) = job else { break };
                    match job {
                        CloudJob::Prefill { prompt, reply } => {
                            let _ = reply.send(target.prefill(&prompt));
                        }
                        CloudJob::Verify { window, pos, kv, reply } => {
                            let _ = reply.send(target.verify(&window, pos, kv));
                        }
                        CloudJob::Decode { token, pos, kv, reply } => {
                            let _ = reply.send(target.decode(token, pos, kv));
                        }
                    }
                    inflight.fetch_sub(1, Ordering::Relaxed);
                }
            }));
        }

        // ---- Edge pool: drafter workers (one PJRT client each) ----
        let mut edge_handles = Vec::new();
        for worker in 0..self.cfg.n_drafters.max(1) {
            let queue = queue.clone();
            let results = results.clone();
            let job_tx = job_tx.clone();
            let dir = self.artifacts_dir.clone();
            let cfg = self.cfg.clone();
            let inflight = inflight.clone();
            let ready = ready.clone();
            edge_handles.push(std::thread::spawn(move || {
                let rt = Arc::new(Runtime::load(&dir).expect("edge runtime"));
                rt.warmup_prefix("draft_").expect("edge warmup");
                ready.wait();
                let draft = DraftEngine::new(rt.clone());
                let target_meta = TargetEngine::new(rt);
                let mut awc = AwcPolicy::new(AwcWeights::builtin());
                loop {
                    let req = queue.lock().unwrap().pop_front();
                    let Some(req) = req else { break };
                    match serve_one(
                        &cfg, &draft, &target_meta, &job_tx, &inflight, &mut awc, req, worker,
                    ) {
                        Ok(resp) => results.lock().unwrap().push(resp),
                        Err(e) => eprintln!("[coordinator] request failed: {e:#}"),
                    }
                }
            }));
        }
        drop(job_tx);

        // Serving clock starts once every worker has compiled its models.
        ready.wait();
        let t0 = Instant::now();

        for h in edge_handles {
            h.join().expect("edge worker panicked");
        }
        // Edge workers dropped their senders; cloud workers drain and exit.
        for h in cloud_handles {
            h.join().expect("cloud worker panicked");
        }

        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut rs = Arc::try_unwrap(results)
            .expect("no outstanding refs")
            .into_inner()
            .unwrap();
        rs.sort_by_key(|r| r.id);
        let stats = ServeStats::from_responses(&rs, wall_ms);
        Ok((rs, stats))
    }
}

/// Half-RTT network delay injection.
fn net_leg(rtt_ms: f64) {
    if rtt_ms > 0.0 {
        std::thread::sleep(std::time::Duration::from_micros((rtt_ms * 500.0) as u64));
    }
}

/// Run one request's full speculative-decoding lifecycle from its edge
/// drafter: prefill both sides, then window-decide / draft / ship /
/// verify / correct until done.
#[allow(clippy::too_many_arguments)]
fn serve_one(
    cfg: &ServeConfig,
    draft: &DraftEngine,
    target_meta: &TargetEngine,
    job_tx: &mpsc::Sender<CloudJob>,
    inflight: &Arc<AtomicUsize>,
    awc: &mut AwcPolicy,
    req: ServeRequest,
    worker: usize,
) -> Result<ServeResponse> {
    let t0 = Instant::now();
    let max_new = req.max_new_tokens.min(cfg.max_new_tokens);

    // --- Target prefill (prompt travels to the cloud) ---
    let (tx, rx) = mpsc::channel();
    net_leg(cfg.rtt_ms);
    inflight.fetch_add(1, Ordering::Relaxed);
    job_tx
        .send(CloudJob::Prefill { prompt: req.prompt.clone(), reply: tx })
        .ok();
    // --- Edge prefill happens concurrently on this thread ---
    let fused_only = matches!(cfg.window, ServeWindow::FusedOnly);
    let mut draft_state = if fused_only {
        None
    } else {
        let (_logits, kv, _len) = draft.prefill(&req.prompt)?;
        Some(kv)
    };
    let (t_logits, mut t_kv, prompt_len) = rx.recv().expect("cloud prefill reply")?;
    net_leg(cfg.rtt_ms);

    // First token comes from the target's prefill logits.
    let first_token = argmax(&t_logits);
    let ttft_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut output: Vec<i32> = vec![first_token];
    let mut target_pos = prompt_len; // rows written in the target cache
    let mut draft_pos = prompt_len; // rows written in the draft cache
    let mut last_token = first_token;
    let mut drafted = 0u32;
    let mut accepted_total = 0u32;
    let mut rounds = 0u32;
    let mut gamma_sum = 0u64;
    let mut acc_ema = crate::util::stats::Ema::new(0.3);
    let mut rtt_ema = crate::util::stats::Ema::new(0.3);
    let mut tpot_ema = crate::util::stats::Ema::new(0.3);

    let cache_limit = target_meta.max_len().min(draft.max_len());
    let pair_key = (worker as u64) << 32 | req.id as u64;

    while output.len() < max_new {
        let remaining = (max_new - output.len()) as u32;
        // Window decision (AWC features measured from live signals).
        let decision = match &cfg.window {
            ServeWindow::Static(g) => crate::policies::window::WindowDecision {
                gamma: *g,
                mode: ExecMode::Distributed,
            },
            ServeWindow::FusedOnly => crate::policies::window::WindowDecision {
                gamma: 1,
                mode: ExecMode::Fused,
            },
            ServeWindow::Awc => {
                let feats = WindowFeatures {
                    queue_depth_util: inflight.load(Ordering::Relaxed) as f64
                        / cfg.n_verifiers.max(1) as f64,
                    acceptance_recent: acc_ema.value_or(0.7),
                    rtt_recent_ms: rtt_ema.value_or(cfg.rtt_ms),
                    tpot_recent_ms: tpot_ema.value_or(0.0),
                    gamma_prev: gamma_sum
                        .checked_div(rounds as u64)
                        .unwrap_or(4)
                        .max(1) as u32,
                };
                awc.decide(pair_key, &feats)
            }
        };

        let round_start = Instant::now();
        if decision.mode == ExecMode::Fused || draft_state.is_none() {
            // Fused: the cloud decodes directly (no per-token network).
            let (tx, rx) = mpsc::channel();
            inflight.fetch_add(1, Ordering::Relaxed);
            job_tx
                .send(CloudJob::Decode { token: last_token, pos: target_pos, kv: t_kv, reply: tx })
                .ok();
            let (logits, kv) = rx.recv().expect("cloud decode reply")?;
            t_kv = kv;
            target_pos += 1;
            last_token = argmax(&logits);
            output.push(last_token);
            // Keep the drafter's view consistent for later rounds.
            if let Some(kv) = draft_state.take() {
                draft_state = Some(draft.resync(&[output[output.len() - 2]], draft_pos, kv)?);
                draft_pos += 1;
            }
            rounds += 1;
            tpot_ema.push(round_start.elapsed().as_secs_f64() * 1e3);
            if target_pos + 2 >= cache_limit {
                break;
            }
            continue;
        }

        // Distributed round.
        let gamma_req = decision.gamma.min(remaining.max(1));
        let gamma = target_meta.nearest_gamma(gamma_req);
        // Cache capacity guard: window occupies [target_pos, target_pos+γ].
        if target_pos + gamma as usize + 2 >= cache_limit {
            break;
        }
        gamma_sum += gamma as u64;

        // 1. Draft γ tokens locally.
        let kv = draft_state.take().expect("draft cache");
        let (draft_tokens, kv) = draft.draft_window(last_token, draft_pos, gamma, kv)?;
        draft_pos += gamma as usize;
        draft_state = Some(kv);
        drafted += gamma;

        // 2. Ship to the cloud; 3. verify there; 4. result returns.
        let mut window = Vec::with_capacity(gamma as usize + 1);
        window.push(last_token);
        window.extend_from_slice(&draft_tokens);
        let net_start = Instant::now();
        net_leg(cfg.rtt_ms);
        let (tx, rx) = mpsc::channel();
        inflight.fetch_add(1, Ordering::Relaxed);
        job_tx
            .send(CloudJob::Verify { window, pos: target_pos, kv: t_kv, reply: tx })
            .ok();
        let (accepted, correction, kv) = rx.recv().expect("cloud verify reply")?;
        net_leg(cfg.rtt_ms);
        rtt_ema.push(net_start.elapsed().as_secs_f64() * 1e3);
        t_kv = kv;

        // 5. Advance the canonical sequence: accepted drafts + correction.
        for &t in draft_tokens.iter().take(accepted as usize) {
            output.push(t);
        }
        output.push(correction);
        accepted_total += accepted;
        acc_ema.push(accepted as f64 / gamma as f64);
        target_pos += accepted as usize + 1;
        rounds += 1;

        // 6. Drafter-side rollback/resync (position-based):
        //    all-accept leaves one canonical row (the last draft token)
        //    missing from the draft cache — feed it through.
        if accepted == gamma {
            let kv = draft_state.take().unwrap();
            let missing = draft_tokens[gamma as usize - 1];
            draft_state = Some(draft.resync(&[missing], draft_pos, kv)?);
            draft_pos += 1;
        } else {
            // Partial accept: roll the draft cursor back to the corrected
            // position; stale rows beyond it are masked (attention length
            // = position) and overwritten as decoding continues.
            draft_pos = target_pos;
        }
        last_token = correction;
        let produced = accepted + 1;
        tpot_ema.push(round_start.elapsed().as_secs_f64() * 1e3 / produced as f64);
    }

    // A window can overshoot the budget (accepted+1 tokens land at once);
    // clip to the requested length like any serving API would.
    output.truncate(max_new);
    let e2e_ms = t0.elapsed().as_secs_f64() * 1e3;
    let out_tokens = output.len();
    let tpot_ms = if out_tokens > 1 {
        (e2e_ms - ttft_ms) / (out_tokens - 1) as f64
    } else {
        0.0
    };
    Ok(ServeResponse {
        id: req.id,
        output: output
            .iter()
            .map(|&t| t.clamp(0, 255) as u8)
            .collect(),
        ttft_ms,
        e2e_ms,
        tpot_ms,
        drafted,
        accepted: accepted_total,
        rounds,
        mean_gamma: if rounds == 0 {
            0.0
        } else {
            gamma_sum as f64 / rounds as f64
        },
    })
}
