//! The real edge–cloud serving coordinator: PJRT-backed draft/target
//! engines, threaded drafter/verifier pools, emulated network links, and
//! genuine speculative decoding over the AOT artifacts.
//!
//! Greedy speculative decoding is *output-invariant*: the served sequence
//! equals the target model's own greedy decode — the integration tests
//! assert this against the fused baseline.

pub mod api;
pub mod engine;
pub mod service;

pub use api::{ServeRequest, ServeResponse, ServeStats};
pub use engine::{argmax, DraftEngine, TargetEngine};
pub use service::{Coordinator, ServeConfig, ServeWindow};
