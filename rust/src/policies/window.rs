//! Window-size policies (paper §3.4): Static γ, the Dynamic threshold
//! heuristic, and the fused-only baseline. The learned AWC policy lives in
//! [`crate::awc`] and implements the same [`WindowPolicy`] trait.

/// Execution mode for the next speculation iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Edge drafts γ tokens, cloud verifies (network round trip).
    Distributed,
    /// Cloud generates tokens directly; no speculation (γ ≤ 1 regime).
    Fused,
}

/// A window decision for one iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowDecision {
    /// Speculation window size (≥1; meaningful in distributed mode).
    pub gamma: u32,
    /// Fused vs distributed execution.
    pub mode: ExecMode,
}

/// The feature vector window policies observe — exactly the five inputs
/// of the WC-DNN (paper §4.1), assembled by the performance analyzer.
///
/// **Liveness invariant** (scenario engine): policies must read network
/// and load state from *this* vector on every `decide` call, never from
/// configuration captured at construction — scripted dynamics
/// ([`crate::scenario`]) change links and hardware mid-run, and the
/// simulator feeds those changes through here (measured EMAs once
/// telemetry flows; the *live* link as the cold-start fallback). The
/// built-in policies and AWC hold no config-derived constants; the
/// regression lock is `window_features_track_live_link_state` in
/// `tests/scenario_integration.rs`.
#[derive(Clone, Copy, Debug, Default)]
pub struct WindowFeatures {
    /// Queue-depth utilization of the routed target: occupancy relative
    /// to its decode batch capacity, in [0, ~2].
    pub queue_depth_util: f64,
    /// Recent token acceptance ratio for this draft–target pair.
    pub acceptance_recent: f64,
    /// Recent round-trip time on the link, ms.
    pub rtt_recent_ms: f64,
    /// Recent time-per-output-token on the target, ms.
    pub tpot_recent_ms: f64,
    /// Window size chosen in the previous iteration.
    pub gamma_prev: u32,
}

impl WindowFeatures {
    /// Flatten to the WC-DNN input layout `[q_depth, α, RTT, TPOT, γ_prev]`.
    pub fn to_vec(&self) -> [f64; 5] {
        [
            self.queue_depth_util,
            self.acceptance_recent,
            self.rtt_recent_ms,
            self.tpot_recent_ms,
            self.gamma_prev as f64,
        ]
    }
}

/// Per-connection window policy. The simulator keeps one policy instance
/// per simulation; `pair_key` identifies the (drafter, target) connection
/// so stateful policies (AWC's EMA/hysteresis) track each link separately
/// (paper §4.4: "smoothing state is maintained per draft-target pair").
pub trait WindowPolicy: Send {
    /// Decide γ and mode for the next iteration of `pair_key`.
    fn decide(&mut self, pair_key: u64, features: &WindowFeatures) -> WindowDecision;
    /// Forget a connection's state (request completed).
    fn forget(&mut self, _pair_key: u64) {}
    /// Policy name for reports.
    fn name(&self) -> &'static str;
}

/// Fixed window size (the paper's Static baseline, γ = 4 in §5.2).
pub struct StaticWindow(pub u32);

impl WindowPolicy for StaticWindow {
    fn decide(&mut self, _pair: u64, _f: &WindowFeatures) -> WindowDecision {
        WindowDecision {
            gamma: self.0.max(1),
            mode: ExecMode::Distributed,
        }
    }
    fn name(&self) -> &'static str {
        "static"
    }
}

/// Threshold heuristic (the paper's Dynamic baseline, §5.2): increment γ
/// when recent acceptance exceeds `hi` (0.75), decrement when it falls
/// below `lo` (0.25); clamped to [1, 12].
pub struct DynamicWindow {
    init: u32,
    lo: f64,
    hi: f64,
    /// Clamp range for the heuristic's walk. Tighter than AWC's [1, 12]:
    /// with a high-acceptance workload the threshold rule ratchets upward
    /// (crossing `hi` is far more likely than crossing `lo`), and an
    /// unbounded walk parks γ at the ceiling where drafting cost eats the
    /// speedup. [2, 8] is the operational clamp.
    min: u32,
    max: u32,
    state: std::collections::HashMap<u64, u32>,
}

impl DynamicWindow {
    /// New heuristic with thresholds (`lo`, `hi`) and initial γ.
    pub fn new(init: u32, lo: f64, hi: f64) -> Self {
        DynamicWindow {
            init: init.clamp(2, 6),
            lo,
            hi,
            min: 2,
            max: 6,
            state: std::collections::HashMap::new(),
        }
    }

    /// Override the clamp range.
    pub fn with_range(mut self, min: u32, max: u32) -> Self {
        self.min = min.max(1);
        self.max = max.min(12);
        self.init = self.init.clamp(self.min, self.max);
        self
    }
}

impl WindowPolicy for DynamicWindow {
    fn decide(&mut self, pair: u64, f: &WindowFeatures) -> WindowDecision {
        let g = self.state.entry(pair).or_insert(self.init);
        if f.acceptance_recent > self.hi {
            *g = (*g + 1).min(self.max);
        } else if f.acceptance_recent < self.lo {
            *g = g.saturating_sub(1).max(self.min);
        }
        WindowDecision {
            gamma: *g,
            mode: ExecMode::Distributed,
        }
    }
    fn forget(&mut self, pair: u64) {
        self.state.remove(&pair);
    }
    fn name(&self) -> &'static str {
        "dynamic"
    }
}

/// Cloud-only baseline: always fused (Fig. 6's green series).
pub struct FusedOnly;

impl WindowPolicy for FusedOnly {
    fn decide(&mut self, _pair: u64, _f: &WindowFeatures) -> WindowDecision {
        WindowDecision {
            gamma: 1,
            mode: ExecMode::Fused,
        }
    }
    fn name(&self) -> &'static str {
        "fused"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feat(acc: f64) -> WindowFeatures {
        WindowFeatures {
            acceptance_recent: acc,
            ..Default::default()
        }
    }

    #[test]
    fn static_is_constant() {
        let mut p = StaticWindow(4);
        for acc in [0.0, 0.5, 1.0] {
            let d = p.decide(0, &feat(acc));
            assert_eq!(d.gamma, 4);
            assert_eq!(d.mode, ExecMode::Distributed);
        }
    }

    #[test]
    fn dynamic_tracks_acceptance() {
        let mut p = DynamicWindow::new(4, 0.25, 0.75).with_range(1, 12);
        // High acceptance grows the window...
        for _ in 0..5 {
            p.decide(1, &feat(0.9));
        }
        assert_eq!(p.decide(1, &feat(0.9)).gamma, 10);
        // ...low acceptance shrinks it...
        for _ in 0..20 {
            p.decide(1, &feat(0.1));
        }
        assert_eq!(p.decide(1, &feat(0.1)).gamma, 1);
        // ...mid-band holds steady.
        assert_eq!(p.decide(1, &feat(0.5)).gamma, 1);
    }

    #[test]
    fn dynamic_clamps_to_range() {
        let mut p = DynamicWindow::new(11, 0.25, 0.75).with_range(1, 12);
        for _ in 0..10 {
            p.decide(2, &feat(1.0));
        }
        assert_eq!(p.decide(2, &feat(1.0)).gamma, 12);
        // Default operational clamp is [2, 6].
        let mut q = DynamicWindow::new(4, 0.25, 0.75);
        for _ in 0..10 {
            q.decide(3, &feat(1.0));
        }
        assert_eq!(q.decide(3, &feat(1.0)).gamma, 6);
        for _ in 0..10 {
            q.decide(3, &feat(0.0));
        }
        assert_eq!(q.decide(3, &feat(0.0)).gamma, 2);
    }

    #[test]
    fn dynamic_state_is_per_pair() {
        let mut p = DynamicWindow::new(4, 0.25, 0.75);
        p.decide(1, &feat(0.9)); // pair 1 grows
        assert_eq!(p.decide(2, &feat(0.5)).gamma, 4, "pair 2 untouched");
        p.forget(1);
        assert_eq!(p.decide(1, &feat(0.5)).gamma, 4, "pair 1 reset");
    }

    #[test]
    fn fused_only_always_fused() {
        let mut p = FusedOnly;
        assert_eq!(p.decide(0, &feat(1.0)).mode, ExecMode::Fused);
    }

    #[test]
    fn feature_layout_matches_wcdnn_order() {
        let f = WindowFeatures {
            queue_depth_util: 0.5,
            acceptance_recent: 0.8,
            rtt_recent_ms: 10.0,
            tpot_recent_ms: 40.0,
            gamma_prev: 4,
        };
        assert_eq!(f.to_vec(), [0.5, 0.8, 10.0, 40.0, 4.0]);
    }
}
