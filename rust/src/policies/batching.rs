//! Batching policies (paper §3.4): FIFO dispatch vs Length-Aware Batching
//! (LAB) — the head-of-line request grouped with requests of similar
//! length to minimize padding (the ORCA/Sarathi-style baseline of §5.3).

/// A queued request visible to the batch former.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QueuedRequest {
    /// Request id.
    pub id: usize,
    /// Length signal used for grouping: prompt tokens for prefill
    /// batches, remaining output tokens for decode batches.
    pub length: u32,
    /// Queue entry time, ms.
    pub enqueued_ms: f64,
}

/// Batch formation interface: given the current queue (front first) and a
/// batch capacity, return the *indices into the queue* to dispatch now.
///
/// Invariants every implementation must uphold:
/// * at most `max_batch` indices, all in-bounds and distinct;
/// * a non-empty queue yields a non-empty batch (no starvation);
/// * the head-of-line request (index 0) is always included — LAB mitigates
///   head-of-line *blocking* by whom it adds, not by skipping the head.
pub trait BatchingPolicy: Send {
    /// Select queue indices to batch.
    fn form_batch(&self, queue: &[QueuedRequest], max_batch: usize) -> Vec<usize>;
    /// Policy name for reports.
    fn name(&self) -> &'static str;
}

/// First-in-first-out: take the front `max_batch` requests.
pub struct Fifo;

impl BatchingPolicy for Fifo {
    fn form_batch(&self, queue: &[QueuedRequest], max_batch: usize) -> Vec<usize> {
        (0..queue.len().min(max_batch)).collect()
    }
    fn name(&self) -> &'static str {
        "fifo"
    }
}

/// Length-Aware Batching: take the head-of-line request, then fill the
/// batch with the queued requests whose length is closest to the head's
/// (relative difference within `tolerance` preferred, nearest-length
/// otherwise). Matches the paper's description: "LAB takes the
/// head-of-line request and batches it with other requests whose lengths
/// closely match the head-of-line request".
pub struct Lab {
    /// Preferred relative length tolerance (e.g. 0.5 ⇒ within ±50%).
    pub tolerance: f64,
}

impl Default for Lab {
    fn default() -> Self {
        Lab { tolerance: 0.5 }
    }
}

impl BatchingPolicy for Lab {
    fn form_batch(&self, queue: &[QueuedRequest], max_batch: usize) -> Vec<usize> {
        if queue.is_empty() || max_batch == 0 {
            return Vec::new();
        }
        let head_len = queue[0].length as f64;
        // Candidates sorted by |length - head|, then by queue position
        // (FIFO fairness among equal matches).
        let mut candidates: Vec<usize> = (1..queue.len()).collect();
        candidates.sort_by(|&a, &b| {
            let da = (queue[a].length as f64 - head_len).abs();
            let db = (queue[b].length as f64 - head_len).abs();
            // total_cmp, not partial_cmp().unwrap(): the distances are
            // finite today, but a NaN (e.g. from a future length signal)
            // must degrade the ordering, never panic mid-dispatch. On
            // finite values the two orderings agree, so tie-breaks and
            // batch composition are byte-identical to the old comparator.
            da.total_cmp(&db).then(a.cmp(&b))
        });
        let mut batch = vec![0];
        for &i in &candidates {
            if batch.len() >= max_batch {
                break;
            }
            batch.push(i);
        }
        // Tolerance shapes preference, not admission: with spare capacity
        // we still fill the batch (compute would idle otherwise), but the
        // sort guarantees closest lengths first.
        let _ = self.tolerance;
        batch
    }
    fn name(&self) -> &'static str {
        "lab"
    }
}

/// Padding overhead of a batch: sum over members of (max_len − len),
/// the wasted work LAB minimizes.
pub fn padding_cost(queue: &[QueuedRequest], batch: &[usize]) -> u64 {
    let max_len = batch
        .iter()
        .map(|&i| queue[i].length)
        .max()
        .unwrap_or(0) as u64;
    batch
        .iter()
        .map(|&i| max_len - queue[i].length as u64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, Gen};

    fn queue(lens: &[u32]) -> Vec<QueuedRequest> {
        lens.iter()
            .enumerate()
            .map(|(id, &l)| QueuedRequest {
                id,
                length: l,
                enqueued_ms: id as f64,
            })
            .collect()
    }

    #[test]
    fn fifo_takes_front() {
        let q = queue(&[10, 900, 12, 11]);
        assert_eq!(Fifo.form_batch(&q, 2), vec![0, 1]);
        assert_eq!(Fifo.form_batch(&q, 10), vec![0, 1, 2, 3]);
    }

    #[test]
    fn lab_groups_similar_lengths() {
        // Head is short (10); LAB should pick the other short ones, not
        // the 900-token request sitting at position 1.
        let q = queue(&[10, 900, 12, 11, 850]);
        let batch = Lab::default().form_batch(&q, 3);
        assert_eq!(batch[0], 0, "head of line always included");
        assert!(batch.contains(&2) && batch.contains(&3));
        assert!(!batch.contains(&1));
    }

    #[test]
    fn lab_reduces_padding_vs_fifo() {
        let q = queue(&[100, 2000, 110, 95, 1900, 105]);
        let fifo_cost = padding_cost(&q, &Fifo.form_batch(&q, 4));
        let lab_cost = padding_cost(&q, &Lab::default().form_batch(&q, 4));
        assert!(
            lab_cost < fifo_cost / 4,
            "lab={lab_cost} fifo={fifo_cost}"
        );
    }

    #[test]
    fn lab_fills_capacity_when_queue_allows() {
        let q = queue(&[10, 9000, 8000]);
        // Nothing is "similar" to the head, but idle capacity is worse
        // than padding: batch still fills.
        assert_eq!(Lab::default().form_batch(&q, 3).len(), 3);
    }

    /// Regression (ISSUE satellite): the LAB candidate sort moved from
    /// `partial_cmp(..).unwrap()` to `total_cmp`. On finite distances the
    /// two comparators order identically, so the tie-break order — queue
    /// position among equal |length − head| — must be exactly what the
    /// old comparator produced.
    #[test]
    fn lab_tie_order_on_finite_values_unchanged() {
        // Head 100; positions 1..=4 at distances 10, 10, 5, 10: nearest
        // first, FIFO among the three equal-distance candidates.
        let q = queue(&[100, 110, 90, 105, 110]);
        assert_eq!(Lab::default().form_batch(&q, 5), vec![0, 3, 1, 2, 4]);
        // Explicit cross-check against the legacy comparator on the same
        // candidate set.
        let head_len = q[0].length as f64;
        let mut legacy: Vec<usize> = (1..q.len()).collect();
        legacy.sort_by(|&a, &b| {
            let da = (q[a].length as f64 - head_len).abs();
            let db = (q[b].length as f64 - head_len).abs();
            da.partial_cmp(&db).unwrap().then(a.cmp(&b))
        });
        assert_eq!(&Lab::default().form_batch(&q, 5)[1..], &legacy[..]);
    }

    #[test]
    fn empty_queue_empty_batch() {
        assert!(Fifo.form_batch(&[], 8).is_empty());
        assert!(Lab::default().form_batch(&[], 8).is_empty());
    }

    #[test]
    fn prop_batching_invariants() {
        run_prop("batching invariants", 300, |g: &mut Gen| {
            let n = g.usize_in(1, 60);
            let q: Vec<QueuedRequest> = (0..n)
                .map(|id| QueuedRequest {
                    id,
                    length: g.usize_in(1, 2048) as u32,
                    enqueued_ms: id as f64,
                })
                .collect();
            // Exercise capacities both below and above the queue length.
            // LAB's `tolerance` is a preference-only knob today (it never
            // filters admission — see Lab::form_batch); randomizing it
            // pins that contract so a future tolerance-based admission
            // change trips these invariants instead of shipping silently.
            let max_batch = g.usize_in(1, 80);
            let lab = Lab { tolerance: g.f64_in(0.0, 4.0) };
            for policy in [&Fifo as &dyn BatchingPolicy, &lab, &Lab::default()] {
                let batch = policy.form_batch(&q, max_batch);
                assert!(!batch.is_empty(), "{}: starvation", policy.name());
                assert!(batch.len() <= max_batch, "{}: over capacity", policy.name());
                assert_eq!(batch[0], 0, "{}: head-of-line skipped", policy.name());
                let mut sorted = batch.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), batch.len(), "duplicate indices");
                assert!(sorted.iter().all(|&i| i < q.len()), "out of bounds");
                // With spare capacity no policy may leave work idle.
                assert_eq!(
                    batch.len(),
                    q.len().min(max_batch),
                    "{}: under-filled batch",
                    policy.name()
                );
            }
        });
    }
}
