//! Request routing policies (paper §3.4): Random, Round-Robin, and
//! Join-the-Shortest-Queue over a read-only snapshot of target state.

use crate::util::rng::Pcg64;

/// Read-only view of one target server the router can inspect.
#[derive(Clone, Copy, Debug, Default)]
pub struct TargetSnapshot {
    /// Target id.
    pub id: usize,
    /// Requests waiting in the prefill queue.
    pub prefill_queue: usize,
    /// Requests currently in decode/verify residency.
    pub active: usize,
    /// Recent mean TPOT on this target, ms (0 if unknown).
    pub recent_tpot_ms: f64,
    /// Whether the server is currently executing a batch.
    pub busy: bool,
}

impl TargetSnapshot {
    /// Total load signal used by JSQ (queued + resident work).
    pub fn load(&self) -> usize {
        self.prefill_queue + self.active
    }
}

/// Routing policy interface. Policies may keep internal state (e.g.
/// round-robin cursor); randomness comes from the caller's RNG stream so
/// simulations stay deterministic.
pub trait RoutingPolicy: Send {
    /// Pick a target id for an arriving request.
    fn route(&mut self, targets: &[TargetSnapshot], rng: &mut Pcg64) -> usize;
    /// Policy name for reports.
    fn name(&self) -> &'static str;
}

/// Uniform random selection.
pub struct Random;

impl RoutingPolicy for Random {
    fn route(&mut self, targets: &[TargetSnapshot], rng: &mut Pcg64) -> usize {
        targets[rng.index(targets.len())].id
    }
    fn name(&self) -> &'static str {
        "random"
    }
}

/// Round-robin over target ids.
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    /// Cursor starts at target 0.
    pub fn new() -> Self {
        RoundRobin { next: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl RoutingPolicy for RoundRobin {
    fn route(&mut self, targets: &[TargetSnapshot], _rng: &mut Pcg64) -> usize {
        let t = targets[self.next % targets.len()].id;
        self.next = (self.next + 1) % targets.len();
        t
    }
    fn name(&self) -> &'static str {
        "round_robin"
    }
}

/// Join-the-Shortest-Queue: route to the target with the least queued +
/// resident work; ties broken by lower id (deterministic).
pub struct Jsq;

impl RoutingPolicy for Jsq {
    fn route(&mut self, targets: &[TargetSnapshot], _rng: &mut Pcg64) -> usize {
        targets
            .iter()
            .min_by_key(|t| (t.load(), t.id))
            .expect("at least one target")
            .id
    }
    fn name(&self) -> &'static str {
        "jsq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snaps(loads: &[usize]) -> Vec<TargetSnapshot> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &l)| TargetSnapshot {
                id,
                prefill_queue: l,
                ..Default::default()
            })
            .collect()
    }

    #[test]
    fn jsq_picks_min_load() {
        let mut p = Jsq;
        let mut rng = Pcg64::new(1);
        assert_eq!(p.route(&snaps(&[3, 1, 2]), &mut rng), 1);
        // Tie -> lowest id.
        assert_eq!(p.route(&snaps(&[2, 2, 2]), &mut rng), 0);
    }

    #[test]
    fn jsq_counts_active_too() {
        let mut p = Jsq;
        let mut rng = Pcg64::new(1);
        let mut ts = snaps(&[0, 0]);
        ts[0].active = 5;
        assert_eq!(p.route(&ts, &mut rng), 1);
    }

    #[test]
    fn round_robin_cycles() {
        let mut p = RoundRobin::new();
        let mut rng = Pcg64::new(1);
        let ts = snaps(&[0, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|_| p.route(&ts, &mut rng)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn random_covers_all_targets() {
        let mut p = Random;
        let mut rng = Pcg64::new(7);
        let ts = snaps(&[0; 8]);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[p.route(&ts, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn prop_routing_invariants() {
        use crate::util::prop::{run_prop, Gen};
        run_prop("routing invariants", 300, |g: &mut Gen| {
            let n = g.usize_in(1, 12);
            // Non-contiguous ids (offset) so membership is a real check,
            // not an accident of 0..n indexing.
            let offset = g.usize_in(0, 5);
            let ts: Vec<TargetSnapshot> = (0..n)
                .map(|i| TargetSnapshot {
                    id: offset + i,
                    prefill_queue: g.usize_in(0, 20),
                    active: g.usize_in(0, 20),
                    recent_tpot_ms: g.f64_in(0.0, 100.0),
                    busy: g.bool_with(0.5),
                })
                .collect();
            let seed = g.u64_in(0, u64::MAX - 1);
            let policies: Vec<Box<dyn RoutingPolicy>> = vec![
                Box::new(Random),
                Box::new(RoundRobin::new()),
                Box::new(Jsq),
            ];
            for mut p in policies {
                let mut rng = Pcg64::new(seed);
                for _ in 0..3 {
                    let picked = p.route(&ts, &mut rng);
                    // Returned id must be a *member* target id.
                    assert!(
                        ts.iter().any(|t| t.id == picked),
                        "{}: id {picked} not in snapshot",
                        p.name()
                    );
                }
            }
            // JSQ must pick a minimum-load target, ties to lowest id.
            let mut rng = Pcg64::new(seed);
            let picked = Jsq.route(&ts, &mut rng);
            let min_load = ts.iter().map(|t| t.load()).min().unwrap();
            let expect = ts
                .iter()
                .filter(|t| t.load() == min_load)
                .map(|t| t.id)
                .min()
                .unwrap();
            assert_eq!(picked, expect, "jsq must take the least-loaded target");
            // Round-robin covers every target exactly once per cycle.
            let mut rr = RoundRobin::new();
            let mut seen: Vec<usize> = (0..n).map(|_| rr.route(&ts, &mut rng)).collect();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), n, "round robin must cover all targets");
        });
    }

    #[test]
    fn random_is_roughly_uniform() {
        let mut p = Random;
        let mut rng = Pcg64::new(11);
        let ts = snaps(&[0; 4]);
        let mut counts = [0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            counts[p.route(&ts, &mut rng)] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.25).abs() < 0.02, "frac={frac}");
        }
    }
}
