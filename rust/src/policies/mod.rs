//! The three pluggable policy families of paper §3.4 — request routing,
//! batching, and window-size control. Each policy operates on a read-only
//! snapshot of recent system performance metrics (queue depth, RTT, TPOT,
//! acceptance rate).

pub mod batching;
pub mod routing;
pub mod window;

pub use batching::{BatchingPolicy, Fifo, Lab, QueuedRequest};
pub use routing::{Jsq, Random, RoundRobin, RoutingPolicy, TargetSnapshot};
pub use window::{
    DynamicWindow, ExecMode, StaticWindow, WindowDecision, WindowFeatures, WindowPolicy,
};

use crate::config::{BatchingKind, RoutingKind, WindowKind};

/// Instantiate a routing policy from its config selector.
pub fn make_routing(kind: RoutingKind) -> Box<dyn RoutingPolicy> {
    match kind {
        RoutingKind::Random => Box::new(Random),
        RoutingKind::RoundRobin => Box::new(RoundRobin::new()),
        RoutingKind::Jsq => Box::new(Jsq),
    }
}

/// Instantiate a batching policy from its config selector.
pub fn make_batching(kind: BatchingKind) -> Box<dyn BatchingPolicy> {
    match kind {
        BatchingKind::Fifo => Box::new(Fifo),
        BatchingKind::Lab => Box::new(Lab::default()),
    }
}

/// Instantiate a window policy from its config selector.
///
/// `WindowKind::Awc` loads the embedded pretrained WC-DNN unless a weight
/// file path is provided.
pub fn make_window(kind: &WindowKind) -> Result<Box<dyn WindowPolicy>, String> {
    Ok(match kind {
        WindowKind::Static(g) => Box::new(StaticWindow(*g)),
        WindowKind::Dynamic { init, lo, hi } => Box::new(DynamicWindow::new(*init, *lo, *hi)),
        WindowKind::Awc { weights_path } => {
            let weights = match weights_path {
                Some(p) => crate::awc::AwcWeights::from_file(p)?,
                None => crate::awc::AwcWeights::builtin(),
            };
            Box::new(crate::awc::AwcPolicy::new(weights))
        }
        WindowKind::FusedOnly => Box::new(window::FusedOnly),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factories_produce_the_right_policies() {
        assert_eq!(make_routing(RoutingKind::Random).name(), "random");
        assert_eq!(make_routing(RoutingKind::RoundRobin).name(), "round_robin");
        assert_eq!(make_routing(RoutingKind::Jsq).name(), "jsq");
        assert_eq!(make_batching(BatchingKind::Fifo).name(), "fifo");
        assert_eq!(make_batching(BatchingKind::Lab).name(), "lab");
        assert_eq!(make_window(&WindowKind::Static(4)).unwrap().name(), "static");
        assert_eq!(
            make_window(&WindowKind::Dynamic { init: 4, lo: 0.25, hi: 0.75 })
                .unwrap()
                .name(),
            "dynamic"
        );
        assert_eq!(
            make_window(&WindowKind::Awc { weights_path: None }).unwrap().name(),
            "awc"
        );
        assert_eq!(make_window(&WindowKind::FusedOnly).unwrap().name(), "fused");
    }
}
