//! Lightweight property-based testing helpers (replaces `proptest`,
//! unavailable offline).
//!
//! A property is a closure over a [`Gen`] (seeded value source). The
//! runner executes it for many seeds; on failure it reports the seed so
//! the case replays deterministically:
//!
//! ```no_run
//! use dsd::util::prop::{run_prop, Gen};
//! run_prop("sum is commutative", 200, |g: &mut Gen| {
//!     let mut draws = (g.f64_in(0.0, 1e6), 0.0);
//!     draws.1 = g.f64_in(0.0, 1e6);
//!     let (a, b) = draws;
//!     assert!((a + b - (b + a)).abs() < 1e-9);
//! });
//! ```

use super::rng::Pcg64;

/// Seeded value generator handed to property closures.
pub struct Gen {
    rng: Pcg64,
    /// Seed of the current case (for failure reporting / replay).
    pub seed: u64,
}

impl Gen {
    /// Generator for one case.
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Pcg64::new(seed),
            seed,
        }
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform u64 in [lo, hi] inclusive.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Bernoulli draw.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }

    /// Vector of values from an element generator.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one of the provided items.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.index(xs.len());
        &xs[i]
    }

    /// Uniform random permutation of `0..n`. Used by order-insensitivity
    /// properties, e.g. "document key order never changes a sweep cell's
    /// cache key".
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut xs: Vec<usize> = (0..n).collect();
        self.shuffle(&mut xs);
        xs
    }

    /// Shuffle a vector in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.rng.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Borrow the underlying RNG for ad-hoc draws.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `prop` for `cases` deterministic seeds; panics with the failing
/// seed on the first violated assertion.
pub fn run_prop(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        // Stable per-case seed; independent of `cases` so adding cases
        // never changes earlier ones.
        let seed = 0xD5D0_5EED_u64
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run_prop("trivial", 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        run_prop("fails", 50, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 90, "x={x}");
        });
    }

    #[test]
    fn generator_is_deterministic_per_seed() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..100 {
            assert_eq!(a.u64_in(0, 1_000_000), b.u64_in(0, 1_000_000));
        }
    }

    #[test]
    fn permutation_is_a_bijection() {
        let mut g = Gen::new(11);
        for n in [0usize, 1, 2, 7, 32] {
            let p = g.permutation(n);
            assert_eq!(p.len(), n);
            let mut sorted = p.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n).collect::<Vec<_>>());
        }
        // Deterministic per seed.
        assert_eq!(Gen::new(3).permutation(10), Gen::new(3).permutation(10));
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut g = Gen::new(5);
        let mut xs = vec![3, 1, 4, 1, 5, 9, 2, 6];
        let mut want = xs.clone();
        g.shuffle(&mut xs);
        let mut got = xs.clone();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn vec_of_and_pick() {
        let mut g = Gen::new(1);
        let v = g.vec_of(10, |g| g.usize_in(0, 5));
        assert_eq!(v.len(), 10);
        assert!(v.iter().all(|&x| x <= 5));
        let items = [1, 2, 3];
        let p = *g.pick(&items);
        assert!(items.contains(&p));
    }
}
