//! Streaming statistics used by the metrics analyzer and bench harness:
//! mean/std accumulators (Welford), percentiles, exponential moving
//! averages, fixed-bucket histograms, and sliding time windows.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    /// Empty accumulator.
    pub fn new() -> Self {
        Accumulator {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add an observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population standard deviation (0 when n < 2).
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }

    /// Smallest observation (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Largest observation (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }
}

/// Percentile of a sample (linear interpolation, `q` in [0, 100]).
///
/// Sorts a copy; fine for end-of-run reporting.
///
/// NaN policy: samples sort by IEEE-754 *total order* (`f64::total_cmp`),
/// under which NaN lands past +∞ at the top of the sorted sample. A stray
/// non-finite latency therefore perturbs only the extreme upper
/// percentiles that actually reach it — it can never abort an end-of-run
/// report (the previous `partial_cmp(..).unwrap()` comparator panicked on
/// the first NaN). For all-finite samples the ordering is unchanged.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Arithmetic mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Exponential moving average with smoothing factor `alpha` in (0, 1].
///
/// `value = alpha * x + (1 - alpha) * value`. Used by AWC's stabilizer
/// (paper §4.4, alpha = 0.4) and the metrics snapshots.
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// New EMA with the given smoothing factor.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0,1]");
        Ema { alpha, value: None }
    }

    /// Feed an observation; returns the smoothed value.
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    /// Current smoothed value (None before any observation).
    pub fn value(&self) -> Option<f64> {
        self.value
    }

    /// Current value or a fallback.
    pub fn value_or(&self, fallback: f64) -> f64 {
        self.value.unwrap_or(fallback)
    }

    /// Clear state.
    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Sliding window over (time, value) observations; evicts entries older
/// than `horizon`. Backs the "recent X" features the AWC policy consumes.
#[derive(Clone, Debug)]
pub struct TimeWindow {
    horizon: f64,
    entries: std::collections::VecDeque<(f64, f64)>,
    sum: f64,
}

impl TimeWindow {
    /// Window keeping observations within `horizon` time units of the
    /// latest push.
    pub fn new(horizon: f64) -> Self {
        TimeWindow {
            horizon,
            entries: std::collections::VecDeque::new(),
            sum: 0.0,
        }
    }

    /// Record `value` observed at time `now` (non-decreasing).
    ///
    /// Eviction runs *before* the insert: a quiet gap longer than the
    /// horizon leaves the window momentarily empty, which re-zeroes the
    /// running sum exactly (see [`TimeWindow::evict`]) before the new
    /// value lands. The eviction set is identical either way (the fresh
    /// entry could never be older than the horizon), but this order is
    /// what lets the drift bound below hold.
    pub fn push(&mut self, now: f64, value: f64) {
        self.evict(now);
        self.entries.push_back((now, value));
        self.sum += value;
    }

    fn evict(&mut self, now: f64) {
        while let Some(&(t, v)) = self.entries.front() {
            if now - t > self.horizon {
                self.entries.pop_front();
                self.sum -= v;
            } else {
                break;
            }
        }
        // `sum -= v` accumulates floating-point error over long runs
        // (multi-million-event simulations push and evict continuously).
        // An empty window has an exactly known sum, so resync it here:
        // accumulated error can never outlive one window occupancy, and
        // every gap longer than the horizon restores an exact sum.
        if self.entries.is_empty() {
            self.sum = 0.0;
        }
    }

    /// Mean over the current window (None if empty).
    pub fn mean(&self) -> Option<f64> {
        if self.entries.is_empty() {
            None
        } else {
            Some(self.sum / self.entries.len() as f64)
        }
    }

    /// Number of in-window observations.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the window holds no observations.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Fixed-bucket histogram for latency distributions in reports.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    width: f64,
    buckets: Vec<u64>,
    /// Observations below `lo` / at-or-above the last bucket edge.
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    /// Histogram over [lo, hi) with `n` equal buckets.
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Histogram {
            lo,
            width: (hi - lo) / n as f64,
            buckets: vec![0; n],
            underflow: 0,
            overflow: 0,
        }
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        let idx = ((x - self.lo) / self.width) as usize;
        if idx >= self.buckets.len() {
            self.overflow += 1;
        } else {
            self.buckets[idx] += 1;
        }
    }

    /// Bucket counts.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Total recorded observations including under/overflow.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Observations below the lower edge.
    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    /// Observations at or above the upper edge.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Width of one bucket — the resolution of [`Histogram::percentile`].
    pub fn bucket_width(&self) -> f64 {
        self.width
    }

    /// Lower edge of the histogram range.
    pub fn lo(&self) -> f64 {
        self.lo
    }

    /// Upper edge of the histogram range.
    pub fn hi(&self) -> f64 {
        self.lo + self.width * self.buckets.len() as f64
    }

    /// Percentile estimate (`q` in [0, 100]) from bucket counts, with
    /// linear interpolation inside the selected bucket. Accurate to one
    /// bucket width; this is what lets a streaming sink report p50/p99
    /// without retaining per-observation samples. Underflow clamps to the
    /// lower edge, overflow to the upper edge; NaN when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 100.0) / 100.0) * total as f64;
        let mut cum = self.underflow as f64;
        if rank <= cum && self.underflow > 0 {
            return self.lo;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c as f64;
            if rank <= next {
                let frac = ((rank - cum) / c as f64).clamp(0.0, 1.0);
                return self.lo + self.width * (i as f64 + frac);
            }
            cum = next;
        }
        self.hi()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_moments() {
        let mut a = Accumulator::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            a.push(x);
        }
        assert_eq!(a.count(), 8);
        assert!((a.mean() - 5.0).abs() < 1e-12);
        assert!((a.std() - 2.0).abs() < 1e-12);
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 9.0);
    }

    #[test]
    fn empty_accumulator() {
        let a = Accumulator::new();
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.std(), 0.0);
        assert!(a.min().is_nan());
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.02);
        assert!(percentile(&[], 50.0).is_nan());
    }

    // Regression: a single non-finite latency sample used to abort the
    // whole end-of-run report via the `partial_cmp(..).unwrap()` sort
    // comparator. NaN now sorts last (IEEE total order), so mid-range
    // percentiles stay finite and only the extreme tail sees the NaN.
    #[test]
    fn percentile_tolerates_nan_samples() {
        let xs = [1.0, 2.0, f64::NAN, 3.0];
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan(), "NaN owns the top rank");
        // Infinities order normally, below NaN.
        let xs = [f64::INFINITY, 1.0, f64::NAN, f64::NEG_INFINITY];
        assert_eq!(percentile(&xs, 0.0), f64::NEG_INFINITY);
        assert_eq!(percentile(&xs, 50.0), f64::INFINITY); // (1.0 + ∞) / 2
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn ema_tracks_and_smooths() {
        let mut e = Ema::new(0.4);
        assert_eq!(e.push(10.0), 10.0); // first value passes through
        let v = e.push(20.0);
        assert!((v - 14.0).abs() < 1e-12); // 0.4*20 + 0.6*10
        e.reset();
        assert_eq!(e.value(), None);
        assert_eq!(e.value_or(3.0), 3.0);
    }

    #[test]
    #[should_panic]
    fn ema_rejects_bad_alpha() {
        Ema::new(0.0);
    }

    #[test]
    fn time_window_eviction() {
        let mut w = TimeWindow::new(10.0);
        w.push(0.0, 1.0);
        w.push(5.0, 2.0);
        w.push(14.0, 3.0); // evicts t=0 entry (14-0 > 10)
        assert_eq!(w.len(), 2);
        assert!((w.mean().unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn time_window_empty() {
        let w = TimeWindow::new(5.0);
        assert!(w.is_empty());
        assert_eq!(w.mean(), None);
    }

    #[test]
    fn time_window_sum_resets_exactly_when_emptied() {
        let mut w = TimeWindow::new(1.0);
        // Values chosen so `sum -= v` leaves a residue in plain f64
        // arithmetic: (0.1 + 0.2) - 0.1 - 0.2 != 0.0 exactly — without
        // the empty-window resync the next mean would inherit it.
        w.push(0.0, 0.1);
        w.push(0.5, 0.2);
        w.push(100.0, 3.0); // gap > horizon: evicts both, resyncs, inserts
        assert_eq!(w.len(), 1);
        assert_eq!(w.sum, 3.0, "sum is exact after a full eviction, no residue");
        assert_eq!(w.mean(), Some(3.0));
        w.push(300.0, 0.0); // empties again before inserting 0.0
        assert_eq!(w.sum, 0.0, "sum is exactly re-zeroed");
    }

    /// Property: over long random push/evict sequences the running sum
    /// stays equal (to fp tolerance) to a naive recompute over the
    /// retained entries, and emptying the window resyncs it *exactly*.
    #[test]
    fn prop_time_window_running_sum_matches_naive_recompute() {
        use crate::util::prop::{run_prop, Gen};
        run_prop("time-window sum vs naive recompute", 40, |g: &mut Gen| {
            let horizon = g.f64_in(0.5, 20.0);
            let mut w = TimeWindow::new(horizon);
            let mut now = 0.0;
            let steps = g.usize_in(200, 2000);
            for _ in 0..steps {
                // Occasional jumps past the horizon empty the window and
                // must trigger the exact resync.
                now += if g.bool_with(0.05) {
                    horizon * g.f64_in(1.5, 3.0)
                } else {
                    g.f64_in(0.0, horizon / 4.0)
                };
                w.push(now, g.f64_in(-10.0, 10.0));
                let naive: f64 = w.entries.iter().map(|&(_, v)| v).sum();
                let scale = naive.abs().max(1.0);
                assert!(
                    (w.sum - naive).abs() <= 1e-9 * scale,
                    "running sum drifted: {} vs naive {naive}",
                    w.sum
                );
            }
            // Force a full eviction: the empty-window resync is *exact*,
            // even after thousands of inexact `sum -= v` updates.
            let v = g.f64_in(-10.0, 10.0);
            w.push(now + horizon * 4.0, v);
            assert_eq!(w.len(), 1);
            assert_eq!(w.sum, v, "sum must be exactly the sole survivor");
        });
    }

    #[test]
    fn histogram_bucketing() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [-1.0, 0.0, 0.5, 5.0, 9.99, 10.0, 100.0] {
            h.push(x);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.buckets()[0], 2); // 0.0, 0.5
        assert_eq!(h.buckets()[5], 1); // 5.0
        assert_eq!(h.buckets()[9], 1); // 9.99
    }

    #[test]
    fn histogram_percentiles_track_exact_within_bucket() {
        // 1..=1000 in [0, 1000) with 100 buckets of width 10: the
        // histogram percentile must agree with the exact one to within a
        // bucket width everywhere.
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let mut h = Histogram::new(0.0, 1000.0, 100);
        for &x in &xs {
            h.push(x);
        }
        for q in [0.0, 1.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let exact = percentile(&xs, q);
            let approx = h.percentile(q);
            assert!(
                (approx - exact).abs() <= h.bucket_width() + 1e-9,
                "q={q}: approx {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn histogram_percentile_edge_cases() {
        let h = Histogram::new(0.0, 10.0, 10);
        assert!(h.percentile(50.0).is_nan(), "empty histogram");
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0); // underflow
        h.push(50.0); // overflow
        assert_eq!(h.percentile(0.0), 0.0);
        assert_eq!(h.percentile(100.0), 10.0);
        assert_eq!(h.lo(), 0.0);
        assert_eq!(h.hi(), 10.0);
        assert_eq!(h.underflow(), 1);
        assert_eq!(h.overflow(), 1);
    }

    #[test]
    fn mean_helper() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
