//! Minimal YAML-subset parser for DSD deployment configurations.
//!
//! Replaces `serde_yaml` (unavailable offline). Supports the subset the
//! paper's configuration files need:
//!
//! * nested block mappings (indentation-scoped)
//! * block sequences (`- item`), including sequences of mappings
//! * inline scalars: strings (bare / single / double quoted), integers,
//!   floats, booleans, null
//! * flow sequences of scalars: `[a, b, c]`
//! * `#` comments and blank lines
//!
//! Anchors, aliases, multi-document streams, and block scalars are *not*
//! supported — DSD configs do not use them. Parsed documents are returned
//! as [`Json`] values so the typed config layer shares one value model.

use super::json::Json;
use std::fmt;

/// YAML parse error with line context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct YamlError {
    /// Human-readable description.
    pub msg: String,
    /// 1-based line number.
    pub line: usize,
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "yaml error at line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for YamlError {}

/// Parse a YAML document into a [`Json`] value.
pub fn parse(text: &str) -> Result<Json, YamlError> {
    let lines: Vec<Line> = text
        .lines()
        .enumerate()
        .map(|(i, raw)| Line::new(i + 1, raw))
        .filter(|l| !l.blank)
        .collect();
    let mut pos = 0;
    if lines.is_empty() {
        return Ok(Json::Null);
    }
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        return Err(YamlError {
            msg: "unexpected dedent/content after document".into(),
            line: lines[pos].no,
        });
    }
    Ok(v)
}

struct Line {
    no: usize,
    indent: usize,
    /// Content with comments stripped and trailing space trimmed.
    content: String,
    blank: bool,
}

impl Line {
    fn new(no: usize, raw: &str) -> Line {
        let indent = raw.len() - raw.trim_start_matches(' ').len();
        let stripped = strip_comment(raw.trim_start_matches(' '));
        let content = stripped.trim_end().to_string();
        let blank = content.is_empty();
        Line {
            no,
            indent,
            content,
            blank,
        }
    }
}

/// Strip a `#` comment that is not inside quotes.
fn strip_comment(s: &str) -> &str {
    let bytes = s.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'#' if !in_single && !in_double => {
                // YAML requires '#' to be preceded by space/line start.
                if i == 0 || bytes[i - 1] == b' ' {
                    return &s[..i];
                }
            }
            _ => {}
        }
    }
    s
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let line = &lines[*pos];
    if line.content.starts_with("- ") || line.content == "-" {
        parse_sequence(lines, pos, indent)
    } else {
        parse_mapping(lines, pos, indent)
    }
}

fn parse_sequence(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError {
                msg: "unexpected indent inside sequence".into(),
                line: line.no,
            });
        }
        if !(line.content.starts_with("- ") || line.content == "-") {
            break;
        }
        let no = line.no;
        let rest = line.content[1..].trim_start().to_string();
        if rest.is_empty() {
            // Nested block on following lines.
            *pos += 1;
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Json::Null);
            }
        } else if let Some((key, val)) = split_key(&rest) {
            // "- key: value" — inline start of a mapping item. Re-parse the
            // remainder as a mapping whose virtual indent is indent + 2.
            let virt_indent = indent + 2;
            let mut map = Vec::new();
            push_mapping_entry(&mut map, key, val, lines, pos, virt_indent, no)?;
            // Continue consuming further keys at the virtual indent.
            while *pos < lines.len()
                && lines[*pos].indent == virt_indent
                && !lines[*pos].content.starts_with("- ")
            {
                let l = &lines[*pos];
                let (k, v) = split_key(&l.content).ok_or_else(|| YamlError {
                    msg: "expected 'key: value' in mapping item".into(),
                    line: l.no,
                })?;
                let lno = l.no;
                push_mapping_entry(&mut map, k, v, lines, pos, virt_indent, lno)?;
            }
            items.push(Json::Obj(map));
        } else {
            *pos += 1;
            items.push(parse_scalar(&rest, no)?);
        }
    }
    Ok(Json::Arr(items))
}

fn parse_mapping(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let mut pairs = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError {
                msg: "unexpected indent inside mapping".into(),
                line: line.no,
            });
        }
        if line.content.starts_with("- ") || line.content == "-" {
            break;
        }
        let (key, val) = split_key(&line.content).ok_or_else(|| YamlError {
            msg: format!("expected 'key: value', got '{}'", line.content),
            line: line.no,
        })?;
        let no = line.no;
        push_mapping_entry(&mut pairs, key, val, lines, pos, indent, no)?;
    }
    Ok(Json::Obj(pairs))
}

/// Consume one `key: value` entry starting at `*pos` (whose line is already
/// split into key/val); advances `*pos` past the entry including any nested
/// block.
fn push_mapping_entry(
    pairs: &mut Vec<(String, Json)>,
    key: String,
    val: String,
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    line_no: usize,
) -> Result<(), YamlError> {
    *pos += 1;
    let value = if val.is_empty() {
        // Nested block or implicit null.
        if *pos < lines.len() && lines[*pos].indent > indent {
            let child_indent = lines[*pos].indent;
            parse_block(lines, pos, child_indent)?
        } else if *pos < lines.len()
            && lines[*pos].indent == indent
            && lines[*pos].content.starts_with("- ")
        {
            // Sequences are allowed at the same indent as their key.
            parse_sequence(lines, pos, indent)?
        } else {
            Json::Null
        }
    } else {
        parse_scalar(&val, line_no)?
    };
    pairs.push((key, value));
    Ok(())
}

/// Split `key: value` (value may be empty). Returns None if no unquoted ':'
/// separator exists.
fn split_key(s: &str) -> Option<(String, String)> {
    let bytes = s.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b':' if !in_single && !in_double => {
                // ':' must terminate the key: end-of-line or followed by space.
                if i + 1 == bytes.len() || bytes[i + 1] == b' ' {
                    let key = unquote(s[..i].trim());
                    let val = s[i + 1..].trim().to_string();
                    return Some((key, val));
                }
            }
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> String {
    let b = s.as_bytes();
    if b.len() >= 2
        && ((b[0] == b'"' && b[b.len() - 1] == b'"')
            || (b[0] == b'\'' && b[b.len() - 1] == b'\''))
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

fn parse_scalar(s: &str, line: usize) -> Result<Json, YamlError> {
    let s = s.trim();
    // Flow sequence of scalars.
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| YamlError {
            msg: "unterminated flow sequence".into(),
            line,
        })?;
        if inner.trim().is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        return inner
            .split(',')
            .map(|part| parse_scalar(part, line))
            .collect::<Result<Vec<_>, _>>()
            .map(Json::Arr);
    }
    if s.starts_with('"') || s.starts_with('\'') {
        return Ok(Json::Str(unquote(s)));
    }
    Ok(match s {
        "null" | "~" | "" => Json::Null,
        "true" | "True" => Json::Bool(true),
        "false" | "False" => Json::Bool(false),
        _ => {
            if let Ok(x) = s.parse::<f64>() {
                Json::Num(x)
            } else {
                Json::Str(s.to_string())
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_nesting() {
        let doc = "\
name: dsd
seed: 42
rate: 1.5
flag: true
nothing: null
network:
  rtt_ms: 10
  jitter_ms: 0.5
";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("dsd"));
        assert_eq!(v.get("seed").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("rate").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("nothing"), Some(&Json::Null));
        assert_eq!(v.path(&["network", "rtt_ms"]).unwrap().as_f64(), Some(10.0));
    }

    #[test]
    fn sequences_of_scalars_and_maps() {
        let doc = "\
datasets:
  - gsm8k
  - cnndm
devices:
  - name: a100
    count: 4
  - name: h100
    count: 2
";
        let v = parse(doc).unwrap();
        let ds = v.get("datasets").unwrap().as_arr().unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].as_str(), Some("gsm8k"));
        let dev = v.get("devices").unwrap().as_arr().unwrap();
        assert_eq!(dev[0].get("name").unwrap().as_str(), Some("a100"));
        assert_eq!(dev[1].get("count").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn sequence_at_key_indent() {
        let doc = "\
items:
- 1
- 2
";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("items").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn comments_and_blanks() {
        let doc = "\
# header comment
a: 1  # trailing comment

b: \"text # not comment\"
";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_str(), Some("text # not comment"));
    }

    #[test]
    fn flow_sequences() {
        let v = parse("xs: [1, 2.5, a, \"b\"]\nempty: []\n").unwrap();
        let xs = v.get("xs").unwrap().as_arr().unwrap();
        assert_eq!(xs[0].as_f64(), Some(1.0));
        assert_eq!(xs[2].as_str(), Some("a"));
        assert_eq!(xs[3].as_str(), Some("b"));
        assert!(v.get("empty").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn quoted_keys_and_colon_values() {
        let v = parse("\"k:1\": v\nurl: http://x/y\n").unwrap();
        assert_eq!(v.get("k:1").unwrap().as_str(), Some("v"));
        assert_eq!(v.get("url").unwrap().as_str(), Some("http://x/y"));
    }

    #[test]
    fn deep_nesting() {
        let doc = "\
a:
  b:
    c:
      - d: 1
        e:
          f: 2
";
        let v = parse(doc).unwrap();
        let item = &v.path(&["a", "b", "c"]).unwrap().as_arr().unwrap()[0];
        assert_eq!(item.get("d").unwrap().as_f64(), Some(1.0));
        assert_eq!(item.path(&["e", "f"]).unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn empty_document() {
        assert_eq!(parse("").unwrap(), Json::Null);
        assert_eq!(parse("# only comments\n").unwrap(), Json::Null);
    }

    #[test]
    fn errors_report_lines() {
        let err = parse("a: 1\n  weird\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn null_value_for_trailing_key() {
        let v = parse("a: 1\nb:\n").unwrap();
        assert_eq!(v.get("b"), Some(&Json::Null));
    }
}
