//! Tiny command-line argument parser (replaces `clap`, unavailable offline).
//!
//! Model: `dsd <subcommand> [--flag] [--key value]...`. Flags are
//! registered up front so typos are caught; `--help` text is generated.

use std::collections::BTreeMap;
use std::fmt;

/// CLI parse error.
#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Specification of one option.
#[derive(Clone)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<&'static str>,
}

/// Declarative subcommand spec; parse with [`Command::parse`].
pub struct Command {
    name: &'static str,
    about: &'static str,
    opts: Vec<OptSpec>,
}

/// Parsed arguments for one subcommand.
pub struct Args {
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
}

impl Command {
    /// New subcommand spec.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    /// Register a `--key value` option with an optional default.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default,
        });
        self
    }

    /// Register a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("dsd {} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{}\t{}{}\n", o.name, val, o.help, def));
        }
        s
    }

    /// Parse raw args (everything after the subcommand name).
    pub fn parse(&self, raw: &[String]) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        for o in &self.opts {
            if let Some(d) = o.default {
                values.insert(o.name, d.to_string());
            }
            if !o.takes_value {
                flags.insert(o.name, false);
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let arg = &raw[i];
            let name = arg
                .strip_prefix("--")
                .ok_or_else(|| CliError(format!("expected --option, got '{arg}'")))?;
            if name == "help" {
                return Err(CliError(self.help()));
            }
            // Support --key=value form too.
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let spec = self
                .opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| CliError(format!("unknown option --{name}\n\n{}", self.help())))?;
            if spec.takes_value {
                let val = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        raw.get(i)
                            .cloned()
                            .ok_or_else(|| CliError(format!("--{name} requires a value")))?
                    }
                };
                values.insert(spec.name, val);
            } else {
                if inline.is_some() {
                    return Err(CliError(format!("--{name} does not take a value")));
                }
                flags.insert(spec.name, true);
            }
            i += 1;
        }
        Ok(Args { values, flags })
    }
}

impl Args {
    /// String value of an option (set or defaulted).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    /// Required option, error message on absence.
    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("missing required option --{name}")))
    }

    /// Parse an option as u64.
    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        self.get(name)
            .map(|s| {
                s.parse()
                    .map_err(|_| CliError(format!("--{name} expects an integer, got '{s}'")))
            })
            .transpose()
    }

    /// Parse an option as usize.
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        Ok(self.get_u64(name)?.map(|x| x as usize))
    }

    /// Parse an option as f64.
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        self.get(name)
            .map(|s| {
                s.parse()
                    .map_err(|_| CliError(format!("--{name} expects a number, got '{s}'")))
            })
            .transpose()
    }

    /// Whether a boolean flag was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("simulate", "run the simulator")
            .opt("config", "path to YAML config", None)
            .opt("seed", "rng seed", Some("42"))
            .flag("verbose", "chatty output")
    }

    fn strs(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_defaults() {
        let a = cmd()
            .parse(&strs(&["--config", "c.yaml", "--verbose"]))
            .unwrap();
        assert_eq!(a.get("config"), Some("c.yaml"));
        assert_eq!(a.get_u64("seed").unwrap(), Some(42));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = cmd().parse(&strs(&["--seed=7"])).unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), Some(7));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&strs(&["--nope", "x"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&strs(&["--config"])).is_err());
    }

    #[test]
    fn required_helper() {
        let a = cmd().parse(&strs(&[])).unwrap();
        assert!(a.require("config").is_err());
        assert!(a.require("seed").is_ok());
    }

    #[test]
    fn bad_numeric_value() {
        let a = cmd().parse(&strs(&["--seed", "abc"])).unwrap();
        assert!(a.get_u64("seed").is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = cmd().help();
        assert!(h.contains("--config"));
        assert!(h.contains("--verbose"));
        assert!(h.contains("[default: 42]"));
    }
}
