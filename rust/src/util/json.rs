//! Minimal JSON value model, parser, and writer.
//!
//! Replaces `serde_json` (unavailable offline). Supports the full JSON
//! grammar; numbers are stored as `f64` (adequate for metrics, traces, and
//! model weights). The writer emits deterministic output: object keys keep
//! insertion order via a `Vec<(String, Json)>` backing store.

use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys (deterministic output).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert / overwrite a key on an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Fluent builder form of [`Json::set`].
    pub fn with(mut self, key: &str, value: Json) -> Self {
        self.set(key, value);
        self
    }

    /// Get a field of an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Mutable access to a field of an object.
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        match self {
            Json::Obj(pairs) => pairs.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Remove a key from an object, returning its value (None when the
    /// key is absent or `self` is not an object). Remaining keys keep
    /// their insertion order, so serialized output stays deterministic —
    /// the golden-report tests use this to drop wall-clock fields.
    pub fn remove(&mut self, key: &str) -> Option<Json> {
        match self {
            Json::Obj(pairs) => {
                let idx = pairs.iter().position(|(k, _)| k == key)?;
                Some(pairs.remove(idx).1)
            }
            _ => None,
        }
    }

    /// Get by path, e.g. `j.path(&["network", "rtt_ms"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// As f64 (numbers only).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As u64 if it is a non-negative integral number.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    /// As usize (see [`Json::as_u64`]).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Decode an array of numbers into `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|x| x.as_f64()).collect()
    }

    /// As f64, decoding `null` to NaN. The writer emits non-finite
    /// numbers as `null` (JSON has no NaN/Inf), so this is the inverse
    /// used when reloading metric snapshots: NaN → null → NaN round-trips
    /// and re-serializes to identical bytes.
    pub fn as_f64_or_nan(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    /// Recursively sort object keys (byte order). Arrays keep their
    /// element order — element order is semantic in JSON.
    pub fn canonicalize(&self) -> Json {
        match self {
            Json::Arr(xs) => Json::Arr(xs.iter().map(Json::canonicalize).collect()),
            Json::Obj(pairs) => {
                let mut sorted: Vec<(String, Json)> = pairs
                    .iter()
                    .map(|(k, v)| (k.clone(), v.canonicalize()))
                    .collect();
                sorted.sort_by(|a, b| a.0.cmp(&b.0));
                Json::Obj(sorted)
            }
            other => other.clone(),
        }
    }

    /// Canonical serialization: compact, object keys sorted recursively.
    /// Two structurally equal documents produce identical bytes no matter
    /// what order their keys were inserted or parsed in — the hashing
    /// basis for sweep cell cache keys.
    pub fn to_string_canonical(&self) -> String {
        let mut out = String::new();
        self.write_canonical_into(&mut out);
        out
    }

    /// Canonical serialization into a caller-owned buffer (appends).
    ///
    /// Byte-identical to `self.canonicalize().to_string_compact()` — the
    /// original two-pass implementation, kept as the reference in tests —
    /// but sorts keys *during the write* through a per-object index
    /// instead of deep-cloning the whole tree first. On the sweep
    /// cell-key hot path (one canonical document per cell probe) this
    /// removes an O(tree) clone and, with a reused buffer, all per-cell
    /// string allocations.
    pub fn write_canonical_into(&self, out: &mut String) {
        match self {
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_canonical_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                // Sort an index, not the pairs: no clone, and a stable
                // sort so (pathological) duplicate keys keep the same
                // relative order the clone-and-sort path produced.
                let mut idx: Vec<usize> = (0..pairs.len()).collect();
                idx.sort_by(|&a, &b| pairs[a].0.cmp(&pairs[b].0));
                out.push('{');
                for (i, &k) in idx.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, &pairs[k].0);
                    out.push(':');
                    pairs[k].1.write_canonical_into(out);
                }
                out.push('}');
            }
            scalar => scalar.write(out, None, 0),
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Compact serialization into a caller-owned buffer (appends).
    pub fn write_compact_into(&self, out: &mut String) {
        self.write(out, None, 0);
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    /// Pretty serialization into a caller-owned buffer (appends) — the
    /// allocation-free form of [`Json::to_string_pretty`] for write paths
    /// that persist many documents (e.g. sweep cell files) and want to
    /// reuse one buffer.
    pub fn write_pretty_into(&self, out: &mut String) {
        self.write(out, Some(2), 0);
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document from a string.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Parse a JSONL document (one JSON value per non-empty line).
    pub fn parse_lines(text: &str) -> Result<Vec<Json>, JsonError> {
        text.lines()
            .map(str::trim)
            .filter(|l| !l.is_empty())
            .map(Json::parse)
            .collect()
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no NaN/Inf; emit null like most tolerant writers.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Human-readable description.
    pub msg: String,
    /// Byte offset in the input.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = &self.bytes[self.pos..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "3.25e2", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let back = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn insertion_order_preserved() {
        let v = Json::obj()
            .with("z", 1.0.into())
            .with("a", 2.0.into())
            .with("m", 3.0.into());
        assert_eq!(v.to_string_compact(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn set_overwrites() {
        let mut v = Json::obj().with("k", 1.0.into());
        v.set("k", 2.0.into());
        assert_eq!(v.get("k").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn remove_and_get_mut() {
        let mut v = Json::obj()
            .with("a", 1.0.into())
            .with("b", 2.0.into())
            .with("c", 3.0.into());
        assert_eq!(v.remove("b"), Some(Json::Num(2.0)));
        assert_eq!(v.remove("b"), None);
        // Remaining keys keep insertion order.
        assert_eq!(v.to_string_compact(), r#"{"a":1,"c":3}"#);
        *v.get_mut("a").unwrap() = Json::Num(9.0);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(9.0));
        assert_eq!(Json::Num(1.0).remove("x"), None);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(42.5).to_string_compact(), "42.5");
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("[1, ").unwrap_err();
        assert!(e.pos >= 3);
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn jsonl_parsing() {
        let lines = Json::parse_lines("{\"a\":1}\n\n{\"a\":2}\n").unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].get("a").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::obj()
            .with("xs", vec![1.0, 2.0].into())
            .with("o", Json::obj().with("k", true.into()));
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn f64_vec_helper() {
        let v = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.5, 3.0]);
        assert!(Json::parse("[1, \"x\"]").unwrap().as_f64_vec().is_none());
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn f64_or_nan_decodes_null() {
        assert_eq!(Json::Num(2.5).as_f64_or_nan(), Some(2.5));
        assert!(Json::Null.as_f64_or_nan().unwrap().is_nan());
        assert_eq!(Json::Bool(true).as_f64_or_nan(), None);
        // Round-trip: NaN serializes to null, reloads as NaN, and
        // re-serializes to the same bytes.
        let first = Json::Num(f64::NAN).to_string_compact();
        let reloaded = Json::parse(&first).unwrap().as_f64_or_nan().unwrap();
        assert_eq!(Json::Num(reloaded).to_string_compact(), first);
    }

    #[test]
    fn canonical_ignores_insertion_order() {
        let a = Json::obj()
            .with("z", 1.0.into())
            .with("a", Json::obj().with("q", 2.0.into()).with("b", 3.0.into()));
        let b = Json::obj()
            .with("a", Json::obj().with("b", 3.0.into()).with("q", 2.0.into()))
            .with("z", 1.0.into());
        assert_eq!(a.to_string_canonical(), b.to_string_canonical());
        assert_eq!(a.to_string_canonical(), r#"{"a":{"b":3,"q":2},"z":1}"#);
        // Compact form still reflects insertion order.
        assert_ne!(a.to_string_compact(), b.to_string_compact());
    }

    #[test]
    fn canonical_preserves_array_order() {
        let v = Json::parse(r#"{"xs": [3, 1, 2]}"#).unwrap();
        assert_eq!(v.to_string_canonical(), r#"{"xs":[3,1,2]}"#);
    }

    #[test]
    fn write_into_forms_match_allocating_forms() {
        let v = Json::obj()
            .with("xs", vec![1.0, 2.5].into())
            .with("s", "q\"uote\n".into())
            .with("o", Json::obj().with("k", Json::Null));
        let mut buf = String::from("prefix|");
        v.write_compact_into(&mut buf);
        assert_eq!(buf, format!("prefix|{}", v.to_string_compact()));
        buf.clear();
        v.write_pretty_into(&mut buf);
        assert_eq!(buf, v.to_string_pretty());
    }

    /// Random document generator for the differential property below:
    /// nested objects/arrays with awkward keys (duplicates, escapes,
    /// empties) and awkward numbers (integral, negative, non-finite).
    fn gen_json(g: &mut crate::util::prop::Gen, depth: usize) -> Json {
        let leaf_only = depth >= 3;
        let kind = g.usize_in(0, if leaf_only { 3 } else { 5 });
        match kind {
            0 => Json::Null,
            1 => Json::Bool(g.bool_with(0.5)),
            2 => {
                let x = *g.pick(&[
                    0.0,
                    -1.0,
                    3.5,
                    42.0,
                    -17.25,
                    1e14,
                    6.02e23,
                    f64::NAN,
                    f64::INFINITY,
                ]);
                Json::Num(x)
            }
            3 => Json::Str((*g.pick(&["", "a", "key\nwith\tescapes\"", "é😀", "z"])).to_string()),
            4 => {
                let n = g.usize_in(0, 4);
                Json::Arr((0..n).map(|_| gen_json(g, depth + 1)).collect())
            }
            _ => {
                let n = g.usize_in(0, 5);
                // Keys drawn with replacement from a small pool, so
                // duplicate keys occur regularly and the stable-sort
                // tie behavior is actually exercised.
                let pool = ["alpha", "beta", "beta", "", "z", "\"q\""];
                Json::Obj(
                    (0..n)
                        .map(|_| ((*g.pick(&pool)).to_string(), gen_json(g, depth + 1)))
                        .collect(),
                )
            }
        }
    }

    #[test]
    fn prop_canonical_writer_matches_clone_and_sort_reference() {
        use crate::util::prop::run_prop;
        run_prop("canonical writer ≡ canonicalize+compact", 300, |g| {
            let doc = gen_json(g, 0);
            let reference = doc.canonicalize().to_string_compact();
            let mut fast = String::new();
            doc.write_canonical_into(&mut fast);
            assert_eq!(fast, reference, "doc: {doc:?}");
            assert_eq!(doc.to_string_canonical(), reference);
        });
    }
}
