//! Substrate utilities built from scratch for the offline environment:
//! RNG, JSON, YAML, CLI parsing, statistics, property testing, tables.

pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod yaml;
