//! Deterministic content hashing (replaces external hash crates,
//! unavailable offline).
//!
//! FNV-1a over bytes. Unlike `std::collections::hash_map::DefaultHasher`
//! (SipHash with a per-process random key), FNV-1a is a pure function of
//! its input: the same bytes hash identically across threads, processes,
//! machines, and releases — the property the sweep cell cache relies on
//! to address results on disk.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a starting from an explicit state (chaining / decorrelated
/// second passes).
pub fn fnv1a_64_seeded(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Standard FNV-1a 64-bit hash.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    fnv1a_64_seeded(FNV_OFFSET, bytes)
}

/// 128-bit content hash as 32 lowercase hex characters: a standard
/// FNV-1a pass plus a second pass whose offset basis is derived from the
/// first digest, so the two halves decorrelate. Collision probability at
/// sweep scales (≤ millions of cells) is negligible.
pub fn content_hash_hex(bytes: &[u8]) -> String {
    let h1 = fnv1a_64(bytes);
    let h2 = fnv1a_64_seeded(h1 ^ 0x9e37_79b9_7f4a_7c15, bytes);
    format!("{h1:016x}{h2:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn deterministic_and_input_sensitive() {
        let a = content_hash_hex(b"sweep cell one");
        let b = content_hash_hex(b"sweep cell one");
        let c = content_hash_hex(b"sweep cell two");
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 32);
        assert!(a.chars().all(|ch| ch.is_ascii_hexdigit()));
    }

    #[test]
    fn single_byte_flip_changes_both_halves() {
        let a = content_hash_hex(b"abcdef");
        let b = content_hash_hex(b"abcdeg");
        assert_ne!(a[..16], b[..16]);
        assert_ne!(a[16..], b[16..]);
    }
}
