//! ASCII table rendering for the experiment harness — every `reproduce`
//! subcommand prints paper-style rows through this module so outputs are
//! uniform and diff-able in EXPERIMENTS.md.

/// Column alignment.
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// Simple monospace table builder.
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    /// New table with header labels (all right-aligned except the first).
    pub fn new(headers: &[&str]) -> Self {
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns,
            rows: Vec::new(),
            title: None,
        }
    }

    /// Attach a title line printed above the table.
    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    /// Append a data row (must match header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {t} ==\n"));
        }
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let w = widths[i];
                match aligns[i] {
                    Align::Left => line.push_str(&format!("{:<w$}", cells[i])),
                    Align::Right => line.push_str(&format!("{:>w$}", cells[i])),
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `prec` decimals.
pub fn fnum(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format a percentage delta, signed: `+9.7%` / `-4.1%`.
pub fn fpct(x: f64) -> String {
    format!("{}{:.1}%", if x >= 0.0 { "+" } else { "" }, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["dataset", "tput"]).with_title("demo");
        t.row(vec!["gsm8k".into(), "25.8".into()]);
        t.row(vec!["cnndm".into(), "8.0".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("gsm8k"));
        // Numbers right-aligned to same column end.
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(&["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fnum(3.14159, 2), "3.14");
        assert_eq!(fpct(9.7), "+9.7%");
        assert_eq!(fpct(-4.12), "-4.1%");
    }
}
