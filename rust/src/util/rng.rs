//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so DSD carries its own generator:
//! a PCG64 (XSL-RR 128/64) core with SplitMix64 seeding, plus the
//! distribution samplers the simulator and trace generators need
//! (uniform, exponential, Poisson, normal, log-normal, Bernoulli).
//!
//! Every run of the simulator draws all randomness from one seeded root
//! [`Pcg64`]; child streams are forked with [`Pcg64::fork`] so adding a new
//! consumer does not perturb existing streams (stable determinism).

/// SplitMix64 step — used to expand a single `u64` seed into PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64 XSL-RR 128/64: 128-bit LCG state, 64-bit xor-shift/rotate output.
///
/// Small, fast, statistically strong, and — critically for DSD-Sim —
/// reproducible across platforms (no floating point in the core).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let a = splitmix64(&mut sm);
        let b = splitmix64(&mut sm);
        let c = splitmix64(&mut sm);
        let d = splitmix64(&mut sm);
        let state = ((a as u128) << 64) | b as u128;
        // Stream selector must be odd.
        let inc = (((c as u128) << 64) | d as u128) | 1;
        let mut rng = Pcg64 { state, inc };
        rng.next_u64(); // burn-in so state differs from raw seed material
        rng
    }

    /// Fork an independent child stream keyed by `tag`.
    ///
    /// Forking is stable: the child depends only on the parent's *seed
    /// path*, not on how many numbers the parent has drawn since. Callers
    /// should fork all children up front from a dedicated seeding RNG.
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let mix = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg64::new(mix)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (n > 0), via Lemire rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Widening-multiply rejection method (unbiased).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let low = m as u64;
            if low >= n {
                return (m >> 64) as u64;
            }
            let threshold = n.wrapping_neg() % n;
            if low >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with the given rate (mean 1/rate).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // Inverse CDF; guard against ln(0).
        let u = 1.0 - self.f64();
        -u.ln() / rate
    }

    /// Standard normal variate (Box–Muller, single-value form).
    pub fn normal(&mut self) -> f64 {
        // Polar Box–Muller without caching the second value keeps the
        // generator state a pure function of draw count.
        loop {
            let u = self.range_f64(-1.0, 1.0);
            let v = self.range_f64(-1.0, 1.0);
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal variate with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal variate parameterized by the *underlying* normal's
    /// mu/sigma (as in scipy's `lognorm`).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson variate with the given mean.
    ///
    /// Knuth's product method for small lambda; normal approximation with
    /// continuity correction above 30 (adequate for arrival batching).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_with(lambda, lambda.sqrt());
            if x < 0.5 {
                0
            } else {
                (x + 0.5) as u64
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose a uniformly random element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Pcg64::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Pcg64::new(5);
        let rate = 2.5;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_small_lambda_mean() {
        let mut r = Pcg64::new(11);
        let lambda = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn poisson_large_lambda_mean() {
        let mut r = Pcg64::new(13);
        let lambda = 200.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
        assert!((mean - lambda).abs() < lambda * 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg64::new(19);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.3)).count() as f64 / n as f64;
        assert!((hits - 0.3).abs() < 0.01, "hits={hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>()); // vanishing chance
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn range_u64_inclusive_bounds() {
        let mut r = Pcg64::new(37);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let x = r.range_u64(3, 6);
            assert!((3..=6).contains(&x));
            saw_lo |= x == 3;
            saw_hi |= x == 6;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn lognormal_median() {
        let mut r = Pcg64::new(41);
        let n = 50_000;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(2.0, 0.5)).collect();
        xs.sort_by(f64::total_cmp);
        let median = xs[n / 2];
        // Median of lognormal is exp(mu).
        assert!((median - 2.0f64.exp()).abs() < 0.15, "median={median}");
    }
}
