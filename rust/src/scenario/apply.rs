//! Applying scripted events to a live simulation: the mutable runtime
//! view of everything a scenario can change.
//!
//! [`RuntimeDynamics`] snapshots the expanded topology's per-drafter
//! links at t=0 and owns the *current* values the simulator reads on
//! every network and hardware-latency computation: effective link specs,
//! per-target slowdown multipliers, and per-pool availability. Scenario
//! events mutate this state through [`RuntimeDynamics::apply`];
//! multipliers are always applied to the **baseline** snapshot, so
//! repeated degrades do not compound and restores return bit-identical
//! baseline values. Scenario-free simulations read the same state, which
//! then equals the frozen topology exactly.

use super::script::ScenarioEvent;
use crate::config::{LinkSpec, PoolSpec, Topology};

/// A pool availability transition the simulator must react to (dropping
/// queued edge work on Down, waking drafters on Up). Link and slowdown
/// changes need no simulator-side reaction — they are read live.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolTransition {
    /// The pool just went down (was up).
    Down(usize),
    /// The pool just came back (was down).
    Up(usize),
}

/// Mutable runtime state scripted events act on.
pub struct RuntimeDynamics {
    /// t=0 per-drafter links (parallel to the expanded drafter list).
    base_links: Vec<LinkSpec>,
    /// Current effective per-drafter links.
    links: Vec<LinkSpec>,
    /// t=0 fallback link (synthetic drafter ids, e.g. fused-only runs).
    base_default: LinkSpec,
    /// Current effective fallback link.
    default_link: LinkSpec,
    /// Per-target hardware-latency multiplier (1.0 = baseline).
    target_mult: Vec<f64>,
    /// Per-target availability: whether the target currently accepts
    /// new work. Always true without autoscaling; the elastic-capacity
    /// subsystem ([`crate::autoscale`]) flips it as targets provision,
    /// drain, and shut off, so routing reads *live* capacity.
    target_available: Vec<bool>,
    /// Per-drafter-pool availability.
    pool_down: Vec<bool>,
    /// Cumulative drafter-pool end indices (pool `p` covers
    /// `pool_ends[p-1]..pool_ends[p]`).
    pool_ends: Vec<usize>,
}

impl RuntimeDynamics {
    /// Snapshot the expanded topology (plus the global default link and
    /// the drafter pool slicing) as the t=0 baseline.
    pub fn new(
        topo: &Topology,
        default_link: LinkSpec,
        drafter_pools: &[PoolSpec],
        n_targets: usize,
    ) -> RuntimeDynamics {
        let mut pool_ends = Vec::with_capacity(drafter_pools.len());
        let mut total = 0usize;
        for p in drafter_pools {
            total += p.count;
            pool_ends.push(total);
        }
        RuntimeDynamics {
            base_links: topo.links.clone(),
            links: topo.links.clone(),
            base_default: default_link,
            default_link,
            target_mult: vec![1.0; n_targets],
            target_available: vec![true; n_targets],
            pool_down: vec![false; drafter_pools.len()],
            pool_ends,
        }
    }

    /// Current effective link for a drafter id (the fallback default for
    /// synthetic ids). Scenario-free this equals
    /// [`Topology::link`](crate::config::Topology::link) bit-for-bit.
    pub fn link(&self, drafter_id: usize) -> &LinkSpec {
        self.links.get(drafter_id).unwrap_or(&self.default_link)
    }

    /// Current hardware-latency multiplier of one target.
    pub fn target_mult(&self, target_id: usize) -> f64 {
        self.target_mult.get(target_id).copied().unwrap_or(1.0)
    }

    /// Whether any target currently runs slowed down (fast path guard:
    /// scenario-free simulations skip the multiply entirely).
    pub fn any_target_slowdown(&self) -> bool {
        self.target_mult.iter().any(|&m| m != 1.0)
    }

    /// Whether a target currently accepts new work (always true without
    /// an elastic capacity pool; ids beyond the fleet read unavailable).
    pub fn target_available(&self, target_id: usize) -> bool {
        self.target_available.get(target_id).copied().unwrap_or(false)
    }

    /// Flip one target's availability (the autoscale fleet's lifecycle
    /// transitions call this so every routing decision sees live
    /// capacity).
    pub fn set_target_available(&mut self, target_id: usize, available: bool) {
        if let Some(slot) = self.target_available.get_mut(target_id) {
            *slot = available;
        }
    }

    /// Number of targets currently accepting work.
    pub fn n_targets_available(&self) -> usize {
        self.target_available.iter().filter(|&&a| a).count()
    }

    /// Pool index of a drafter id (`None` for synthetic ids beyond the
    /// expanded pools — those can never be "down").
    pub fn pool_of(&self, drafter_id: usize) -> Option<usize> {
        self.pool_ends.iter().position(|&end| drafter_id < end)
    }

    /// Whether a drafter currently belongs to a failed pool.
    pub fn drafter_down(&self, drafter_id: usize) -> bool {
        self.pool_of(drafter_id)
            .map(|p| self.pool_down[p])
            .unwrap_or(false)
    }

    /// Drafter-id range `[lo, hi)` of one pool.
    pub fn pool_range(&self, pool: usize) -> (usize, usize) {
        let hi = self.pool_ends[pool];
        let lo = if pool == 0 { 0 } else { self.pool_ends[pool - 1] };
        (lo, hi)
    }

    fn scaled(base: &LinkSpec, rtt_mult: f64, jitter_mult: f64, bandwidth_mult: f64) -> LinkSpec {
        LinkSpec {
            rtt_ms: base.rtt_ms * rtt_mult,
            jitter_ms: base.jitter_ms * jitter_mult,
            // ∞ · m = ∞ for m > 0: an unmodelled-serialization link
            // stays unmodelled under degradation.
            bandwidth_mbps: base.bandwidth_mbps * bandwidth_mult,
        }
    }

    fn for_pool_links(&mut self, pool: Option<usize>, f: impl Fn(&LinkSpec) -> LinkSpec) {
        match pool {
            Some(p) => {
                let (lo, hi) = self.pool_range(p);
                for i in lo..hi {
                    self.links[i] = f(&self.base_links[i]);
                }
            }
            None => {
                for (cur, base) in self.links.iter_mut().zip(&self.base_links) {
                    *cur = f(base);
                }
                self.default_link = f(&self.base_default);
            }
        }
    }

    /// Apply one event. Returns the pool transition the simulator must
    /// react to, if any; repeated Down (or Up) events on a pool already
    /// in that state are no-ops, so reaction logic runs exactly once per
    /// transition.
    pub fn apply(&mut self, ev: &ScenarioEvent) -> Option<PoolTransition> {
        match *ev {
            ScenarioEvent::LinkDegrade { pool, rtt_mult, jitter_mult, bandwidth_mult } => {
                self.for_pool_links(pool, |base| {
                    Self::scaled(base, rtt_mult, jitter_mult, bandwidth_mult)
                });
                None
            }
            ScenarioEvent::LinkRestore { pool } => {
                self.for_pool_links(pool, |base| *base);
                None
            }
            ScenarioEvent::DrafterPoolDown { pool } => {
                if self.pool_down[pool] {
                    None
                } else {
                    self.pool_down[pool] = true;
                    Some(PoolTransition::Down(pool))
                }
            }
            ScenarioEvent::DrafterPoolUp { pool } => {
                if self.pool_down[pool] {
                    self.pool_down[pool] = false;
                    Some(PoolTransition::Up(pool))
                } else {
                    None
                }
            }
            ScenarioEvent::TargetSlowdown { target, mult } => {
                match target {
                    Some(t) => self.target_mult[t] = mult,
                    None => self.target_mult.fill(mult),
                }
                None
            }
            // Folded into the arrival envelopes at trace-generation time.
            ScenarioEvent::RateOverride { .. } | ScenarioEvent::ClassRateOverride { .. } => None,
            // Routed through the autoscale fleet by the simulator before
            // the dynamics state is consulted (the fleet then flips
            // per-target availability here via `set_target_available`).
            ScenarioEvent::TargetPoolUp { .. } | ScenarioEvent::TargetPoolDown { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn two_pool_cfg() -> SimConfig {
        SimConfig::from_yaml(
            "\
cluster:
  targets:
    - count: 2
  drafters:
    - count: 4
      rtt_ms: 6
    - count: 3
network:
  rtt_ms: 20
  jitter_ms: 1
",
        )
        .unwrap()
    }

    fn dynamics(cfg: &SimConfig) -> RuntimeDynamics {
        let topo = Topology::expand(cfg).unwrap();
        RuntimeDynamics::new(&topo, cfg.network, &cfg.drafter_pools, cfg.n_targets())
    }

    #[test]
    fn baseline_matches_topology() {
        let cfg = two_pool_cfg();
        let topo = Topology::expand(&cfg).unwrap();
        let d = dynamics(&cfg);
        for i in 0..7 {
            assert_eq!(d.link(i).rtt_ms, topo.link(i).rtt_ms);
            assert_eq!(d.link(i).jitter_ms, topo.link(i).jitter_ms);
        }
        // Synthetic ids fall back to the global default, like Topology.
        assert_eq!(d.link(99).rtt_ms, 20.0);
        assert_eq!(d.target_mult(0), 1.0);
        assert!(!d.any_target_slowdown());
        assert!(!d.drafter_down(0));
        assert_eq!(d.pool_of(3), Some(0));
        assert_eq!(d.pool_of(4), Some(1));
        assert_eq!(d.pool_of(7), None);
        assert_eq!(d.pool_range(1), (4, 7));
    }

    #[test]
    fn degrade_is_absolute_and_restore_returns_baseline() {
        let cfg = two_pool_cfg();
        let mut d = dynamics(&cfg);
        let degrade = ScenarioEvent::LinkDegrade {
            pool: Some(1),
            rtt_mult: 8.0,
            jitter_mult: 2.0,
            bandwidth_mult: 1.0,
        };
        d.apply(&degrade);
        assert_eq!(d.link(4).rtt_ms, 160.0); // pool 1 base 20 × 8
        assert_eq!(d.link(0).rtt_ms, 6.0); // pool 0 untouched
        // Re-applying does not compound: multipliers act on the baseline.
        d.apply(&degrade);
        assert_eq!(d.link(4).rtt_ms, 160.0);
        d.apply(&ScenarioEvent::LinkRestore { pool: Some(1) });
        assert_eq!(d.link(4).rtt_ms, 20.0);
        assert_eq!(d.link(4).jitter_ms, 1.0);
    }

    #[test]
    fn global_degrade_covers_default_link_and_keeps_infinite_bandwidth() {
        let cfg = two_pool_cfg();
        let mut d = dynamics(&cfg);
        d.apply(&ScenarioEvent::LinkDegrade {
            pool: None,
            rtt_mult: 2.0,
            jitter_mult: 0.0,
            bandwidth_mult: 0.25,
        });
        assert_eq!(d.link(0).rtt_ms, 12.0);
        assert_eq!(d.link(5).rtt_ms, 40.0);
        assert_eq!(d.link(99).rtt_ms, 40.0); // default link scales too
        assert_eq!(d.link(0).jitter_ms, 0.0);
        assert!(d.link(0).bandwidth_mbps.is_infinite(), "∞ bandwidth stays ∞");
        d.apply(&ScenarioEvent::LinkRestore { pool: None });
        assert_eq!(d.link(99).rtt_ms, 20.0);
    }

    #[test]
    fn pool_transitions_fire_once() {
        let cfg = two_pool_cfg();
        let mut d = dynamics(&cfg);
        let down = ScenarioEvent::DrafterPoolDown { pool: 0 };
        assert_eq!(d.apply(&down), Some(PoolTransition::Down(0)));
        assert_eq!(d.apply(&down), None, "already down");
        assert!(d.drafter_down(2));
        assert!(!d.drafter_down(5));
        let up = ScenarioEvent::DrafterPoolUp { pool: 0 };
        assert_eq!(d.apply(&up), Some(PoolTransition::Up(0)));
        assert_eq!(d.apply(&up), None, "already up");
        assert!(!d.drafter_down(2));
    }

    #[test]
    fn target_slowdown_sets_and_restores() {
        let cfg = two_pool_cfg();
        let mut d = dynamics(&cfg);
        d.apply(&ScenarioEvent::TargetSlowdown { target: Some(1), mult: 3.0 });
        assert_eq!(d.target_mult(0), 1.0);
        assert_eq!(d.target_mult(1), 3.0);
        assert!(d.any_target_slowdown());
        d.apply(&ScenarioEvent::TargetSlowdown { target: None, mult: 1.0 });
        assert!(!d.any_target_slowdown());
    }

    #[test]
    fn target_availability_defaults_on_and_toggles() {
        let cfg = two_pool_cfg();
        let mut d = dynamics(&cfg);
        assert!(d.target_available(0));
        assert!(d.target_available(1));
        assert!(!d.target_available(9), "ids beyond the fleet are unavailable");
        assert_eq!(d.n_targets_available(), 2);
        d.set_target_available(1, false);
        assert!(!d.target_available(1));
        assert_eq!(d.n_targets_available(), 1);
        d.set_target_available(1, true);
        assert_eq!(d.n_targets_available(), 2);
        d.set_target_available(9, false); // out of range: ignored
        assert_eq!(d.n_targets_available(), 2);
    }

    #[test]
    fn rate_override_is_a_runtime_noop() {
        let cfg = two_pool_cfg();
        let mut d = dynamics(&cfg);
        assert_eq!(d.apply(&ScenarioEvent::RateOverride { rate_per_s: 50.0 }), None);
        assert_eq!(d.link(0).rtt_ms, 6.0);
    }
}
