//! The scripted-event timeline: typed, validated dynamics a scenario
//! injects into a running simulation.
//!
//! Events are declared in YAML (see `examples/scenarios/`) and scheduled
//! on the simulator's [`EventQueue`](crate::sim::EventQueue) at build
//! time as `Ev::Scenario(index)` entries; ties at one timestamp resolve
//! in timeline order. [`ScenarioEvent::RateOverride`] is the one
//! exception: arrivals are materialized at trace-generation time, so
//! rate overrides fold into the arrival envelope
//! ([`crate::scenario::ArrivalPlan`]) instead of firing at runtime.

use crate::util::json::Json;

/// One scripted change to the running system. All multipliers are
/// **absolute with respect to the t=0 baseline** — applying a degrade
/// twice does not compound, and `LinkRestore` / `mult: 1` returns the
/// exact baseline values (bit-for-bit).
#[derive(Clone, Debug, PartialEq)]
pub enum ScenarioEvent {
    /// Scale link parameters of one drafter pool (or every link plus the
    /// fallback default link when `pool` is `None`). An infinite
    /// baseline bandwidth stays infinite under any positive multiplier —
    /// degrade bandwidth only on finite-bandwidth links.
    LinkDegrade {
        /// Drafter-pool index; `None` = global.
        pool: Option<usize>,
        /// RTT multiplier (≥ 0).
        rtt_mult: f64,
        /// Jitter multiplier (≥ 0).
        jitter_mult: f64,
        /// Bandwidth multiplier (> 0).
        bandwidth_mult: f64,
    },
    /// Reset link parameters of a pool (or everything) to baseline.
    LinkRestore {
        /// Drafter-pool index; `None` = global.
        pool: Option<usize>,
    },
    /// Device failure: every drafter in the pool stops serving. Queued
    /// edge work is dropped and affected requests migrate to fused
    /// (cloud-only) execution until the pool comes back.
    DrafterPoolDown {
        /// Drafter-pool index.
        pool: usize,
    },
    /// Recovery: the pool's drafters resume; fused-parked requests
    /// migrate back through the normal per-round window decision.
    DrafterPoolUp {
        /// Drafter-pool index.
        pool: usize,
    },
    /// Co-tenant interference: scale one target's (or every target's)
    /// hardware latency by `mult` (`mult: 1` restores baseline).
    TargetSlowdown {
        /// Target device id; `None` = all targets.
        target: Option<usize>,
        /// Latency multiplier (> 0).
        mult: f64,
    },
    /// Pin the arrival envelope to a new rate from this timestamp onward
    /// (consumed at trace-generation time, not at runtime).
    RateOverride {
        /// New arrival rate, requests/second (> 0).
        rate_per_s: f64,
    },
    /// Pin **one request class's** arrival envelope to a new rate from
    /// this timestamp onward (consumed at trace-generation time, like
    /// [`ScenarioEvent::RateOverride`]). Requires a `classes:` block on
    /// the owning config declaring the named tier — an undeclared name
    /// is rejected at `Simulator::try_new` time, never silently ignored.
    ClassRateOverride {
        /// Tier name as declared in the `classes:` block.
        class: String,
        /// New arrival rate for that tier, requests/second (> 0).
        rate_per_s: f64,
    },
    /// Scripted capacity addition: provision `count` more cloud targets
    /// (cold-start delay applies; clamped to the autoscale `max`).
    /// Requires an `autoscale:` block on the owning config — the
    /// scheduled/scripted provisioning path of [`crate::autoscale`].
    TargetPoolUp {
        /// Targets to add (≥ 1).
        count: usize,
    },
    /// Scripted capacity removal: gracefully drain `count` targets
    /// (in-flight batches finish, queued work re-routes; clamped to the
    /// autoscale `min`). Requires an `autoscale:` block.
    TargetPoolDown {
        /// Targets to drain (≥ 1).
        count: usize,
    },
}

impl ScenarioEvent {
    /// Stable kind name (YAML `kind:` values).
    pub fn kind(&self) -> &'static str {
        match self {
            ScenarioEvent::LinkDegrade { .. } => "link_degrade",
            ScenarioEvent::LinkRestore { .. } => "link_restore",
            ScenarioEvent::DrafterPoolDown { .. } => "drafter_pool_down",
            ScenarioEvent::DrafterPoolUp { .. } => "drafter_pool_up",
            ScenarioEvent::TargetSlowdown { .. } => "target_slowdown",
            ScenarioEvent::RateOverride { .. } => "rate_override",
            ScenarioEvent::ClassRateOverride { .. } => "class_rate_override",
            ScenarioEvent::TargetPoolUp { .. } => "target_pool_up",
            ScenarioEvent::TargetPoolDown { .. } => "target_pool_down",
        }
    }
}

/// A [`ScenarioEvent`] with its firing time.
#[derive(Clone, Debug, PartialEq)]
pub struct TimedEvent {
    /// Simulation time the event fires, ms.
    pub at_ms: f64,
    /// What happens.
    pub event: ScenarioEvent,
}

impl TimedEvent {
    /// Parse one timeline entry. Strict: unknown keys are rejected —
    /// most event fields are optional with no-op defaults, so a typo'd
    /// field (`rtt_mlt: 8`) would otherwise silently neutralize the
    /// event while the scenario still labels and cache-keys the cell.
    pub fn from_json(j: &Json) -> Result<TimedEvent, String> {
        let at_ms = j
            .get("at_ms")
            .and_then(Json::as_f64)
            .ok_or("scenario event: missing number 'at_ms'")?;
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("scenario event: missing 'kind'")?;
        let allowed: &[&str] = match kind {
            "link_degrade" => &["pool", "rtt_mult", "jitter_mult", "bandwidth_mult"],
            "link_restore" => &["pool"],
            "drafter_pool_down" | "drafter_pool_up" => &["pool"],
            "target_slowdown" => &["target", "mult"],
            "rate_override" => &["rate_per_s"],
            "class_rate_override" => &["class", "rate_per_s"],
            "target_pool_up" | "target_pool_down" => &["count"],
            _ => &[], // unknown kind: rejected below with the full list
        };
        if let Json::Obj(pairs) = j {
            for (k, _) in pairs {
                if k != "at_ms" && k != "kind" && !allowed.contains(&k.as_str()) {
                    return Err(format!(
                        "scenario event ({kind}): unknown key '{k}' (known: at_ms, kind{})",
                        allowed
                            .iter()
                            .map(|a| format!(", {a}"))
                            .collect::<String>()
                    ));
                }
            }
        }
        let opt_usize = |key: &str| -> Result<Option<usize>, String> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_usize()
                    .map(Some)
                    .ok_or_else(|| format!("scenario event ({kind}): '{key}' must be an index")),
            }
        };
        let req_usize = |key: &str| -> Result<usize, String> {
            opt_usize(key)?
                .ok_or_else(|| format!("scenario event ({kind}): missing index '{key}'"))
        };
        let num = |key: &str, default: f64| -> Result<f64, String> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| format!("scenario event ({kind}): '{key}' must be a number")),
            }
        };
        let event = match kind {
            "link_degrade" => ScenarioEvent::LinkDegrade {
                pool: opt_usize("pool")?,
                rtt_mult: num("rtt_mult", 1.0)?,
                jitter_mult: num("jitter_mult", 1.0)?,
                bandwidth_mult: num("bandwidth_mult", 1.0)?,
            },
            "link_restore" => ScenarioEvent::LinkRestore { pool: opt_usize("pool")? },
            "drafter_pool_down" => ScenarioEvent::DrafterPoolDown { pool: req_usize("pool")? },
            "drafter_pool_up" => ScenarioEvent::DrafterPoolUp { pool: req_usize("pool")? },
            "target_slowdown" => ScenarioEvent::TargetSlowdown {
                target: opt_usize("target")?,
                mult: num("mult", 1.0)?,
            },
            "rate_override" => ScenarioEvent::RateOverride {
                rate_per_s: j
                    .get("rate_per_s")
                    .and_then(Json::as_f64)
                    .ok_or("scenario event (rate_override): missing number 'rate_per_s'")?,
            },
            "class_rate_override" => ScenarioEvent::ClassRateOverride {
                class: j
                    .get("class")
                    .and_then(Json::as_str)
                    .ok_or("scenario event (class_rate_override): missing 'class'")?
                    .to_string(),
                rate_per_s: j.get("rate_per_s").and_then(Json::as_f64).ok_or(
                    "scenario event (class_rate_override): missing number 'rate_per_s'",
                )?,
            },
            "target_pool_up" => ScenarioEvent::TargetPoolUp {
                count: opt_usize("count")?.unwrap_or(1),
            },
            "target_pool_down" => ScenarioEvent::TargetPoolDown {
                count: opt_usize("count")?.unwrap_or(1),
            },
            other => {
                return Err(format!(
                    "scenario event: unknown kind '{other}' (known: link_degrade, \
                     link_restore, drafter_pool_down, drafter_pool_up, target_slowdown, \
                     rate_override, class_rate_override, target_pool_up, target_pool_down)"
                ))
            }
        };
        Ok(TimedEvent { at_ms, event })
    }

    /// Canonical JSON (fixed key order — part of the cache key).
    pub fn to_canonical_json(&self) -> Json {
        let j = Json::obj()
            .with("at_ms", self.at_ms.into())
            .with("kind", self.event.kind().into());
        match self.event {
            ScenarioEvent::LinkDegrade { pool, rtt_mult, jitter_mult, bandwidth_mult } => {
                let mut j = j;
                if let Some(p) = pool {
                    j.set("pool", p.into());
                }
                j.with("rtt_mult", rtt_mult.into())
                    .with("jitter_mult", jitter_mult.into())
                    .with("bandwidth_mult", bandwidth_mult.into())
            }
            ScenarioEvent::LinkRestore { pool } => {
                let mut j = j;
                if let Some(p) = pool {
                    j.set("pool", p.into());
                }
                j
            }
            ScenarioEvent::DrafterPoolDown { pool } => j.with("pool", pool.into()),
            ScenarioEvent::DrafterPoolUp { pool } => j.with("pool", pool.into()),
            ScenarioEvent::TargetSlowdown { target, mult } => {
                let mut j = j;
                if let Some(t) = target {
                    j.set("target", t.into());
                }
                j.with("mult", mult.into())
            }
            ScenarioEvent::RateOverride { rate_per_s } => {
                j.with("rate_per_s", rate_per_s.into())
            }
            ScenarioEvent::ClassRateOverride { ref class, rate_per_s } => j
                .with("class", class.as_str().into())
                .with("rate_per_s", rate_per_s.into()),
            ScenarioEvent::TargetPoolUp { count } => j.with("count", count.into()),
            ScenarioEvent::TargetPoolDown { count } => j.with("count", count.into()),
        }
    }

    /// Sanity checks against the deployment shape.
    pub fn validate(&self, n_drafter_pools: usize, n_targets: usize) -> Result<(), String> {
        if !self.at_ms.is_finite() || self.at_ms < 0.0 {
            return Err(format!(
                "scenario event ({}): at_ms must be finite and ≥ 0",
                self.event.kind()
            ));
        }
        let pool_ok = |p: Option<usize>| -> Result<(), String> {
            if let Some(p) = p {
                if p >= n_drafter_pools {
                    return Err(format!(
                        "scenario event ({}): pool {p} out of range ({} drafter pools)",
                        self.event.kind(),
                        n_drafter_pools
                    ));
                }
            }
            Ok(())
        };
        let mult_ok = |name: &str, x: f64, allow_zero: bool| -> Result<(), String> {
            let lo_ok = if allow_zero { x >= 0.0 } else { x > 0.0 };
            if !x.is_finite() || !lo_ok {
                return Err(format!(
                    "scenario event ({}): {name} must be finite and {}",
                    self.event.kind(),
                    if allow_zero { "≥ 0" } else { "> 0" }
                ));
            }
            Ok(())
        };
        match self.event {
            ScenarioEvent::LinkDegrade { pool, rtt_mult, jitter_mult, bandwidth_mult } => {
                pool_ok(pool)?;
                mult_ok("rtt_mult", rtt_mult, true)?;
                mult_ok("jitter_mult", jitter_mult, true)?;
                mult_ok("bandwidth_mult", bandwidth_mult, false)
            }
            ScenarioEvent::LinkRestore { pool } => pool_ok(pool),
            ScenarioEvent::DrafterPoolDown { pool } | ScenarioEvent::DrafterPoolUp { pool } => {
                pool_ok(Some(pool))
            }
            ScenarioEvent::TargetSlowdown { target, mult } => {
                if let Some(t) = target {
                    if t >= n_targets {
                        return Err(format!(
                            "scenario event (target_slowdown): target {t} out of range \
                             ({n_targets} targets)"
                        ));
                    }
                }
                mult_ok("mult", mult, false)
            }
            ScenarioEvent::RateOverride { rate_per_s } => {
                mult_ok("rate_per_s", rate_per_s, false)
            }
            ScenarioEvent::ClassRateOverride { ref class, rate_per_s } => {
                if class.is_empty() {
                    return Err(
                        "scenario event (class_rate_override): class must be non-empty".into()
                    );
                }
                mult_ok("rate_per_s", rate_per_s, false)
            }
            ScenarioEvent::TargetPoolUp { count } | ScenarioEvent::TargetPoolDown { count } => {
                if count == 0 {
                    return Err(format!(
                        "scenario event ({}): count must be at least 1",
                        self.event.kind()
                    ));
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(ev: TimedEvent) {
        let j = ev.to_canonical_json();
        let back = TimedEvent::from_json(&j).unwrap();
        assert_eq!(ev, back);
        assert_eq!(
            j.to_string_canonical(),
            back.to_canonical_json().to_string_canonical()
        );
    }

    #[test]
    fn json_roundtrip_all_kinds() {
        roundtrip(TimedEvent {
            at_ms: 1_000.0,
            event: ScenarioEvent::LinkDegrade {
                pool: Some(1),
                rtt_mult: 8.0,
                jitter_mult: 2.0,
                bandwidth_mult: 0.25,
            },
        });
        roundtrip(TimedEvent {
            at_ms: 2_000.0,
            event: ScenarioEvent::LinkDegrade {
                pool: None,
                rtt_mult: 4.0,
                jitter_mult: 1.0,
                bandwidth_mult: 1.0,
            },
        });
        roundtrip(TimedEvent { at_ms: 3_000.0, event: ScenarioEvent::LinkRestore { pool: None } });
        roundtrip(TimedEvent { at_ms: 0.0, event: ScenarioEvent::DrafterPoolDown { pool: 0 } });
        roundtrip(TimedEvent { at_ms: 5.5, event: ScenarioEvent::DrafterPoolUp { pool: 2 } });
        roundtrip(TimedEvent {
            at_ms: 9.0,
            event: ScenarioEvent::TargetSlowdown { target: Some(3), mult: 2.5 },
        });
        roundtrip(TimedEvent {
            at_ms: 10.0,
            event: ScenarioEvent::RateOverride { rate_per_s: 33.0 },
        });
        roundtrip(TimedEvent {
            at_ms: 10.5,
            event: ScenarioEvent::ClassRateOverride {
                class: "batch".to_string(),
                rate_per_s: 80.0,
            },
        });
        roundtrip(TimedEvent {
            at_ms: 11.0,
            event: ScenarioEvent::TargetPoolUp { count: 2 },
        });
        roundtrip(TimedEvent {
            at_ms: 12.0,
            event: ScenarioEvent::TargetPoolDown { count: 1 },
        });
    }

    #[test]
    fn target_pool_events_default_count_and_validate() {
        let j = Json::obj()
            .with("at_ms", 5.0.into())
            .with("kind", "target_pool_up".into());
        let ev = TimedEvent::from_json(&j).unwrap();
        assert_eq!(ev.event, ScenarioEvent::TargetPoolUp { count: 1 });
        let zero = TimedEvent {
            at_ms: 5.0,
            event: ScenarioEvent::TargetPoolDown { count: 0 },
        };
        assert!(zero.validate(1, 2).unwrap_err().contains("count"));
        // Foreign keys rejected.
        let bad = Json::obj()
            .with("at_ms", 5.0.into())
            .with("kind", "target_pool_down".into())
            .with("pool", 1.into());
        assert!(TimedEvent::from_json(&bad).unwrap_err().contains("unknown key"));
    }

    #[test]
    fn degrade_multipliers_default_to_one() {
        let j = Json::obj()
            .with("at_ms", 100.0.into())
            .with("kind", "link_degrade".into())
            .with("rtt_mult", 6.0.into());
        let ev = TimedEvent::from_json(&j).unwrap();
        assert_eq!(
            ev.event,
            ScenarioEvent::LinkDegrade {
                pool: None,
                rtt_mult: 6.0,
                jitter_mult: 1.0,
                bandwidth_mult: 1.0,
            }
        );
    }

    #[test]
    fn unknown_kind_and_missing_fields_rejected() {
        let bad = Json::obj().with("at_ms", 1.0.into()).with("kind", "explode".into());
        assert!(TimedEvent::from_json(&bad).unwrap_err().contains("unknown kind"));
        let no_pool = Json::obj()
            .with("at_ms", 1.0.into())
            .with("kind", "drafter_pool_down".into());
        assert!(TimedEvent::from_json(&no_pool).unwrap_err().contains("pool"));
        let no_at = Json::obj().with("kind", "link_restore".into());
        assert!(TimedEvent::from_json(&no_at).unwrap_err().contains("at_ms"));
    }

    #[test]
    fn typoed_optional_fields_rejected_not_defaulted() {
        // `rtt_mlt` must not silently parse as a no-op degrade.
        let typo = Json::obj()
            .with("at_ms", 1.0.into())
            .with("kind", "link_degrade".into())
            .with("rtt_mlt", 8.0.into());
        let err = TimedEvent::from_json(&typo).unwrap_err();
        assert!(err.contains("unknown key 'rtt_mlt'"), "{err}");
        // Fields of *other* kinds are unknown here too.
        let wrong_kind = Json::obj()
            .with("at_ms", 1.0.into())
            .with("kind", "target_slowdown".into())
            .with("pool", 0.into());
        assert!(TimedEvent::from_json(&wrong_kind).unwrap_err().contains("unknown key"));
    }

    #[test]
    fn validation_checks_ranges() {
        let ev = |event| TimedEvent { at_ms: 10.0, event };
        assert!(ev(ScenarioEvent::DrafterPoolDown { pool: 2 }).validate(2, 4).is_err());
        assert!(ev(ScenarioEvent::DrafterPoolDown { pool: 1 }).validate(2, 4).is_ok());
        assert!(ev(ScenarioEvent::TargetSlowdown { target: Some(4), mult: 2.0 })
            .validate(2, 4)
            .is_err());
        assert!(ev(ScenarioEvent::TargetSlowdown { target: None, mult: 0.0 })
            .validate(2, 4)
            .is_err());
        assert!(ev(ScenarioEvent::LinkDegrade {
            pool: None,
            rtt_mult: f64::NAN,
            jitter_mult: 1.0,
            bandwidth_mult: 1.0,
        })
        .validate(2, 4)
        .is_err());
        assert!(ev(ScenarioEvent::LinkDegrade {
            pool: None,
            rtt_mult: 0.0, // zero RTT is allowed (ideal link)
            jitter_mult: 0.0,
            bandwidth_mult: 0.5,
        })
        .validate(2, 4)
        .is_ok());
        assert!(ev(ScenarioEvent::RateOverride { rate_per_s: -1.0 }).validate(2, 4).is_err());
        assert!(ev(ScenarioEvent::ClassRateOverride {
            class: String::new(),
            rate_per_s: 5.0,
        })
        .validate(2, 4)
        .is_err());
        assert!(ev(ScenarioEvent::ClassRateOverride {
            class: "interactive".to_string(),
            rate_per_s: 0.0,
        })
        .validate(2, 4)
        .is_err());
        assert!(ev(ScenarioEvent::ClassRateOverride {
            class: "interactive".to_string(),
            rate_per_s: 5.0,
        })
        .validate(2, 4)
        .is_ok());
        let past = TimedEvent {
            at_ms: -1.0,
            event: ScenarioEvent::LinkRestore { pool: None },
        };
        assert!(past.validate(2, 4).is_err());
    }
}
