//! Workload trace schema (paper §3.2, Table 1).
//!
//! A trace record carries everything needed to drive one request through
//! DSD-Sim: prompt/output lengths, the ground-truth per-token acceptance
//! sequence for the draft–target pair, arrival time, and the drafter it
//! lands on.

use crate::util::json::Json;

/// One request in a workload trace (Table 1 of the paper).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Prompt length in tokens.
    pub prompt_length: u32,
    /// Number of output tokens to generate.
    pub output_length: u32,
    /// Ground-truth acceptance outcome per *draft* token: `acceptance_seq[i]`
    /// says whether the i-th draft token proposed for this request would be
    /// accepted by the target. Consumed sequentially as speculation windows
    /// advance; length ≥ `output_length` (regenerated cyclically if shorter).
    pub acceptance_seq: Vec<bool>,
    /// Arrival time, milliseconds from trace start.
    pub arrival_time_ms: f64,
    /// Edge drafter device the request arrives at.
    pub drafter_id: usize,
    /// Request-class index (tier position in the `classes:` block; 0 for
    /// single-tenant traces). Serialized only when nonzero, so classless
    /// traces keep their historical Table-1 bytes.
    pub class_id: usize,
}

impl TraceRecord {
    /// Serialize to the JSON schema of Table 1.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("prompt_length", (self.prompt_length as u64).into())
            .with("output_length", (self.output_length as u64).into())
            .with(
                "acceptance_seq",
                Json::Arr(
                    self.acceptance_seq
                        .iter()
                        .map(|&b| Json::Num(if b { 1.0 } else { 0.0 }))
                        .collect(),
                ),
            )
            .with("arrival_time_ms", self.arrival_time_ms.into())
            .with("drafter_id", self.drafter_id.into());
        if self.class_id != 0 {
            j.set("class_id", self.class_id.into());
        }
        j
    }

    /// Parse from the Table-1 JSON schema.
    pub fn from_json(j: &Json) -> Result<TraceRecord, String> {
        let field = |k: &str| j.get(k).ok_or_else(|| format!("missing field '{k}'"));
        let acceptance_seq = field("acceptance_seq")?
            .as_arr()
            .ok_or("acceptance_seq must be an array")?
            .iter()
            .map(|x| x.as_f64().map(|v| v != 0.0))
            .collect::<Option<Vec<bool>>>()
            .ok_or("acceptance_seq entries must be 0/1")?;
        Ok(TraceRecord {
            prompt_length: field("prompt_length")?
                .as_u64()
                .ok_or("prompt_length must be a non-negative integer")? as u32,
            output_length: field("output_length")?
                .as_u64()
                .ok_or("output_length must be a non-negative integer")? as u32,
            acceptance_seq,
            arrival_time_ms: field("arrival_time_ms")?
                .as_f64()
                .ok_or("arrival_time_ms must be a number")?,
            drafter_id: field("drafter_id")?
                .as_usize()
                .ok_or("drafter_id must be a non-negative integer")?,
            // Optional: absent on every trace written before request
            // classes existed.
            class_id: match j.get("class_id") {
                Some(v) => v
                    .as_usize()
                    .ok_or("class_id must be a non-negative integer")?,
                None => 0,
            },
        })
    }

    /// Empirical acceptance rate of this record's sequence.
    pub fn acceptance_rate(&self) -> f64 {
        if self.acceptance_seq.is_empty() {
            return 0.0;
        }
        self.acceptance_seq.iter().filter(|&&b| b).count() as f64
            / self.acceptance_seq.len() as f64
    }
}

/// A full workload trace plus its provenance.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    /// Dataset name (gsm8k / cnndm / humaneval / custom).
    pub dataset: String,
    /// Records sorted by arrival time.
    pub records: Vec<TraceRecord>,
}

impl Trace {
    /// Number of requests.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether the trace is empty.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Mean prompt length.
    pub fn mean_prompt(&self) -> f64 {
        crate::util::stats::mean(
            &self
                .records
                .iter()
                .map(|r| r.prompt_length as f64)
                .collect::<Vec<_>>(),
        )
    }

    /// Mean output length.
    pub fn mean_output(&self) -> f64 {
        crate::util::stats::mean(
            &self
                .records
                .iter()
                .map(|r| r.output_length as f64)
                .collect::<Vec<_>>(),
        )
    }

    /// Mean acceptance rate across records.
    pub fn mean_acceptance(&self) -> f64 {
        crate::util::stats::mean(
            &self
                .records
                .iter()
                .map(|r| r.acceptance_rate())
                .collect::<Vec<_>>(),
        )
    }

    /// Assert arrival times are non-decreasing.
    pub fn validate(&self) -> Result<(), String> {
        for w in self.records.windows(2) {
            if w[1].arrival_time_ms < w[0].arrival_time_ms {
                return Err("trace arrivals are not sorted".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TraceRecord {
        TraceRecord {
            prompt_length: 27,
            output_length: 94,
            acceptance_seq: vec![true, false, true],
            arrival_time_ms: 5.3,
            drafter_id: 38,
            class_id: 0,
        }
    }

    #[test]
    fn class_id_roundtrips_and_stays_off_classless_records() {
        let classless = sample().to_json();
        assert!(classless.get("class_id").is_none(), "classless bytes unchanged");
        let mut r = sample();
        r.class_id = 2;
        let j = r.to_json();
        assert_eq!(j.get("class_id").and_then(Json::as_usize), Some(2));
        assert_eq!(TraceRecord::from_json(&j).unwrap(), r);
        // Absent field parses as class 0 (pre-classes traces).
        assert_eq!(TraceRecord::from_json(&classless).unwrap().class_id, 0);
    }

    #[test]
    fn json_roundtrip() {
        let r = sample();
        let j = r.to_json();
        let back = TraceRecord::from_json(&j).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn schema_matches_table1() {
        let j = sample().to_json();
        for field in [
            "prompt_length",
            "output_length",
            "acceptance_seq",
            "arrival_time_ms",
            "drafter_id",
        ] {
            assert!(j.get(field).is_some(), "missing {field}");
        }
    }

    #[test]
    fn missing_field_rejected() {
        let mut j = sample().to_json();
        j = match j {
            Json::Obj(pairs) => Json::Obj(
                pairs
                    .into_iter()
                    .filter(|(k, _)| k != "output_length")
                    .collect(),
            ),
            _ => unreachable!(),
        };
        assert!(TraceRecord::from_json(&j).is_err());
    }

    #[test]
    fn acceptance_rate() {
        assert!((sample().acceptance_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_validation() {
        let mut t = Trace {
            dataset: "x".into(),
            records: vec![sample(), sample()],
        };
        assert!(t.validate().is_ok());
        t.records[1].arrival_time_ms = 1.0;
        assert!(t.validate().is_err());
    }
}
