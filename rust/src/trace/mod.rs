//! Workloads and trace model (paper §3.2): Table-1 record schema,
//! statistical generators for the GSM8K / CNN-DailyMail / HumanEval
//! benchmark profiles, and JSONL trace IO.

pub mod datasets;
pub mod io;
pub mod schema;

pub use datasets::{all_datasets, dataset_by_name, DatasetProfile, CNNDM, GSM8K, HUMANEVAL};
pub use schema::{Trace, TraceRecord};
