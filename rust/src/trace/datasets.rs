//! Statistical workload generators for the three paper benchmarks.
//!
//! The paper derives traces from GSM8K (reasoning), CNN/DailyMail
//! (summarization) and HumanEval (code generation), capturing acceptance
//! sequences from hardware profiling (§3.2). We have neither the datasets'
//! tokenized prompts nor a GPU pair to profile, so each benchmark is
//! replaced by a *statistical profile*: log-normal prompt/output length
//! distributions matching the benchmark's character (GSM8K short-in /
//! medium-out, CNN/DM long-in / short-out, HumanEval medium-in / long-out)
//! and a two-state Markov acceptance process whose stationary rate and
//! burstiness reflect the draft–target agreement typical for that task
//! family. The simulator replays `acceptance_seq` verbatim either way, so
//! scheduler dynamics depend only on these statistics (DESIGN.md §4).

use super::schema::{Trace, TraceRecord};
use crate::scenario::ArrivalPlan;
use crate::util::rng::Pcg64;

/// Statistical profile of one benchmark workload.
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    /// Dataset name.
    pub name: &'static str,
    /// Log-normal (mu, sigma) of prompt token length.
    pub prompt_mu_sigma: (f64, f64),
    /// Log-normal (mu, sigma) of output token length.
    pub output_mu_sigma: (f64, f64),
    /// Clamp bounds on prompt length.
    pub prompt_range: (u32, u32),
    /// Clamp bounds on output length.
    pub output_range: (u32, u32),
    /// Stationary draft-token acceptance rate α.
    pub acceptance_rate: f64,
    /// Lag-1 autocorrelation of the acceptance process (bursty
    /// agreement/disagreement runs).
    pub acceptance_corr: f64,
}

/// GSM8K: short reasoning prompts, medium outputs, high acceptance (the
/// draft model tracks chain-of-thought arithmetic phrasing well).
pub const GSM8K: DatasetProfile = DatasetProfile {
    name: "gsm8k",
    prompt_mu_sigma: (4.0, 0.35),  // median ~55 tokens
    output_mu_sigma: (4.55, 0.30), // median ~95 tokens
    prompt_range: (16, 256),
    output_range: (24, 320),
    acceptance_rate: 0.86,
    acceptance_corr: 0.30,
};

/// CNN/DailyMail: long article prompts, short summaries, lower acceptance
/// (abstractive summarization diverges more between models).
pub const CNNDM: DatasetProfile = DatasetProfile {
    name: "cnndm",
    prompt_mu_sigma: (6.62, 0.45), // median ~750 tokens
    output_mu_sigma: (4.06, 0.30), // median ~58 tokens
    prompt_range: (200, 3000),
    output_range: (20, 160),
    acceptance_rate: 0.66,
    acceptance_corr: 0.25,
};

/// HumanEval: medium prompts, medium-long code completions, high-ish
/// acceptance (code has low-entropy continuations).
pub const HUMANEVAL: DatasetProfile = DatasetProfile {
    name: "humaneval",
    prompt_mu_sigma: (4.95, 0.40), // median ~140 tokens
    output_mu_sigma: (4.75, 0.32), // median ~115 tokens
    prompt_range: (40, 512),
    output_range: (32, 320),
    acceptance_rate: 0.78,
    acceptance_corr: 0.35,
};

/// Look up a profile by name.
pub fn dataset_by_name(name: &str) -> Option<&'static DatasetProfile> {
    match name.to_ascii_lowercase().as_str() {
        "gsm8k" => Some(&GSM8K),
        "cnndm" | "cnn_dailymail" | "cnn/dailymail" => Some(&CNNDM),
        "humaneval" => Some(&HUMANEVAL),
        _ => None,
    }
}

/// The three paper benchmarks.
pub fn all_datasets() -> [&'static DatasetProfile; 3] {
    [&GSM8K, &CNNDM, &HUMANEVAL]
}

impl DatasetProfile {
    /// Sample one request's lengths.
    fn sample_lengths(&self, rng: &mut Pcg64) -> (u32, u32) {
        let (pm, ps) = self.prompt_mu_sigma;
        let (om, os) = self.output_mu_sigma;
        let p = rng.lognormal(pm, ps).round() as u32;
        let o = rng.lognormal(om, os).round() as u32;
        (
            p.clamp(self.prompt_range.0, self.prompt_range.1),
            o.clamp(self.output_range.0, self.output_range.1),
        )
    }

    /// Sample an acceptance sequence of length `n` from the two-state
    /// Markov process with stationary rate α and lag-1 correlation ρ:
    /// `P(1→1) = α + ρ(1-α)`, `P(0→1) = α(1-ρ)`.
    pub fn sample_acceptance(&self, rng: &mut Pcg64, n: usize) -> Vec<bool> {
        let a = self.acceptance_rate;
        let rho = self.acceptance_corr;
        let p_stay = a + rho * (1.0 - a);
        let p_gain = a * (1.0 - rho);
        let mut seq = Vec::with_capacity(n);
        let mut state = rng.bernoulli(a);
        for _ in 0..n {
            seq.push(state);
            state = if state {
                rng.bernoulli(p_stay)
            } else {
                rng.bernoulli(p_gain)
            };
        }
        seq
    }

    /// Generate a full trace: `n` requests, Poisson arrivals at
    /// `rate_per_s` (requests/second across the whole system), drafter ids
    /// uniform over `n_drafters` (paper §3.2, synthetic arrival mode).
    /// Delegates to [`DatasetProfile::generate_plan`] with a stationary
    /// plan — the two are bit-identical by construction.
    pub fn generate(
        &self,
        n: usize,
        rate_per_s: f64,
        n_drafters: usize,
        seed: u64,
    ) -> Trace {
        self.generate_plan(n, &ArrivalPlan::constant(rate_per_s), n_drafters, seed)
    }

    /// Generate a trace whose arrivals follow a scenario
    /// [`ArrivalPlan`] (time-varying rate envelopes, thinning-sampled;
    /// see [`crate::scenario::arrivals`]). Per-request draws interleave
    /// with arrival draws exactly as in the legacy generator, so a
    /// constant plan reproduces the historical traces bit for bit.
    pub fn generate_plan(
        &self,
        n: usize,
        plan: &ArrivalPlan,
        n_drafters: usize,
        seed: u64,
    ) -> Trace {
        let mut rng = Pcg64::new(seed ^ fxhash(self.name));
        let mut sampler = plan.sampler();
        let mut t_ms = 0.0f64;
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            t_ms = sampler.next_after(t_ms, &mut rng);
            let (prompt_length, output_length) = self.sample_lengths(&mut rng);
            // Draft tokens consumed can exceed output_length (rejected
            // tokens still consume sequence entries); 2x + slack is ample.
            let seq_len = (output_length as usize) * 2 + 16;
            let acceptance_seq = self.sample_acceptance(&mut rng, seq_len);
            records.push(TraceRecord {
                prompt_length,
                output_length,
                acceptance_seq,
                arrival_time_ms: t_ms,
                drafter_id: rng.index(n_drafters.max(1)),
                class_id: 0,
            });
        }
        Trace {
            dataset: self.name.to_string(),
            records,
        }
    }

    /// Generate one multi-tenant trace: `n` requests across `plans.len()`
    /// request classes, each class drawing arrivals from its own
    /// [`ArrivalPlan`] with its own rng stream, merged globally by
    /// arrival time (ties break toward the lower class index, i.e. the
    /// higher-priority tier declared first). Each class's draw sequence
    /// is the same interleave as [`DatasetProfile::generate_plan`] — one
    /// arrival draw, then the per-request payload draws — so a
    /// single-class call reproduces `generate_plan` with a perturbed
    /// seed, and adding a tier never disturbs another tier's payloads.
    pub fn generate_classes(
        &self,
        n: usize,
        plans: &[ArrivalPlan],
        n_drafters: usize,
        seed: u64,
    ) -> Trace {
        assert!(!plans.is_empty(), "generate_classes needs at least one class plan");
        // Independent per-class streams: same dataset hash, distinct odd
        // multiplier per tier so streams never collide across seeds.
        let mut rngs: Vec<Pcg64> = (0..plans.len())
            .map(|ci| {
                Pcg64::new(
                    seed ^ fxhash(self.name)
                        ^ (ci as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                )
            })
            .collect();
        let mut samplers: Vec<_> = plans.iter().map(|p| p.sampler()).collect();
        // Pre-draw each class's first arrival so the merge loop always
        // compares concrete next-arrival times.
        let mut next_t: Vec<f64> = samplers
            .iter_mut()
            .zip(rngs.iter_mut())
            .map(|(s, rng)| s.next_after(0.0, rng))
            .collect();
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            let mut ci = 0usize;
            for (k, &t) in next_t.iter().enumerate().skip(1) {
                if t < next_t[ci] {
                    ci = k;
                }
            }
            let t_ms = next_t[ci];
            let rng = &mut rngs[ci];
            let (prompt_length, output_length) = self.sample_lengths(rng);
            let seq_len = (output_length as usize) * 2 + 16;
            let acceptance_seq = self.sample_acceptance(rng, seq_len);
            records.push(TraceRecord {
                prompt_length,
                output_length,
                acceptance_seq,
                arrival_time_ms: t_ms,
                drafter_id: rng.index(n_drafters.max(1)),
                class_id: ci,
            });
            next_t[ci] = samplers[ci].next_after(t_ms, rng);
        }
        Trace {
            dataset: self.name.to_string(),
            records,
        }
    }
}

/// Tiny FNV-style hash to derive per-dataset seeds.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(dataset_by_name("GSM8K").unwrap().name, "gsm8k");
        assert_eq!(dataset_by_name("cnn/dailymail").unwrap().name, "cnndm");
        assert!(dataset_by_name("wikitext").is_none());
    }

    #[test]
    fn generated_traces_match_profile_statistics() {
        for ds in all_datasets() {
            let t = ds.generate(2000, 50.0, 100, 7);
            assert_eq!(t.len(), 2000);
            t.validate().unwrap();
            let acc = t.mean_acceptance();
            assert!(
                (acc - ds.acceptance_rate).abs() < 0.03,
                "{}: acc={acc} want≈{}",
                ds.name,
                ds.acceptance_rate
            );
            // Median of lognormal = exp(mu); mean of clamped sample should
            // land within a factor ~1.5 of it.
            let want_p = ds.prompt_mu_sigma.0.exp();
            assert!(
                t.mean_prompt() > want_p * 0.7 && t.mean_prompt() < want_p * 1.6,
                "{}: prompt mean {} vs median {want_p}",
                ds.name,
                t.mean_prompt()
            );
        }
    }

    #[test]
    fn dataset_characters_are_distinct() {
        let g = GSM8K.generate(1000, 50.0, 10, 1);
        let c = CNNDM.generate(1000, 50.0, 10, 1);
        let h = HUMANEVAL.generate(1000, 50.0, 10, 1);
        // CNN/DM: longest prompts, shortest outputs; HumanEval: longest
        // outputs.
        assert!(c.mean_prompt() > 3.0 * g.mean_prompt());
        assert!(h.mean_output() > g.mean_output());
        assert!(c.mean_output() < g.mean_output());
    }

    #[test]
    fn arrival_rate_matches_poisson() {
        let t = GSM8K.generate(5000, 100.0, 10, 3);
        let span_s = t.records.last().unwrap().arrival_time_ms / 1000.0;
        let rate = t.len() as f64 / span_s;
        assert!((rate - 100.0).abs() < 8.0, "rate={rate}");
    }

    #[test]
    fn acceptance_autocorrelation_present() {
        let mut rng = Pcg64::new(5);
        let seq = HUMANEVAL.sample_acceptance(&mut rng, 100_000);
        let xs: Vec<f64> = seq.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        let mean = crate::util::stats::mean(&xs);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..xs.len() {
            den += (xs[i] - mean) * (xs[i] - mean);
            if i + 1 < xs.len() {
                num += (xs[i] - mean) * (xs[i + 1] - mean);
            }
        }
        let lag1 = num / den;
        assert!(
            (lag1 - HUMANEVAL.acceptance_corr).abs() < 0.05,
            "lag1={lag1}"
        );
    }

    #[test]
    fn generation_is_deterministic() {
        let a = GSM8K.generate(50, 20.0, 5, 9);
        let b = GSM8K.generate(50, 20.0, 5, 9);
        assert_eq!(a.records, b.records);
    }

    #[test]
    fn constant_plan_is_bit_identical_to_legacy_generation() {
        // The legacy draw sequence, reproduced inline: one exponential
        // per arrival interleaved with the per-request draws. The
        // plan-driven generator must match record for record, bit for
        // bit (the scenario engine's no-regression contract).
        let plan = ArrivalPlan::constant(20.0);
        let via_plan = GSM8K.generate_plan(50, &plan, 5, 9);
        let legacy = GSM8K.generate(50, 20.0, 5, 9);
        assert_eq!(via_plan.records, legacy.records);
        for (a, b) in via_plan.records.iter().zip(&legacy.records) {
            assert!(a.arrival_time_ms == b.arrival_time_ms, "bit-identical arrivals");
        }
    }

    #[test]
    fn spike_plan_concentrates_arrivals() {
        use crate::scenario::ArrivalProcess;
        let plan = ArrivalPlan {
            process: ArrivalProcess::Spike {
                base_per_s: 10.0,
                peak_per_s: 200.0,
                t_start_ms: 1_000.0,
                t_end_ms: 2_000.0,
            },
            overrides: Vec::new(),
        };
        let t = GSM8K.generate_plan(400, &plan, 8, 3);
        t.validate().unwrap();
        let in_spike = t
            .records
            .iter()
            .filter(|r| (1_000.0..2_000.0).contains(&r.arrival_time_ms))
            .count();
        // 1 s at 200/s dominates the surrounding 10/s base traffic.
        assert!(in_spike > 120, "in_spike={in_spike}");
    }

    #[test]
    fn class_traces_merge_sorted_and_deterministic() {
        let plans = vec![ArrivalPlan::constant(20.0), ArrivalPlan::constant(5.0)];
        let a = GSM8K.generate_classes(400, &plans, 8, 9);
        let b = GSM8K.generate_classes(400, &plans, 8, 9);
        assert_eq!(a.records, b.records);
        a.validate().unwrap();
        let n0 = a.records.iter().filter(|r| r.class_id == 0).count();
        let n1 = a.records.iter().filter(|r| r.class_id == 1).count();
        assert_eq!(n0 + n1, 400);
        assert!(n0 > 0 && n1 > 0, "both classes arrive: {n0}/{n1}");
        // 20/s vs 5/s → class 0 dominates roughly 4:1.
        assert!(n0 > n1 * 2, "rate split: {n0} vs {n1}");
    }

    #[test]
    fn class_streams_are_independent() {
        // Adding a second tier must not disturb the first tier's payload
        // draws: class 0's records keep identical lengths/acceptance when
        // tier 1's rate changes (only the merge order can move them).
        let lo = vec![ArrivalPlan::constant(20.0), ArrivalPlan::constant(2.0)];
        let hi = vec![ArrivalPlan::constant(20.0), ArrivalPlan::constant(50.0)];
        let a = GSM8K.generate_classes(300, &lo, 8, 9);
        let b = GSM8K.generate_classes(300, &hi, 8, 9);
        let pa: Vec<_> = a
            .records
            .iter()
            .filter(|r| r.class_id == 0)
            .map(|r| (r.prompt_length, r.output_length, r.arrival_time_ms.to_bits()))
            .collect();
        let pb: Vec<_> = b
            .records
            .iter()
            .filter(|r| r.class_id == 0)
            .map(|r| (r.prompt_length, r.output_length, r.arrival_time_ms.to_bits()))
            .collect();
        let shared = pa.len().min(pb.len());
        assert!(shared > 50, "enough class-0 arrivals to compare: {shared}");
        assert_eq!(pa[..shared], pb[..shared]);
    }

    #[test]
    fn drafter_ids_cover_pool() {
        let t = GSM8K.generate(2000, 50.0, 8, 11);
        let mut seen = vec![false; 8];
        for r in &t.records {
            seen[r.drafter_id] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
