//! Trace (de)serialization: JSONL on disk, one Table-1 record per line.

use super::schema::{Trace, TraceRecord};
use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

/// Write a trace to a JSONL file (first line is a header object).
pub fn write_jsonl(trace: &Trace, path: &Path) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let header = Json::obj()
        .with("dataset", trace.dataset.as_str().into())
        .with("count", trace.records.len().into());
    writeln!(f, "{}", header.to_string_compact())?;
    for r in &trace.records {
        writeln!(f, "{}", r.to_json().to_string_compact())?;
    }
    Ok(())
}

/// Read a trace from a JSONL file produced by [`write_jsonl`].
pub fn read_jsonl(path: &Path) -> Result<Trace, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let lines = Json::parse_lines(&text).map_err(|e| e.to_string())?;
    if lines.is_empty() {
        return Err("empty trace file".into());
    }
    let dataset = lines[0]
        .get("dataset")
        .and_then(Json::as_str)
        .unwrap_or("custom")
        .to_string();
    let records = lines[1..]
        .iter()
        .map(TraceRecord::from_json)
        .collect::<Result<Vec<_>, _>>()?;
    let t = Trace { dataset, records };
    t.validate()?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::datasets::GSM8K;

    #[test]
    fn jsonl_roundtrip() {
        let t = GSM8K.generate(25, 10.0, 4, 1);
        let dir = std::env::temp_dir().join("dsd_trace_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        write_jsonl(&t, &path).unwrap();
        let back = read_jsonl(&path).unwrap();
        assert_eq!(back.dataset, "gsm8k");
        assert_eq!(back.records, t.records);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_file_rejected() {
        let dir = std::env::temp_dir().join("dsd_trace_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"dataset\":\"x\"}\n{\"nope\": 1}\n").unwrap();
        assert!(read_jsonl(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
