//! Core speculative-decoding mathematics and window semantics (paper §2.1),
//! shared by the simulator and the real serving coordinator.

/// How draft/verify rounds are scheduled against each other.
///
/// `Sequential` is the paper's model: draft → ship → verify → downlink,
/// one window in flight per request. `Pipelined` (DiP-SD-style) starts
/// drafting window k+1 the moment window k ships, hiding draft latency
/// behind the verification round trip; a rejection anywhere in window k
/// invalidates the in-flight speculative window, and the simulator
/// meters the discarded work as `wasted_draft_tokens` /
/// `wasted_uplink_ms`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ExecutionMode {
    /// One window in flight: draft, ship, verify, repeat (paper §2.1).
    #[default]
    Sequential,
    /// Draft window k+1 overlaps verification of window k; rejections
    /// invalidate (and meter) the speculative window.
    Pipelined,
}

impl ExecutionMode {
    /// Config-file / CLI spelling.
    pub fn label(self) -> &'static str {
        match self {
            ExecutionMode::Sequential => "sequential",
            ExecutionMode::Pipelined => "pipelined",
        }
    }

    /// Parse the config-file spelling.
    pub fn parse(s: &str) -> Result<ExecutionMode, String> {
        match s {
            "sequential" => Ok(ExecutionMode::Sequential),
            "pipelined" => Ok(ExecutionMode::Pipelined),
            other => Err(format!(
                "unknown execution mode '{other}' (expected sequential | pipelined)"
            )),
        }
    }
}

/// Expected duration of one *sequential* round: draft γ tokens, ship
/// them, verify, return the verdict (paper §2.1's round trip).
pub fn sequential_round_ms(gamma: u32, draft_ms: f64, verify_ms: f64, rtt_ms: f64) -> f64 {
    gamma as f64 * draft_ms + verify_ms + rtt_ms
}

/// Expected duration of one *pipelined* round once the pipe is warm:
/// drafting of the next window overlaps the verify + network leg of the
/// current one, so the steady-state period is the max of the two stages.
/// `p_flush` is the probability the round rejects somewhere and the
/// overlap is wasted (≈ `1 − α^γ`): flushed rounds pay the full
/// sequential latency again while the pipe refills.
pub fn pipelined_round_ms(
    gamma: u32,
    draft_ms: f64,
    verify_ms: f64,
    rtt_ms: f64,
    p_flush: f64,
) -> f64 {
    let seq = sequential_round_ms(gamma, draft_ms, verify_ms, rtt_ms);
    let overlapped = (gamma as f64 * draft_ms).max(verify_ms + rtt_ms);
    let p = p_flush.clamp(0.0, 1.0);
    p * seq + (1.0 - p) * overlapped
}

/// Expected per-round speedup of pipelined over sequential execution for
/// acceptance rate `alpha` (the flush probability is `1 − α^γ`). Values
/// above 1.0 mean pipelining wins — the crossover frontier reproduced by
/// `dsd reproduce pipeline`.
pub fn pipelined_speedup(
    alpha: f64,
    gamma: u32,
    draft_ms: f64,
    verify_ms: f64,
    rtt_ms: f64,
) -> f64 {
    let p_flush = 1.0 - alpha.clamp(0.0, 1.0).powi(gamma as i32);
    sequential_round_ms(gamma, draft_ms, verify_ms, rtt_ms)
        / pipelined_round_ms(gamma, draft_ms, verify_ms, rtt_ms, p_flush)
}

/// Expected number of accepted draft tokens per window,
/// `E[τ] = (1 − α^{γ+1}) / (1 − α)` (paper Eq. 1).
pub fn expected_accepted(alpha: f64, gamma: u32) -> f64 {
    if (alpha - 1.0).abs() < 1e-12 {
        return gamma as f64 + 1.0;
    }
    (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha)
}

/// Expected speedup over standard decoding,
/// `S = (1 − α^{γ+1}) / ((1 − α)(cγ + 1))` where `c` is the draft/target
/// per-token cost ratio (paper Eq. 2).
pub fn expected_speedup(alpha: f64, gamma: u32, c: f64) -> f64 {
    expected_accepted(alpha, gamma) / (c * gamma as f64 + 1.0)
}

/// The γ that maximizes [`expected_speedup`] over `1..=max_gamma`.
pub fn optimal_gamma(alpha: f64, c: f64, max_gamma: u32) -> u32 {
    (1..=max_gamma)
        .max_by(|&a, &b| {
            // total_cmp: a NaN speedup (e.g. NaN α from a corrupt trace)
            // must degrade the argmax, never panic; finite values order
            // identically to the old partial_cmp comparator.
            expected_speedup(alpha, a, c).total_cmp(&expected_speedup(alpha, b, c))
        })
        .unwrap_or(1)
}

/// Outcome of verifying one speculation window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VerifyOutcome {
    /// Draft tokens accepted (0..=γ).
    pub accepted: u32,
    /// Total sequence tokens produced this round: accepted draft tokens
    /// plus the target's own token (the correction on mismatch, or the
    /// bonus token when all γ are accepted). Always `accepted + 1`.
    pub produced: u32,
    /// Draft tokens consumed from the acceptance sequence (always γ —
    /// rejected speculation still consumed drafting work).
    pub consumed: u32,
}

/// Apply the paper's acceptance rule to a window of size `gamma` using the
/// ground-truth `acceptance_seq` starting at `cursor`.
///
/// Tokens are verified in order; the first `false` stops acceptance and
/// the target substitutes its own token (`t_i'`); if every draft token is
/// accepted the target appends one bonus token. Either way the round
/// produces `accepted + 1` sequence tokens (Figure 1(c), steps 2–4).
///
/// The sequence is consumed cyclically if the cursor runs past the end
/// (generators size sequences so this is rare).
pub fn verify_window(acceptance_seq: &[bool], cursor: usize, gamma: u32) -> VerifyOutcome {
    debug_assert!(gamma >= 1);
    let n = acceptance_seq.len();
    let mut accepted = 0;
    for i in 0..gamma {
        let bit = if n == 0 {
            false
        } else {
            acceptance_seq[(cursor + i as usize) % n]
        };
        if bit {
            accepted += 1;
        } else {
            break;
        }
    }
    VerifyOutcome {
        accepted,
        produced: accepted + 1,
        consumed: gamma,
    }
}

/// Per-request speculation progress tracker used by both execution paths.
#[derive(Clone, Debug)]
pub struct SpeculationState {
    /// Tokens of the final sequence produced so far.
    pub generated: u32,
    /// Target output length.
    pub output_length: u32,
    /// Cursor into the acceptance sequence.
    pub cursor: usize,
    /// Draft tokens proposed so far (accepted + rejected).
    pub drafted: u32,
    /// Draft tokens accepted so far.
    pub accepted: u32,
    /// Completed verification rounds.
    pub rounds: u32,
}

impl SpeculationState {
    /// Fresh state for a request of `output_length` tokens.
    pub fn new(output_length: u32) -> Self {
        SpeculationState {
            generated: 0,
            output_length,
            cursor: 0,
            drafted: 0,
            accepted: 0,
            rounds: 0,
        }
    }

    /// Whether generation is complete.
    pub fn done(&self) -> bool {
        self.generated >= self.output_length
    }

    /// Remaining tokens to generate.
    pub fn remaining(&self) -> u32 {
        self.output_length.saturating_sub(self.generated)
    }

    /// Effective window for the next round: the policy's γ, capped so we
    /// do not draft far past the end of the sequence.
    pub fn effective_gamma(&self, policy_gamma: u32) -> u32 {
        policy_gamma.clamp(1, self.remaining().max(1))
    }

    /// Advance one verification round with window `gamma`; returns the
    /// outcome. Produced tokens are clipped to the output length.
    pub fn advance(&mut self, acceptance_seq: &[bool], gamma: u32) -> VerifyOutcome {
        let out = verify_window(acceptance_seq, self.cursor, gamma);
        self.cursor += out.consumed as usize;
        self.drafted += out.consumed;
        self.accepted += out.accepted;
        self.generated = (self.generated + out.produced).min(self.output_length);
        self.rounds += 1;
        out
    }

    /// Advance one *fused-mode* decode step (target generates `k` tokens
    /// autoregressively, no speculation).
    pub fn advance_fused(&mut self, k: u32) {
        self.generated = (self.generated + k).min(self.output_length);
        self.rounds += 1;
    }

    /// Empirical acceptance rate so far (None before any drafting).
    pub fn acceptance_rate(&self) -> Option<f64> {
        if self.drafted == 0 {
            None
        } else {
            Some(self.accepted as f64 / self.drafted as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, Gen};

    #[test]
    fn execution_mode_parse_and_label_round_trip() {
        assert_eq!(ExecutionMode::default(), ExecutionMode::Sequential);
        for m in [ExecutionMode::Sequential, ExecutionMode::Pipelined] {
            assert_eq!(ExecutionMode::parse(m.label()), Ok(m));
        }
        assert!(ExecutionMode::parse("overlapped").is_err());
    }

    #[test]
    fn pipelined_round_model_behaviour() {
        // γ=4, 2 ms/draft token, 10 ms verify, 60 ms RTT: the sequential
        // round is 8 + 10 + 60 = 78 ms.
        assert!((sequential_round_ms(4, 2.0, 10.0, 60.0) - 78.0).abs() < 1e-12);
        // Never-flushing pipe hides the draft entirely behind the RTT.
        assert!((pipelined_round_ms(4, 2.0, 10.0, 60.0, 0.0) - 70.0).abs() < 1e-12);
        // Always-flushing pipe degenerates to sequential.
        assert!((pipelined_round_ms(4, 2.0, 10.0, 60.0, 1.0) - 78.0).abs() < 1e-12);
        // High acceptance + long RTT: pipelining wins (speedup > 1).
        assert!(pipelined_speedup(0.9, 4, 2.0, 10.0, 120.0) > 1.0);
        // Zero acceptance: every round flushes; no gain, no loss.
        assert!((pipelined_speedup(0.0, 4, 2.0, 10.0, 120.0) - 1.0).abs() < 1e-12);
        // Speedup is capped by the sequential/overlapped ratio.
        let cap = 78.0 / 70.0;
        assert!(pipelined_speedup(1.0, 4, 2.0, 10.0, 60.0) <= cap + 1e-12);
    }

    #[test]
    fn eq1_matches_closed_form() {
        // alpha = 0.8, gamma = 4: (1 - 0.8^5) / 0.2 = 3.3616
        assert!((expected_accepted(0.8, 4) - 3.3616).abs() < 1e-4);
        // alpha -> 1 degenerates to gamma + 1.
        assert!((expected_accepted(1.0, 4) - 5.0).abs() < 1e-12);
        // alpha = 0: only the target's token.
        assert!((expected_accepted(0.0, 4) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eq2_speedup_behaviour() {
        // Cheap drafter, high acceptance => real speedup.
        assert!(expected_speedup(0.8, 4, 0.05) > 2.5);
        // Expensive drafter kills the benefit.
        assert!(expected_speedup(0.8, 4, 1.0) < 1.0);
    }

    #[test]
    fn optimal_gamma_monotone_in_alpha() {
        let lo = optimal_gamma(0.5, 0.05, 12);
        let hi = optimal_gamma(0.9, 0.05, 12);
        assert!(hi >= lo, "higher acceptance supports larger windows");
        assert!(hi <= 12 && lo >= 1);
    }

    /// Regression (ISSUE satellite): the argmax moved from
    /// `partial_cmp(..).unwrap()` to `total_cmp` — a NaN α (corrupt
    /// acceptance estimate) must yield *some* in-range γ, never panic
    /// mid-decision.
    #[test]
    fn optimal_gamma_survives_nan_alpha() {
        let g = optimal_gamma(f64::NAN, 0.05, 12);
        assert!((1..=12).contains(&g));
        // Finite inputs keep the exact pre-refactor argmax.
        assert_eq!(optimal_gamma(0.8, 0.05, 12), {
            let mut best = 1;
            let mut best_s = f64::MIN;
            for g in 1..=12u32 {
                let s = expected_speedup(0.8, g, 0.05);
                if s > best_s {
                    best_s = s;
                    best = g;
                }
            }
            best
        });
    }

    #[test]
    fn verify_window_cases() {
        // All accepted: gamma + 1 produced (bonus token).
        let out = verify_window(&[true, true, true], 0, 3);
        assert_eq!(out, VerifyOutcome { accepted: 3, produced: 4, consumed: 3 });
        // Reject at relative position 1: 1 accepted + 1 correction.
        let out = verify_window(&[true, false, true], 0, 3);
        assert_eq!(out, VerifyOutcome { accepted: 1, produced: 2, consumed: 3 });
        // Immediate reject: only the target's token.
        let out = verify_window(&[false, true], 0, 2);
        assert_eq!(out, VerifyOutcome { accepted: 0, produced: 1, consumed: 2 });
    }

    #[test]
    fn cyclic_consumption() {
        let out = verify_window(&[true, false], 1, 3); // reads idx 1,2%2=0,...
        assert_eq!(out.accepted, 0); // idx1 = false
        let out = verify_window(&[true, false], 2, 1); // idx 2%2=0 = true
        assert_eq!(out.accepted, 1);
    }

    #[test]
    fn state_progresses_to_completion() {
        let seq = vec![true; 64];
        let mut st = SpeculationState::new(20);
        let mut guard = 0;
        while !st.done() {
            let g = st.effective_gamma(4);
            st.advance(&seq, g);
            guard += 1;
            assert!(guard < 100);
        }
        assert_eq!(st.generated, 20);
        // All-accept: every round produces gamma+1 = 5 tokens.
        assert_eq!(st.rounds, 4);
        assert_eq!(st.acceptance_rate(), Some(1.0));
    }

    #[test]
    fn fused_mode_progresses() {
        let mut st = SpeculationState::new(5);
        st.advance_fused(2);
        st.advance_fused(2);
        st.advance_fused(2);
        assert!(st.done());
        assert_eq!(st.generated, 5); // clipped
        assert_eq!(st.acceptance_rate(), None); // nothing drafted
    }

    #[test]
    fn prop_invariants() {
        run_prop("verify window invariants", 500, |g: &mut Gen| {
            let n = g.usize_in(1, 64);
            let seq = g.vec_of(n, |g| g.bool_with(0.7));
            let gamma = g.usize_in(1, 12) as u32;
            let cursor = g.usize_in(0, 1000);
            let out = verify_window(&seq, cursor, gamma);
            assert!(out.accepted <= gamma);
            assert_eq!(out.produced, out.accepted + 1);
            assert_eq!(out.consumed, gamma);
        });
    }

    #[test]
    fn prop_state_terminates_and_counts() {
        run_prop("speculation state terminates", 200, |g: &mut Gen| {
            let out_len = g.usize_in(1, 200) as u32;
            let n = g.usize_in(8, 256);
            let seq = g.vec_of(n, |g| {
                let p = g.f64_in(0.0, 1.0);
                g.bool_with(p)
            });
            let mut st = SpeculationState::new(out_len);
            let mut rounds = 0;
            while !st.done() {
                let gamma = st.effective_gamma(g.usize_in(1, 12) as u32);
                st.advance(&seq, gamma);
                rounds += 1;
                // Even with 0 acceptance every round produces >= 1 token.
                assert!(rounds <= out_len, "must terminate in <= out_len rounds");
            }
            assert_eq!(st.generated, out_len);
            assert!(st.accepted <= st.drafted);
        });
    }
}
