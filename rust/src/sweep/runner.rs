//! Deterministic parallel execution of sweep grids.
//!
//! Cells run on a pool of `std::thread` workers pulling indices from an
//! atomic counter; every cell owns a fully seeded simulator, and results
//! land in a slot vector addressed by cell index. Output order therefore
//! depends only on the grid — never on thread scheduling — so repeated
//! runs (at any thread count) produce byte-identical summaries.

use super::cache::{CacheLookup, CellCache, CellKeyer, MAX_FAILED_ATTEMPTS};
use super::grid::{SweepCell, SweepGrid};
use crate::autoscale::AutoscaleMetrics;
use crate::config::SimConfig;
use crate::log_warn;
use crate::metrics::{SimReport, SloSpec, StreamingReport, TimeSeriesConfig, TimeSeriesSummary};
use crate::obs::registry;
use crate::sim::Simulator;
use crate::util::json::Json;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Flat per-class reading carried by class-bearing cells: the tier
/// name plus the numbers the fairness analyses plot (completion count,
/// latency means, attainment against the tier's own SLO). A compact
/// projection of [`crate::metrics::ClassSummary`] — full per-class
/// series stay in the reports; cell files carry only what summaries
/// consume.
#[derive(Clone, Debug)]
pub struct ClassCellMetrics {
    /// Tier name as declared in the `classes:` block.
    pub name: String,
    /// Completed requests in the tier.
    pub completed: u64,
    /// Mean TTFT, ms (0 for an empty tier).
    pub mean_ttft_ms: f64,
    /// Mean TPOT, ms.
    pub mean_tpot_ms: f64,
    /// Attainment against the tier's own SLO (0 when nothing completed).
    pub slo_attainment: f64,
}

impl ClassCellMetrics {
    /// JSON encoding (insertion-ordered keys, deterministic).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("name", self.name.as_str().into())
            .with("completed", self.completed.into())
            .with("mean_ttft_ms", self.mean_ttft_ms.into())
            .with("mean_tpot_ms", self.mean_tpot_ms.into())
            .with("slo_attainment", self.slo_attainment.into())
    }

    /// Decode one reading (cache load path); `None` on shape mismatch.
    pub fn from_json(j: &Json) -> Option<ClassCellMetrics> {
        Some(ClassCellMetrics {
            name: j.get("name")?.as_str()?.to_string(),
            completed: j.get("completed")?.as_u64()?,
            mean_ttft_ms: j.get("mean_ttft_ms")?.as_f64_or_nan()?,
            mean_tpot_ms: j.get("mean_tpot_ms")?.as_f64_or_nan()?,
            slo_attainment: j.get("slo_attainment")?.as_f64_or_nan()?,
        })
    }
}

/// Flat per-cell metric snapshot, common to both metric modes.
#[derive(Clone, Debug)]
pub struct CellMetrics {
    /// Completed requests.
    pub completed: u64,
    /// Steady-state throughput, req/s (naive ratio in streaming mode).
    pub throughput_rps: f64,
    /// Output-token throughput, tokens/s.
    pub token_throughput: f64,
    /// Mean busy fraction across targets.
    pub target_utilization: f64,
    /// Mean TTFT, ms.
    pub mean_ttft_ms: f64,
    /// p99 TTFT, ms (exact in full mode, ±bucket in streaming mode).
    pub p99_ttft_ms: f64,
    /// Mean TPOT, ms.
    pub mean_tpot_ms: f64,
    /// p99 TPOT, ms.
    pub p99_tpot_ms: f64,
    /// Mean end-to-end latency, ms.
    pub mean_e2e_ms: f64,
    /// Mean acceptance over speculating requests (NaN if none).
    pub mean_acceptance: f64,
    /// Mean target queueing delay, ms.
    pub mean_queue_delay_ms: f64,
    /// Mean one-way network delay, ms.
    pub mean_net_delay_ms: f64,
    /// Simulated duration, ms.
    pub sim_duration_ms: f64,
    /// DES events processed.
    pub events_processed: u64,
    /// Mean WC-DNN feature vector observed at window-decision time
    /// `[q_depth_util, α_recent, RTT_recent, TPOT_recent, γ_prev]` —
    /// carried so the AWC dataset generator can run on this runner (and
    /// its cache) without re-entering the simulator.
    pub mean_features: [f64; 5],
    /// Windowed time series — populated (by [`run_cells_cached`]) for
    /// scenario-bearing and autoscale-bearing cells, where
    /// single-number summaries hide the scripted dynamics. `None` keeps
    /// scenario-free cell files and summaries byte-identical to their
    /// historical layout.
    pub time_series: Option<TimeSeriesSummary>,
    /// Elastic-capacity accounting — present only for cells whose
    /// config carries an `autoscale:` block (see [`crate::autoscale`]).
    pub autoscale: Option<AutoscaleMetrics>,
    /// Interactive-tier SLO attainment fraction
    /// ([`SloSpec::INTERACTIVE`]) — populated alongside `autoscale`:
    /// the elasticity experiments trade cost against SLO attainment,
    /// which the flat metric set did not carry. `None` keeps historical
    /// cell bytes.
    pub slo_interactive: Option<f64>,
    /// Per-request-class readings, tier order — present only for cells
    /// whose config carries a `classes:` block. `None` keeps historical
    /// cell bytes.
    pub per_class: Option<Vec<ClassCellMetrics>>,
}

impl CellMetrics {
    /// Snapshot a full-record report.
    pub fn from_report(rep: &SimReport) -> CellMetrics {
        CellMetrics {
            completed: rep.system.completed as u64,
            throughput_rps: rep.system.throughput_rps,
            token_throughput: rep.system.token_throughput,
            target_utilization: rep.system.target_utilization,
            mean_ttft_ms: rep.mean_ttft(),
            p99_ttft_ms: rep.p_ttft(99.0),
            mean_tpot_ms: rep.mean_tpot(),
            p99_tpot_ms: rep.p_tpot(99.0),
            mean_e2e_ms: rep.mean_e2e(),
            mean_acceptance: rep.mean_acceptance(),
            mean_queue_delay_ms: rep.system.mean_queue_delay_ms,
            mean_net_delay_ms: rep.system.mean_net_delay_ms,
            sim_duration_ms: rep.system.sim_duration_ms,
            events_processed: rep.system.events_processed,
            mean_features: rep.system.mean_features,
            time_series: None,
            autoscale: rep.system.autoscale.clone(),
            slo_interactive: None,
            per_class: None,
        }
    }

    /// Snapshot a streaming report.
    pub fn from_streaming(rep: &StreamingReport) -> CellMetrics {
        CellMetrics {
            completed: rep.stream.completed,
            throughput_rps: rep.system.throughput_rps,
            token_throughput: rep.system.token_throughput,
            target_utilization: rep.system.target_utilization,
            mean_ttft_ms: rep.stream.ttft_ms.mean,
            p99_ttft_ms: rep.stream.ttft_ms.p99,
            mean_tpot_ms: rep.stream.tpot_ms.mean,
            p99_tpot_ms: rep.stream.tpot_ms.p99,
            mean_e2e_ms: rep.stream.e2e_ms.mean,
            mean_acceptance: rep.stream.mean_acceptance,
            mean_queue_delay_ms: rep.system.mean_queue_delay_ms,
            mean_net_delay_ms: rep.system.mean_net_delay_ms,
            sim_duration_ms: rep.system.sim_duration_ms,
            events_processed: rep.system.events_processed,
            mean_features: rep.system.mean_features,
            time_series: None,
            autoscale: rep.system.autoscale.clone(),
            slo_interactive: None,
            per_class: None,
        }
    }

    /// JSON encoding (wall-clock fields deliberately absent: summaries
    /// must be byte-reproducible; the `time_series` key appears only
    /// when populated, so scenario-free summaries keep their historical
    /// byte layout).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("completed", self.completed.into())
            .with("throughput_rps", self.throughput_rps.into())
            .with("token_throughput", self.token_throughput.into())
            .with("target_utilization", self.target_utilization.into())
            .with("mean_ttft_ms", self.mean_ttft_ms.into())
            .with("p99_ttft_ms", self.p99_ttft_ms.into())
            .with("mean_tpot_ms", self.mean_tpot_ms.into())
            .with("p99_tpot_ms", self.p99_tpot_ms.into())
            .with("mean_e2e_ms", self.mean_e2e_ms.into())
            .with("mean_acceptance", self.mean_acceptance.into())
            .with("mean_queue_delay_ms", self.mean_queue_delay_ms.into())
            .with("mean_net_delay_ms", self.mean_net_delay_ms.into())
            .with("sim_duration_ms", self.sim_duration_ms.into())
            .with("events_processed", self.events_processed.into())
            .with(
                "mean_features",
                Json::Arr(self.mean_features.iter().map(|&x| Json::Num(x)).collect()),
            );
        if let Some(ts) = &self.time_series {
            j.set("time_series", ts.to_json());
        }
        if let Some(a) = &self.autoscale {
            j.set("autoscale", a.to_json());
        }
        if let Some(s) = self.slo_interactive {
            j.set("slo_interactive", s.into());
        }
        if let Some(pc) = &self.per_class {
            j.set(
                "per_class",
                Json::Arr(pc.iter().map(|c| c.to_json()).collect()),
            );
        }
        j
    }

    /// Decode a snapshot previously written by [`CellMetrics::to_json`]
    /// (the cell-cache load path). `None` on any missing or mistyped
    /// field — a partial record means a truncated or foreign file and
    /// must fall back to re-execution, never to garbage metrics. NaN
    /// fields (e.g. acceptance of fused cells) round-trip via JSON null.
    pub fn from_json(j: &Json) -> Option<CellMetrics> {
        let f = |k: &str| j.get(k).and_then(Json::as_f64_or_nan);
        let features = j.get("mean_features")?.as_arr()?;
        if features.len() != 5 {
            return None;
        }
        let mut mean_features = [0.0f64; 5];
        for (slot, v) in mean_features.iter_mut().zip(features) {
            *slot = v.as_f64_or_nan()?;
        }
        // Optional field (absent on scenario-free cells and on entries
        // written before the scenario engine): absent is None, present-
        // but-malformed is a decode failure.
        let time_series = match j.get("time_series") {
            None => None,
            Some(t) => Some(TimeSeriesSummary::from_json(t)?),
        };
        let autoscale = match j.get("autoscale") {
            None => None,
            Some(a) => Some(AutoscaleMetrics::from_json(a)?),
        };
        let slo_interactive = match j.get("slo_interactive") {
            None => None,
            Some(s) => Some(s.as_f64_or_nan()?),
        };
        let per_class = match j.get("per_class") {
            None => None,
            Some(p) => Some(
                p.as_arr()?
                    .iter()
                    .map(ClassCellMetrics::from_json)
                    .collect::<Option<Vec<_>>>()?,
            ),
        };
        Some(CellMetrics {
            completed: j.get("completed")?.as_u64()?,
            throughput_rps: f("throughput_rps")?,
            token_throughput: f("token_throughput")?,
            target_utilization: f("target_utilization")?,
            mean_ttft_ms: f("mean_ttft_ms")?,
            p99_ttft_ms: f("p99_ttft_ms")?,
            mean_tpot_ms: f("mean_tpot_ms")?,
            p99_tpot_ms: f("p99_tpot_ms")?,
            mean_e2e_ms: f("mean_e2e_ms")?,
            mean_acceptance: f("mean_acceptance")?,
            mean_queue_delay_ms: f("mean_queue_delay_ms")?,
            mean_net_delay_ms: f("mean_net_delay_ms")?,
            sim_duration_ms: f("sim_duration_ms")?,
            events_processed: j.get("events_processed")?.as_u64()?,
            mean_features,
            time_series,
            autoscale,
            slo_interactive,
            per_class,
        })
    }
}

/// Outcome of one executed cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Cell index in grid expansion order.
    pub index: usize,
    /// `(axis, value)` labels from the grid.
    pub labels: Vec<(String, String)>,
    /// Metrics, or the error that kept the cell from running.
    pub outcome: Result<CellMetrics, String>,
}

impl CellResult {
    /// Metrics of a successful cell (panics on failed cells — use in
    /// experiment code where the grid is known valid).
    pub fn metrics(&self) -> &CellMetrics {
        self.outcome.as_ref().expect("sweep cell failed")
    }

    /// Value of one axis label (None for an unknown axis name).
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// Reasonable worker count for this host.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Execution accounting for one (possibly cached) sweep run. The resume
/// integration tests assert on `executed == 0` for warm re-runs — i.e.
/// cache hits execute zero simulator steps.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RunStats {
    /// Cells in the run (after any filtering).
    pub total: usize,
    /// Cells that actually entered the simulator.
    pub executed: usize,
    /// Cells satisfied from the cell cache.
    pub cache_hits: usize,
    /// Corrupt / truncated cache entries that forced re-execution.
    pub corrupt_entries: usize,
    /// Cells whose persisted failure (at the retry bound) was surfaced
    /// without re-execution.
    pub failed_hits: usize,
}

impl RunStats {
    /// Fold another run's accounting into this one (experiment families
    /// and the AWC dataset generator batch several grid runs per
    /// figure/scenario and report one total).
    pub fn absorb(&mut self, other: RunStats) {
        self.total += other.total;
        self.executed += other.executed;
        self.cache_hits += other.cache_hits;
        self.corrupt_entries += other.corrupt_entries;
        self.failed_hits += other.failed_hits;
    }

    /// One-line human rendering for progress logs.
    pub fn describe(&self) -> String {
        format!(
            "{} cells: {} executed, {} cached{}{}",
            self.total,
            self.executed,
            self.cache_hits,
            if self.corrupt_entries > 0 {
                format!(", {} corrupt entries re-executed", self.corrupt_entries)
            } else {
                String::new()
            },
            if self.failed_hits > 0 {
                format!(", {} persisted failures surfaced", self.failed_hits)
            } else {
                String::new()
            }
        )
    }
}

/// Expand and execute a grid on `threads` workers. Results are ordered
/// by cell index regardless of scheduling.
pub fn run_grid(grid: &SweepGrid, threads: usize) -> Result<Vec<CellResult>, String> {
    let cells = grid.expand()?;
    Ok(run_cells(&cells, grid.streaming, threads))
}

/// [`run_grid`] against a cell cache: hits load from disk, misses
/// execute and persist as they complete.
pub fn run_grid_cached(
    grid: &SweepGrid,
    threads: usize,
    cache: Option<&CellCache>,
) -> Result<(Vec<CellResult>, RunStats), String> {
    let cells = grid.expand()?;
    Ok(run_cells_cached(&cells, grid.streaming, threads, cache))
}

/// Execute pre-expanded cells on `threads` workers (clamped to the cell
/// count; 0 is treated as 1).
pub fn run_cells(cells: &[SweepCell], streaming: bool, threads: usize) -> Vec<CellResult> {
    run_cells_cached(cells, streaming, threads, None).0
}

/// Execute pre-expanded cells, consulting `cache` before every cell and
/// persisting each finished cell *as it completes* (so a killed sweep
/// keeps everything already done). Failed cells persist as retry-counted
/// markers: they re-execute on resume until [`MAX_FAILED_ATTEMPTS`]
/// executions have failed, then surface the stored error without
/// re-entering the simulator. Labels always come from the current grid
/// expansion, so summaries reflect the invoking grid even when metrics
/// were computed by an earlier run.
pub fn run_cells_cached(
    cells: &[SweepCell],
    streaming: bool,
    threads: usize,
    cache: Option<&CellCache>,
) -> (Vec<CellResult>, RunStats) {
    if cells.is_empty() {
        return (Vec::new(), RunStats::default());
    }
    let threads = threads.clamp(1, cells.len());
    let next = AtomicUsize::new(0);
    let executed = AtomicUsize::new(0);
    let cache_hits = AtomicUsize::new(0);
    let corrupt_entries = AtomicUsize::new(0);
    let failed_hits = AtomicUsize::new(0);
    // Concurrently-busy workers, for the registry's occupancy high-water
    // (observability only — RunStats stays the deterministic record).
    let busy = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<CellResult>>> =
        cells.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                // Per-worker key deriver: the invariant wrapper and the
                // serialization buffer amortize across every cell this
                // worker claims (byte-identical keys to `cell_key`).
                let mut keyer = CellKeyer::new(streaming);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let cell = &cells[i];
                    let now_busy = busy.fetch_add(1, Ordering::Relaxed) + 1;
                    registry::SWEEP_WORKERS_BUSY_HW.raise(now_busy as u64);
                    let key = cache.map(|_| keyer.key(&cell.cfg));
                    let mut outcome = None;
                    let mut prior_attempts = 0u32;
                    if let (Some(c), Some(k)) = (cache, key.as_deref()) {
                        match c.load(k) {
                            CacheLookup::Hit(m) => {
                                cache_hits.fetch_add(1, Ordering::Relaxed);
                                registry::SWEEP_CACHE_HITS.inc();
                                outcome = Some(Ok(m));
                            }
                            CacheLookup::Failed { error, attempts }
                                if attempts >= MAX_FAILED_ATTEMPTS =>
                            {
                                // Retry budget exhausted: surface the
                                // persisted error instead of re-executing
                                // forever.
                                failed_hits.fetch_add(1, Ordering::Relaxed);
                                registry::SWEEP_CACHE_FAILED_HITS.inc();
                                outcome = Some(Err(format!(
                                    "persistent failure ({attempts} attempts): {error}"
                                )));
                            }
                            CacheLookup::Failed { attempts, .. } => {
                                prior_attempts = attempts;
                            }
                            CacheLookup::Corrupt(why) => {
                                corrupt_entries.fetch_add(1, Ordering::Relaxed);
                                registry::SWEEP_CACHE_CORRUPT.inc();
                                log_warn!(
                                    "[sweep] corrupt cache entry for cell {} \
                                     ({why}); re-executing",
                                    cell.index
                                );
                            }
                            CacheLookup::Miss => {
                                registry::SWEEP_CACHE_MISSES.inc();
                            }
                        }
                    }
                    let outcome = outcome.unwrap_or_else(|| {
                        executed.fetch_add(1, Ordering::Relaxed);
                        registry::SWEEP_CELLS_EXECUTED.inc();
                        let t0 = Instant::now();
                        let out = run_cell(&cell.cfg, streaming);
                        registry::SWEEP_CELL_WALL_MS
                            .observe_ms(t0.elapsed().as_secs_f64() * 1e3);
                        if let (Some(c), Some(k)) = (cache, key.as_deref()) {
                            let stored = match &out {
                                Ok(m) => c.store(k, &cell.labels, m),
                                Err(e) => {
                                    c.store_failure(k, &cell.labels, e, prior_attempts + 1)
                                }
                            };
                            if let Err(e) = stored {
                                log_warn!("[sweep] {e}");
                            }
                        }
                        out
                    });
                    let result = CellResult {
                        index: cell.index,
                        labels: cell.labels.clone(),
                        outcome,
                    };
                    *slots[i].lock().expect("slot lock") = Some(result);
                    busy.fetch_sub(1, Ordering::Relaxed);
                }
            });
        }
    });
    let stats = RunStats {
        total: cells.len(),
        executed: executed.load(Ordering::Relaxed),
        cache_hits: cache_hits.load(Ordering::Relaxed),
        corrupt_entries: corrupt_entries.load(Ordering::Relaxed),
        failed_hits: failed_hits.load(Ordering::Relaxed),
    };
    let results = slots
        .into_iter()
        .map(|s| s.into_inner().expect("slot lock").expect("cell executed"))
        .collect();
    (results, stats)
}

/// Sentinel dataset name that makes [`run_cell`] panic on entry — only
/// honored under `cfg(test)`, where the panic-containment regression
/// test needs a cell that panics instead of erroring.
#[cfg(test)]
pub(crate) const PANIC_INJECTION_DATASET: &str = "__panic_injection__";

fn run_cell(cfg: &SimConfig, streaming: bool) -> Result<CellMetrics, String> {
    // Panic containment: `run_cells_cached` workers run on
    // `std::thread::scope` threads, where an escaped panic aborts the
    // whole sweep when the scope joins (and would poison the result
    // slots first). A panicking cell must instead surface exactly like
    // an erroring cell — as a per-cell `Err` that persists through the
    // bounded-retry failure-marker path — so one pathological
    // configuration cannot take down a million-cell run. The closure
    // only reads `cfg` (cloned inside) and returns an owned value, so
    // `AssertUnwindSafe` is sound: no shared state survives the unwind.
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_cell_inner(cfg, streaming)
    })) {
        Ok(out) => out,
        Err(payload) => {
            let why = if let Some(s) = payload.downcast_ref::<&str>() {
                s.to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(format!("cell panicked: {why}"))
        }
    }
}

fn run_cell_inner(cfg: &SimConfig, streaming: bool) -> Result<CellMetrics, String> {
    #[cfg(test)]
    if cfg.workload.dataset == PANIC_INJECTION_DATASET {
        panic!("injected panic for containment test");
    }
    // Fallible run variants: a window-policy construction failure (e.g.
    // a bad AWC weights path) must become a per-cell error, not a panic
    // on a scoped worker thread that would abort the whole sweep.
    let sim = Simulator::try_new(cfg.clone())?;
    // Scenario- and autoscale-bearing cells carry the windowed time
    // series: scripted dynamics make the single-number summaries
    // misleading (see the stationarity caveat on
    // `SystemMetrics::throughput_rps`), and the agility/elasticity
    // experiments consume the windows directly. Autoscale-bearing cells
    // additionally carry the interactive SLO attainment (the elasticity
    // trade-off axis).
    let want_series = cfg.scenario.is_some() || cfg.autoscale.is_some();
    let want_slo = cfg.autoscale.is_some();
    // Class-bearing cells carry the per-tier readings the fairness
    // analyses plot; class-free cells keep their historical bytes.
    let classes = cfg.classes.as_ref().map(|c| c.slo_list());
    Ok(if streaming {
        let rep = sim.try_run_streaming()?;
        let mut m = CellMetrics::from_streaming(&rep);
        if want_series {
            m.time_series = Some(rep.stream.time_series.clone());
        }
        if want_slo {
            m.slo_interactive = rep
                .stream
                .slo
                .iter()
                .find(|s| s.spec == SloSpec::INTERACTIVE)
                .map(|s| s.attainment());
        }
        if classes.is_some() {
            m.per_class = Some(
                rep.stream
                    .per_class
                    .iter()
                    .map(|c| ClassCellMetrics {
                        name: c.name.clone(),
                        completed: c.group.completed,
                        mean_ttft_ms: c.group.mean_ttft_ms,
                        mean_tpot_ms: c.group.mean_tpot_ms,
                        slo_attainment: c.slo.attainment(),
                    })
                    .collect(),
            );
        }
        m
    } else {
        let rep = sim.try_run()?;
        let mut m = CellMetrics::from_report(&rep);
        if want_series {
            m.time_series = Some(rep.time_series(&TimeSeriesConfig::default()));
        }
        if want_slo {
            m.slo_interactive = Some(rep.slo_attainment(SloSpec::INTERACTIVE));
        }
        if let Some(cl) = &classes {
            m.per_class = Some(
                rep.per_class_breakdown(cl, &TimeSeriesConfig::default())
                    .iter()
                    .map(|c| ClassCellMetrics {
                        name: c.name.clone(),
                        completed: c.group.completed,
                        mean_ttft_ms: c.group.mean_ttft_ms,
                        mean_tpot_ms: c.group.mean_tpot_ms,
                        slo_attainment: c.slo.attainment(),
                    })
                    .collect(),
            );
        }
        m
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn tiny_grid() -> SweepGrid {
        let base = SimConfig::builder()
            .seed(1)
            .targets(2)
            .drafters(8)
            .requests(12)
            .rate_per_s(20.0)
            .build();
        let mut g = SweepGrid::new(base);
        g.rtt_ms = vec![5.0, 40.0];
        g.seeds = vec![1, 2];
        g
    }

    #[test]
    fn results_ordered_by_cell_index() {
        let grid = tiny_grid();
        let rs = run_grid(&grid, 3).unwrap();
        assert_eq!(rs.len(), 4);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.index, i);
            assert!(r.metrics().completed > 0);
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let grid = tiny_grid();
        let a = run_grid(&grid, 1).unwrap();
        let b = run_grid(&grid, 4).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
            let (mx, my) = (x.metrics(), y.metrics());
            assert_eq!(mx.events_processed, my.events_processed);
            assert!((mx.mean_ttft_ms - my.mean_ttft_ms).abs() < 1e-12);
            assert!((mx.throughput_rps - my.throughput_rps).abs() < 1e-12);
        }
    }

    #[test]
    fn streaming_grid_runs() {
        let mut grid = tiny_grid();
        grid.streaming = true;
        let rs = run_grid(&grid, 2).unwrap();
        assert_eq!(rs.len(), 4);
        assert!(rs[0].metrics().mean_ttft_ms > 0.0);
    }

    fn two_tier_classes() -> crate::config::ClassesConfig {
        use crate::config::{ClassSpec, ClassesConfig};
        use crate::scenario::ArrivalProcess;
        ClassesConfig {
            name: "two_tier".into(),
            tiers: vec![
                ClassSpec {
                    name: "interactive".into(),
                    arrivals: ArrivalProcess::Constant { rate_per_s: 12.0 },
                    slo: SloSpec::INTERACTIVE,
                },
                ClassSpec {
                    name: "batch".into(),
                    arrivals: ArrivalProcess::Constant { rate_per_s: 8.0 },
                    slo: SloSpec::RELAXED,
                },
            ],
            priority_admission: true,
            defer_batch_threshold: None,
        }
    }

    /// ISSUE tentpole: the class axis is byte-deterministic across
    /// thread counts, per-class readings appear exactly on class-bearing
    /// cells, and they survive the cache JSON roundtrip.
    #[test]
    fn class_axis_cells_are_thread_deterministic_and_roundtrip() {
        let mut grid = tiny_grid();
        grid.rtt_ms = vec![5.0];
        grid.seeds = vec![1];
        grid.classes = vec![None, Some(two_tier_classes())];
        let a = run_grid(&grid, 1).unwrap();
        let b = run_grid(&grid, 4).unwrap();
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
            assert_eq!(
                x.metrics().to_json().to_string_pretty(),
                y.metrics().to_json().to_string_pretty(),
                "class-axis cells must be byte-identical across thread counts"
            );
        }
        // Class-free cell: no per_class key. Class-bearing cell: both
        // tiers present with counts partitioning the total.
        assert!(a[0].metrics().per_class.is_none());
        let pc = a[1].metrics().per_class.as_ref().expect("per-class readings");
        assert_eq!(pc.len(), 2);
        assert_eq!(pc[0].name, "interactive");
        assert_eq!(
            pc.iter().map(|c| c.completed).sum::<u64>(),
            a[1].metrics().completed
        );
        let back = CellMetrics::from_json(&a[1].metrics().to_json()).expect("roundtrip");
        assert_eq!(
            back.to_json().to_string_pretty(),
            a[1].metrics().to_json().to_string_pretty()
        );
    }

    #[test]
    fn invalid_cell_reports_error_not_panic() {
        let mut grid = tiny_grid();
        // Unknown dataset passes config validation but fails simulator
        // construction — the cell must carry the error.
        grid.datasets = vec!["nope".into()];
        let rs = run_grid(&grid, 2).unwrap();
        assert!(rs.iter().all(|r| r.outcome.is_err()));
    }

    #[test]
    fn metrics_json_roundtrip_is_lossless() {
        let grid = tiny_grid();
        let rs = run_grid(&grid, 2).unwrap();
        for r in &rs {
            let m = r.metrics();
            let back = CellMetrics::from_json(&m.to_json()).expect("roundtrip");
            assert_eq!(
                back.to_json().to_string_pretty(),
                m.to_json().to_string_pretty(),
                "reloaded metrics must re-serialize byte-identically"
            );
        }
    }

    #[test]
    fn cached_run_executes_each_cell_once() {
        use crate::sweep::cache::CellCache;
        let dir = std::env::temp_dir().join(format!(
            "dsd-runner-cache-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = CellCache::open(&dir).unwrap();
        let grid = tiny_grid();
        let cells = grid.expand().unwrap();
        let (cold, s1) = run_cells_cached(&cells, false, 2, Some(&cache));
        assert_eq!(s1.executed, cells.len());
        assert_eq!(s1.cache_hits, 0);
        let (warm, s2) = run_cells_cached(&cells, false, 3, Some(&cache));
        assert_eq!(s2.executed, 0, "warm run must execute zero cells");
        assert_eq!(s2.cache_hits, cells.len());
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.labels, b.labels);
            assert_eq!(
                a.metrics().to_json().to_string_pretty(),
                b.metrics().to_json().to_string_pretty()
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_cells_cache_with_bounded_retry() {
        use crate::sweep::cache::{CellCache, MAX_FAILED_ATTEMPTS};
        let dir = std::env::temp_dir().join(format!(
            "dsd-runner-cache-fail-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = CellCache::open(&dir).unwrap();
        let mut grid = tiny_grid();
        grid.datasets = vec!["nope".into()];
        let cells = grid.expand().unwrap();
        // Every run up to the retry bound re-executes the failing cells,
        // persisting an advancing attempt count.
        for attempt in 1..=MAX_FAILED_ATTEMPTS as usize {
            let (rs, s) = run_cells_cached(&cells, false, 2, Some(&cache));
            assert_eq!(s.executed, cells.len(), "attempt {attempt} must re-execute");
            assert_eq!(s.failed_hits, 0);
            assert!(rs.iter().all(|r| r.outcome.is_err()));
        }
        assert_eq!(cache.n_entries(), cells.len(), "failures persist as markers");
        // Beyond the bound: zero executions, persisted errors surfaced.
        let (rs, s) = run_cells_cached(&cells, false, 2, Some(&cache));
        assert_eq!(s.executed, 0, "retry budget exhausted");
        assert_eq!(s.failed_hits, cells.len());
        for r in &rs {
            let err = r.outcome.as_ref().unwrap_err();
            assert!(err.contains("persistent failure"), "{err}");
            assert!(err.contains("unknown dataset"), "original error kept: {err}");
        }
        // Cells that start succeeding (e.g. after a fix) overwrite their
        // markers — simulated by swapping in a valid grid sharing keys?
        // Keys are content-addressed, so a *different* (valid) grid is a
        // different key; the overwrite path is covered in cache.rs unit
        // tests instead.
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unloadable_window_policy_reports_error_not_panic() {
        use crate::config::WindowKind;
        let mut grid = tiny_grid();
        // Passes validate() and try_new(); policy construction is what
        // fails. Must become a per-cell error, not a worker panic.
        grid.windows = vec![WindowKind::Awc {
            weights_path: Some("/nonexistent/awc_weights.json".into()),
        }];
        let rs = run_grid(&grid, 2).unwrap();
        assert!(rs.iter().all(|r| r.outcome.is_err()));
    }

    #[test]
    fn panicking_cell_becomes_failed_cell_not_aborted_sweep() {
        // A cell that *panics* (vs returns Err) must be contained: the
        // sweep completes, every other cell still runs, and the panic
        // surfaces as that cell's error. Without `catch_unwind` in
        // `run_cell` this test aborts — the scoped worker's panic
        // re-raises when `std::thread::scope` joins.
        let mut grid = tiny_grid();
        grid.datasets = vec![PANIC_INJECTION_DATASET.into(), "gsm8k".into()];
        let cells = grid.expand().unwrap();
        let (rs, stats) = run_cells_cached(&cells, false, 3, None);
        assert_eq!(rs.len(), cells.len());
        assert_eq!(stats.executed, cells.len());
        let (panicked, fine): (Vec<_>, Vec<_>) = rs
            .iter()
            .partition(|r| r.label("dataset") == Some(PANIC_INJECTION_DATASET));
        assert!(!panicked.is_empty() && !fine.is_empty());
        for r in &panicked {
            let err = r.outcome.as_ref().unwrap_err();
            assert!(err.contains("cell panicked"), "{err}");
            assert!(err.contains("injected panic"), "payload kept: {err}");
        }
        // Healthy cells are unaffected by their panicking neighbors.
        assert!(fine.iter().all(|r| r.outcome.is_ok()));
    }

    #[test]
    fn panicking_cell_persists_through_bounded_retry_markers() {
        use crate::sweep::cache::{CellCache, MAX_FAILED_ATTEMPTS};
        let dir = std::env::temp_dir().join(format!(
            "dsd-runner-cache-panic-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = CellCache::open(&dir).unwrap();
        let mut grid = tiny_grid();
        grid.datasets = vec![PANIC_INJECTION_DATASET.into()];
        let cells = grid.expand().unwrap();
        // Panics ride the same retry-counted failure markers as errors.
        for _ in 0..MAX_FAILED_ATTEMPTS {
            let (_, s) = run_cells_cached(&cells, false, 2, Some(&cache));
            assert_eq!(s.executed, cells.len());
        }
        let (rs, s) = run_cells_cached(&cells, false, 2, Some(&cache));
        assert_eq!(s.executed, 0, "persistent panic markers stop re-execution");
        assert_eq!(s.failed_hits, cells.len());
        for r in &rs {
            let err = r.outcome.as_ref().unwrap_err();
            assert!(err.contains("persistent failure"), "{err}");
            assert!(err.contains("cell panicked"), "{err}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
