//! Deterministic grid sharding and shard-output merging — the
//! horizontal-scale layer of the sweep subsystem.
//!
//! A [`ShardSpec`] (`--shard i/n` on the CLI) partitions a grid's cells
//! by a **stable function of cell index** — `index % n == i` — never by
//! hash or expansion order of a subset, so every process that expands
//! the same grid agrees on the partition without coordination. Each
//! shard runs its cells through the ordinary cached runner, writing the
//! same content-addressed `cells/<key>.json` layout into its run
//! directory (shared or per-shard), plus a [`ShardManifest`]
//! (`summary-shard-<i>-of-<n>.json`) recording the grid fingerprint,
//! the shard spec, and execution accounting.
//!
//! [`merge_shard_dirs`] (`dsd sweep --merge <dir>,...`) splices shard
//! outputs back into one summary **byte-identical** to the
//! single-process `dsd sweep` run: it verifies every manifest agrees on
//! the grid fingerprint, shard count, metric mode, and filter; rejects
//! overlapping or missing shards by name; re-expands the grid from the
//! run directory's `grid.yaml` copy (re-deriving the fingerprint as a
//! cross-check); and loads every cell from the union of the shard cell
//! caches, surfacing persisted failure markers exactly the way a
//! resumed single-process run would.

use super::cache::{CacheLookup, CellCache, CellKeyer, MAX_FAILED_ATTEMPTS, SIM_VERSION_TAG};
use super::grid::{filter_cells, parse_filter, SweepCell, SweepGrid};
use super::runner::{CellResult, RunStats};
use super::summary::SweepSummary;
use crate::util::hash::content_hash_hex;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One shard of an `n`-way deterministic grid partition.
///
/// `index` is 0-based: the valid shards of a 3-way split are `0/3`,
/// `1/3`, `2/3`. A shard owns exactly the cells whose expansion index
/// is congruent to `index` mod `count`; because the seed axis is
/// innermost (replicas of one configuration are adjacent), round-robin
/// by index also balances seed replicas across shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// 0-based shard index in `[0, count)`.
    pub index: usize,
    /// Total number of shards (≥ 1).
    pub count: usize,
}

impl ShardSpec {
    /// Parse the CLI form `i/n` (0-based, `0 <= i < n`, `n >= 1`).
    /// Every malformed input yields a named error, never a panic.
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let (i, n) = s
            .split_once('/')
            .ok_or_else(|| format!("shard: expected i/n (e.g. 0/4), got '{s}'"))?;
        let index: usize = i
            .trim()
            .parse()
            .map_err(|_| format!("shard: index '{i}' is not a non-negative integer"))?;
        let count: usize = n
            .trim()
            .parse()
            .map_err(|_| format!("shard: count '{n}' is not a positive integer"))?;
        if count == 0 {
            return Err("shard: count must be >= 1".into());
        }
        if index >= count {
            return Err(format!(
                "shard: index {index} out of range (0-based; valid: 0..{})",
                count - 1
            ));
        }
        Ok(ShardSpec { index, count })
    }

    /// Whether this shard owns the cell at `cell_index` (a pure function
    /// of the index — the partition is identical in every process).
    pub fn selects(&self, cell_index: usize) -> bool {
        cell_index % self.count == self.index
    }

    /// Human rendering, `i/n`.
    pub fn label(&self) -> String {
        format!("{}/{}", self.index, self.count)
    }

    /// Manifest file name for this shard, `summary-shard-<i>-of-<n>.json`.
    pub fn manifest_name(&self) -> String {
        format!("summary-shard-{}-of-{}.json", self.index, self.count)
    }
}

/// Keep only the cells this shard owns. Original expansion indices are
/// preserved (they are the merge key), so shard summaries report the
/// same indices the full grid would. An empty shard (more shards than
/// cells) is valid and merges cleanly.
pub fn shard_cells(cells: Vec<SweepCell>, spec: &ShardSpec) -> Vec<SweepCell> {
    cells
        .into_iter()
        .filter(|c| spec.selects(c.index))
        .collect()
}

/// Content fingerprint of an expanded (possibly filtered) grid: the
/// hash of every cell's `(index, content key)` pair in order, plus the
/// metric mode and [`SIM_VERSION_TAG`]. Two processes that expand the
/// same grid text under the same simulator version agree on it; any
/// axis, base-config, filter, or metric-mode difference changes it.
/// Shard manifests carry it so `--merge` can refuse to splice shards of
/// different grids.
pub fn grid_fingerprint(cells: &[SweepCell], streaming: bool) -> String {
    let mut keyer = CellKeyer::new(streaming);
    let mut acc = String::with_capacity(64 + cells.len() * 40);
    acc.push_str(SIM_VERSION_TAG);
    acc.push_str(if streaming { ";streaming;" } else { ";full;" });
    for cell in cells {
        acc.push_str(&cell.index.to_string());
        acc.push(':');
        acc.push_str(&keyer.key(&cell.cfg));
        acc.push(';');
    }
    content_hash_hex(acc.as_bytes())
}

/// Per-shard run manifest, persisted as
/// `summary-shard-<i>-of-<n>.json` in the shard's run directory (beside
/// `grid.yaml` and the `cells/` directory, never inside it — `--gc`
/// walks only `cells/` and cannot touch manifests).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    /// Which shard this run executed.
    pub shard: ShardSpec,
    /// [`grid_fingerprint`] of the full (filtered) grid — not of the
    /// shard subset, so all shards of one grid carry the same value.
    pub grid_hash: String,
    /// Metric mode the cells ran (and were keyed) in.
    pub streaming: bool,
    /// Canonical `--filter` label when the shard ran a filtered subset.
    pub filter: Option<String>,
    /// Cells in the full (filtered) grid across all shards.
    pub cells_total: usize,
    /// Cells this shard owns.
    pub cells_in_shard: usize,
    /// Shard cells whose outcome was an error (persisted as
    /// retry-counted failure markers in `cells/`).
    pub failed_cells: usize,
    /// Cache accounting of the shard run.
    pub stats: RunStats,
}

impl ShardManifest {
    /// JSON encoding (deterministic key order; no wall-clock fields).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("version", SIM_VERSION_TAG.into())
            .with("grid_hash", self.grid_hash.as_str().into())
            .with(
                "shard",
                Json::obj()
                    .with("index", (self.shard.index as u64).into())
                    .with("count", (self.shard.count as u64).into()),
            )
            .with("streaming", self.streaming.into());
        if let Some(f) = &self.filter {
            j.set("filter", f.as_str().into());
        }
        j.with("cells_total", (self.cells_total as u64).into())
            .with("cells_in_shard", (self.cells_in_shard as u64).into())
            .with("failed_cells", (self.failed_cells as u64).into())
            .with(
                "stats",
                Json::obj()
                    .with("executed", (self.stats.executed as u64).into())
                    .with("cache_hits", (self.stats.cache_hits as u64).into())
                    .with("corrupt_entries", (self.stats.corrupt_entries as u64).into())
                    .with("failed_hits", (self.stats.failed_hits as u64).into()),
            )
    }

    /// Decode a manifest; `None` on any shape mismatch (the caller turns
    /// that into a named per-file error).
    pub fn from_json(j: &Json) -> Option<ShardManifest> {
        if j.get("version")?.as_str()? != SIM_VERSION_TAG {
            return None;
        }
        let shard = ShardSpec {
            index: j.path(&["shard", "index"])?.as_usize()?,
            count: j.path(&["shard", "count"])?.as_usize()?,
        };
        if shard.count == 0 || shard.index >= shard.count {
            return None;
        }
        let stats = RunStats {
            total: j.get("cells_in_shard")?.as_usize()?,
            executed: j.path(&["stats", "executed"])?.as_usize()?,
            cache_hits: j.path(&["stats", "cache_hits"])?.as_usize()?,
            corrupt_entries: j.path(&["stats", "corrupt_entries"])?.as_usize()?,
            failed_hits: j.path(&["stats", "failed_hits"])?.as_usize()?,
        };
        Some(ShardManifest {
            shard,
            grid_hash: j.get("grid_hash")?.as_str()?.to_string(),
            streaming: j.get("streaming")?.as_bool()?,
            filter: match j.get("filter") {
                None => None,
                Some(f) => Some(f.as_str()?.to_string()),
            },
            cells_total: j.get("cells_total")?.as_usize()?,
            cells_in_shard: j.get("cells_in_shard")?.as_usize()?,
            failed_cells: j.get("failed_cells")?.as_usize()?,
            stats,
        })
    }

    /// Write the manifest into `dir` atomically (tmp + rename), like
    /// every other sweep artifact: a kill mid-write must never leave a
    /// half-manifest that later merges garbage.
    pub fn write_to(&self, dir: &Path) -> Result<PathBuf, String> {
        let path = dir.join(self.shard.manifest_name());
        let tmp = dir.join(format!(
            "{}.tmp.{}",
            self.shard.manifest_name(),
            std::process::id()
        ));
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(&tmp, &text).map_err(|e| format!("shard: write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("shard: rename to {}: {e}", path.display()))?;
        Ok(path)
    }

    /// Load one manifest file.
    pub fn load(path: &Path) -> Result<ShardManifest, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("merge: read {}: {e}", path.display()))?;
        let doc = Json::parse(&text).map_err(|e| format!("merge: {}: {e}", path.display()))?;
        ShardManifest::from_json(&doc)
            .ok_or_else(|| format!("merge: {}: not a valid shard manifest", path.display()))
    }
}

/// Scan a run directory for shard manifests
/// (`summary-shard-<i>-of-<n>.json`), in deterministic name order. A
/// directory that several shards shared as one `--out-dir` holds
/// several manifests.
pub fn find_manifests(dir: &Path) -> Result<Vec<(PathBuf, ShardManifest)>, String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("merge: read {}: {e}", dir.display()))?;
    let mut names: Vec<String> = rd
        .filter_map(|e| e.ok().and_then(|e| e.file_name().into_string().ok()))
        .filter(|n| {
            n.starts_with("summary-shard-") && n.ends_with(".json") && !n.contains(".tmp.")
        })
        .collect();
    names.sort();
    let mut out = Vec::with_capacity(names.len());
    for name in names {
        let path = dir.join(&name);
        out.push((path.clone(), ShardManifest::load(&path)?));
    }
    Ok(out)
}

/// Output of a successful merge.
#[derive(Debug)]
pub struct MergeReport {
    /// The spliced full-grid summary — byte-identical (via
    /// `to_json().to_string_pretty()`) to the single-process run's.
    pub summary: SweepSummary,
    /// Shard count the grid was split into.
    pub shard_count: usize,
    /// Fingerprint every manifest agreed on.
    pub grid_hash: String,
    /// Metric mode of the merged cells.
    pub streaming: bool,
    /// Combined cache accounting across the shard runs (as recorded in
    /// their manifests — the merge itself executes nothing).
    pub stats: RunStats,
}

/// Splice the outputs of N shard runs back into the single-process
/// summary. `dirs` are the shard run directories (one per shard, or one
/// shared directory holding every manifest; a directory may be listed
/// once even if it holds several manifests — duplicates are detected as
/// overlapping shards only when two *different* files claim one shard).
///
/// Validation, in order, each with a named error:
/// 1. every directory holds at least one manifest;
/// 2. all manifests agree on grid hash, shard count, metric mode, and
///    filter;
/// 3. no shard index appears in two manifest files (overlap), and every
///    index in `0..count` appears (missing shards are listed);
/// 4. the first directory's `grid.yaml` re-expands to the manifests'
///    fingerprint (a swapped grid copy cannot silently merge);
/// 5. every cell loads from the union of the `cells/` caches — a
///    missing cell names its index and owning shard.
///
/// Failed cells surface exactly like a resumed single-process run:
/// markers at the retry bound render as `persistent failure (N
/// attempts): <error>`, markers below it surface the stored error
/// verbatim (what the shard's own summary reported when it executed).
pub fn merge_shard_dirs(dirs: &[PathBuf]) -> Result<MergeReport, String> {
    if dirs.is_empty() {
        return Err("merge: no shard directories given".into());
    }
    // 1–3: collect and cross-validate manifests.
    let mut manifests: Vec<(PathBuf, ShardManifest)> = Vec::new();
    for dir in dirs {
        let found = find_manifests(dir)?;
        if found.is_empty() {
            return Err(format!(
                "merge: no shard manifests (summary-shard-*-of-*.json) in {}",
                dir.display()
            ));
        }
        for (path, m) in found {
            // The same physical file reached through two -dir arguments
            // (or a dir listed twice) is not an overlap.
            if manifests.iter().any(|(p, _)| same_file(p, &path)) {
                continue;
            }
            manifests.push((path, m));
        }
    }
    let (first_path, first) = &manifests[0];
    for (path, m) in &manifests[1..] {
        if m.grid_hash != first.grid_hash {
            return Err(format!(
                "merge: grid mismatch: {} has grid hash {} but {} has {}",
                path.display(),
                m.grid_hash,
                first_path.display(),
                first.grid_hash
            ));
        }
        if m.shard.count != first.shard.count {
            return Err(format!(
                "merge: shard-count mismatch: {} says {} shards but {} says {}",
                path.display(),
                m.shard.count,
                first_path.display(),
                first.shard.count
            ));
        }
        if m.streaming != first.streaming {
            return Err(format!(
                "merge: metric-mode mismatch: {} is {} but {} is {}",
                path.display(),
                mode_name(m.streaming),
                first_path.display(),
                mode_name(first.streaming)
            ));
        }
        if m.filter != first.filter {
            return Err(format!(
                "merge: filter mismatch: {} ran '{}' but {} ran '{}'",
                path.display(),
                m.filter.as_deref().unwrap_or("<none>"),
                first_path.display(),
                first.filter.as_deref().unwrap_or("<none>")
            ));
        }
    }
    let count = first.shard.count;
    let mut owner_of: Vec<Option<&Path>> = vec![None; count];
    for (path, m) in &manifests {
        if let Some(prev) = owner_of[m.shard.index] {
            return Err(format!(
                "merge: overlapping shard {}: claimed by both {} and {}",
                m.shard.label(),
                prev.display(),
                path.display()
            ));
        }
        owner_of[m.shard.index] = Some(path.as_path());
    }
    let missing: Vec<String> = (0..count)
        .filter(|&i| owner_of[i].is_none())
        .map(|i| format!("{i}/{count}"))
        .collect();
    if !missing.is_empty() {
        return Err(format!(
            "merge: missing shard(s) {} — pass every shard's run directory",
            missing.join(", ")
        ));
    }

    // 4: re-expand the grid from the first directory's grid.yaml copy.
    let grid_path = dirs[0].join("grid.yaml");
    let grid_text = std::fs::read_to_string(&grid_path)
        .map_err(|e| format!("merge: cannot read {} ({e})", grid_path.display()))?;
    let mut grid = SweepGrid::from_yaml(&grid_text)?;
    grid.streaming = first.streaming;
    let mut cells = grid.expand()?;
    let filter = first.filter.clone();
    if let Some(f) = &filter {
        cells = filter_cells(cells, &parse_filter(f)?)?;
    }
    let hash = grid_fingerprint(&cells, first.streaming);
    if hash != first.grid_hash {
        return Err(format!(
            "merge: {} expands to grid hash {} but the shard manifests record {} — \
             the grid copy and the shard outputs disagree",
            grid_path.display(),
            hash,
            first.grid_hash
        ));
    }
    if cells.len() != first.cells_total {
        return Err(format!(
            "merge: grid expands to {} cells but manifests record {}",
            cells.len(),
            first.cells_total
        ));
    }

    // 5: load every cell from the union of the shard caches. The owning
    // shard's directory is probed first; a shared out-dir means every
    // probe hits the same cache.
    let mut caches: Vec<CellCache> = Vec::with_capacity(dirs.len());
    for dir in dirs {
        let cells_dir = dir.join("cells");
        if !cells_dir.is_dir() {
            return Err(format!("merge: no cells/ directory in {}", dir.display()));
        }
        caches.push(CellCache::open(&cells_dir)?);
    }
    let dir_of_manifest = |manifest_path: &Path| -> usize {
        let parent = manifest_path.parent().unwrap_or(Path::new(""));
        dirs.iter()
            .position(|d| same_file(d, parent))
            .unwrap_or(0)
    };
    let mut owner_dir: Vec<usize> = vec![0; count];
    for (path, m) in &manifests {
        owner_dir[m.shard.index] = dir_of_manifest(path);
    }
    let mut keyer = CellKeyer::new(first.streaming);
    let mut results = Vec::with_capacity(cells.len());
    for cell in &cells {
        let key = keyer.key(&cell.cfg);
        let shard_idx = cell.index % count;
        // Probe the owning shard's cache first, then the rest in order.
        let mut order: Vec<usize> = Vec::with_capacity(caches.len());
        order.push(owner_dir[shard_idx]);
        order.extend((0..caches.len()).filter(|&d| d != owner_dir[shard_idx]));
        let mut outcome: Option<Result<_, String>> = None;
        for d in order {
            match caches[d].load(&key) {
                CacheLookup::Hit(m) => {
                    outcome = Some(Ok(m));
                    break;
                }
                CacheLookup::Failed { error, attempts } => {
                    outcome = Some(Err(if attempts >= MAX_FAILED_ATTEMPTS {
                        format!("persistent failure ({attempts} attempts): {error}")
                    } else {
                        error
                    }));
                    break;
                }
                CacheLookup::Corrupt(why) => {
                    eprintln!("[merge] warning: corrupt entry for cell {}: {why}", cell.index);
                }
                CacheLookup::Miss => {}
            }
        }
        let outcome = outcome.ok_or_else(|| {
            format!(
                "merge: cell {} (shard {}/{count}) missing from every directory — \
                 that shard run is incomplete; re-run it with --resume, then merge again",
                cell.index, shard_idx
            )
        })?;
        results.push(CellResult {
            index: cell.index,
            labels: cell.labels.clone(),
            outcome,
        });
    }
    let mut stats = RunStats::default();
    for (_, m) in &manifests {
        stats.absorb(m.stats);
    }
    let summary = SweepSummary::new(results, first.streaming).with_filter(filter);
    Ok(MergeReport {
        summary,
        shard_count: count,
        grid_hash: hash,
        streaming: first.streaming,
        stats,
    })
}

fn mode_name(streaming: bool) -> &'static str {
    if streaming {
        "streaming"
    } else {
        "full"
    }
}

/// Path identity without requiring canonicalization to succeed.
fn same_file(a: &Path, b: &Path) -> bool {
    match (std::fs::canonicalize(a), std::fs::canonicalize(b)) {
        (Ok(ca), Ok(cb)) => ca == cb,
        _ => a == b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::util::prop::{run_prop, Gen};

    fn tiny_grid() -> SweepGrid {
        let base = SimConfig::builder()
            .seed(1)
            .targets(2)
            .drafters(8)
            .requests(10)
            .rate_per_s(20.0)
            .build();
        let mut g = SweepGrid::new(base);
        g.rtt_ms = vec![5.0, 40.0];
        g.seeds = vec![1, 2, 3];
        g
    }

    #[test]
    fn parse_accepts_valid_specs() {
        assert_eq!(ShardSpec::parse("0/1").unwrap(), ShardSpec { index: 0, count: 1 });
        assert_eq!(ShardSpec::parse("2/3").unwrap(), ShardSpec { index: 2, count: 3 });
        assert_eq!(ShardSpec::parse(" 1 / 4 ").unwrap(), ShardSpec { index: 1, count: 4 });
    }

    #[test]
    fn parse_rejects_malformed_specs_with_named_errors() {
        for bad in ["", "1", "1/", "/2", "a/b", "1/0", "2/2", "5/3", "-1/2", "1/2/3"] {
            let err = ShardSpec::parse(bad).unwrap_err();
            assert!(err.starts_with("shard:"), "'{bad}' → {err}");
        }
        assert!(ShardSpec::parse("2/2").unwrap_err().contains("out of range"));
        assert!(ShardSpec::parse("1/0").unwrap_err().contains("positive"));
    }

    /// ISSUE satellite: every cell lands in exactly one shard, for any
    /// shard count — the partition is exhaustive and disjoint.
    #[test]
    fn prop_every_cell_in_exactly_one_shard() {
        run_prop("shard partition exhaustive+disjoint", 50, |g: &mut Gen| {
            let n_cells = g.usize_in(1, 60);
            let count = g.usize_in(1, 9);
            let mut seen = vec![0u32; n_cells];
            for index in 0..count {
                let spec = ShardSpec { index, count };
                for (ci, slot) in seen.iter_mut().enumerate() {
                    if spec.selects(ci) {
                        *slot += 1;
                    }
                }
            }
            assert!(
                seen.iter().all(|&c| c == 1),
                "cells must appear in exactly one shard (counts: {seen:?})"
            );
        });
    }

    #[test]
    fn one_way_split_is_the_identity() {
        let grid = tiny_grid();
        let cells = grid.expand().unwrap();
        let n = cells.len();
        let sharded = shard_cells(grid.expand().unwrap(), &ShardSpec { index: 0, count: 1 });
        assert_eq!(sharded.len(), n);
        for (a, b) in cells.iter().zip(&sharded) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.labels, b.labels);
        }
    }

    #[test]
    fn shards_preserve_original_indices_and_round_robin() {
        let grid = tiny_grid();
        let spec = ShardSpec { index: 1, count: 3 };
        let cells = shard_cells(grid.expand().unwrap(), &spec);
        assert!(!cells.is_empty());
        for c in &cells {
            assert_eq!(c.index % 3, 1, "shard 1/3 owns indices ≡1 mod 3");
        }
        // Seed replicas (innermost axis) spread across shards: the three
        // seeds of the first configuration land on shards 0, 1, 2.
        assert_eq!(cells[0].index, 1);
    }

    #[test]
    fn empty_shard_is_valid() {
        let mut grid = tiny_grid();
        grid.rtt_ms = vec![5.0];
        grid.seeds = vec![1];
        // 1 cell, 3 shards: shards 1 and 2 are empty.
        let cells = grid.expand().unwrap();
        assert_eq!(cells.len(), 1);
        assert!(shard_cells(cells.clone(), &ShardSpec { index: 1, count: 3 }).is_empty());
        assert_eq!(shard_cells(cells, &ShardSpec { index: 0, count: 3 }).len(), 1);
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let grid = tiny_grid();
        let cells = grid.expand().unwrap();
        let h = grid_fingerprint(&cells, false);
        assert_eq!(h, grid_fingerprint(&grid.expand().unwrap(), false));
        assert_eq!(h.len(), 32);
        // Metric mode is part of the fingerprint.
        assert_ne!(h, grid_fingerprint(&cells, true));
        // Any axis change is too.
        let mut other = tiny_grid();
        other.seeds = vec![1, 2];
        assert_ne!(h, grid_fingerprint(&other.expand().unwrap(), false));
        // A filtered subset fingerprints differently from the full grid.
        let kept = filter_cells(grid.expand().unwrap(), &parse_filter("seed=1").unwrap()).unwrap();
        assert_ne!(h, grid_fingerprint(&kept, false));
    }

    #[test]
    fn manifest_roundtrips_and_rejects_foreign_versions() {
        let m = ShardManifest {
            shard: ShardSpec { index: 1, count: 4 },
            grid_hash: "ab".repeat(16),
            streaming: true,
            filter: Some("rtt_ms=5".into()),
            cells_total: 24,
            cells_in_shard: 6,
            failed_cells: 1,
            stats: RunStats {
                total: 6,
                executed: 5,
                cache_hits: 1,
                corrupt_entries: 0,
                failed_hits: 0,
            },
        };
        let back = ShardManifest::from_json(&m.to_json()).expect("roundtrip");
        assert_eq!(back, m);
        // Filter-free manifests omit the key and round-trip too.
        let mut nf = m.clone();
        nf.filter = None;
        assert_eq!(ShardManifest::from_json(&nf.to_json()).unwrap(), nf);
        // A version-tag mismatch refuses to decode (a manifest written
        // by a different simulator version must not merge).
        let mut doc = m.to_json();
        doc.set("version", "dsd-sim-0".into());
        assert!(ShardManifest::from_json(&doc).is_none());
        // Out-of-range shard specs refuse to decode.
        let mut doc = m.to_json();
        doc.set(
            "shard",
            Json::obj().with("index", 4u64.into()).with("count", 4u64.into()),
        );
        assert!(ShardManifest::from_json(&doc).is_none());
    }

    #[test]
    fn manifest_write_load_and_scan() {
        let dir = std::env::temp_dir().join(format!("dsd-shard-manifest-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |index: usize| ShardManifest {
            shard: ShardSpec { index, count: 2 },
            grid_hash: "cd".repeat(16),
            streaming: false,
            filter: None,
            cells_total: 8,
            cells_in_shard: 4,
            failed_cells: 0,
            stats: RunStats { total: 4, executed: 4, ..RunStats::default() },
        };
        mk(0).write_to(&dir).unwrap();
        mk(1).write_to(&dir).unwrap();
        // A stale tmp file and an unrelated file are ignored by the scan.
        std::fs::write(dir.join("summary-shard-0-of-2.json.tmp.99"), "junk").unwrap();
        std::fs::write(dir.join("summary.json"), "{}").unwrap();
        let found = find_manifests(&dir).unwrap();
        assert_eq!(found.len(), 2);
        assert_eq!(found[0].1.shard.index, 0);
        assert_eq!(found[1].1.shard.index, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
