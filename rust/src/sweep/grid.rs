//! Declarative sweep grids: a base [`SimConfig`] plus one value list per
//! swept axis, expanded into the cross product of concrete cell configs.
//!
//! Axes (all optional; an absent axis pins the base value):
//! scenario (scripted dynamics), autoscale (elastic target pools),
//! classes (multi-tenant request tiers), RTT, jitter, arrival rate,
//! dataset, routing / batching / window policy, round execution mode
//! (sequential | pipelined), cluster scale (target and drafter counts),
//! and seed.
//!
//! Expansion order is fixed and documented — outermost to innermost:
//! `scenario → autoscale → classes → dataset → routing → batching →
//! window → execution → targets → drafters → rtt → jitter → rate →
//! seed` — so cell indices are stable and seed replicas of one
//! configuration are adjacent.

use crate::autoscale::AutoscaleConfig;
use crate::config::{
    parse_batching, parse_routing, BatchingKind, ClassesConfig, RoutingKind, SimConfig,
    WindowKind,
};
use crate::scenario::Scenario;
use crate::specdec::ExecutionMode;
use crate::util::json::Json;
use crate::util::yaml;

/// One expanded grid cell: a concrete config plus its axis labels.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Position in expansion order (result ordering key). Preserved
    /// across filtering, so a filtered run reports the same indices the
    /// full grid would.
    pub index: usize,
    /// `(axis, value)` pairs in expansion order.
    pub labels: Vec<(String, String)>,
    /// Fully resolved simulator configuration.
    pub cfg: SimConfig,
}

impl SweepCell {
    /// Value of one axis label (None for an unknown axis name).
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// Parse a `--filter` axis selector: `key=value[,key=value]`. Values
/// compare against cell labels verbatim (e.g. `window=static4`,
/// `rtt_ms=5`).
pub fn parse_filter(s: &str) -> Result<Vec<(String, String)>, String> {
    let mut pairs = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (k, v) = part
            .split_once('=')
            .ok_or_else(|| format!("filter: expected key=value, got '{part}'"))?;
        let (k, v) = (k.trim(), v.trim());
        if k.is_empty() || v.is_empty() {
            return Err(format!("filter: empty key or value in '{part}'"));
        }
        pairs.push((k.to_string(), v.to_string()));
    }
    if pairs.is_empty() {
        return Err("filter: no key=value pairs".into());
    }
    Ok(pairs)
}

/// Canonical rendering of a filter (pairs sorted by key then value):
/// equivalent selections label their partial summaries identically no
/// matter how the user ordered the pairs.
pub fn filter_label(pairs: &[(String, String)]) -> String {
    let mut sorted = pairs.to_vec();
    sorted.sort();
    sorted
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(",")
}

/// Keep only cells whose labels match every filter pair. Unknown axis
/// keys and empty selections are errors (a typo must not silently run
/// nothing / everything). Cell indices are preserved.
pub fn filter_cells(
    cells: Vec<SweepCell>,
    pairs: &[(String, String)],
) -> Result<Vec<SweepCell>, String> {
    if let Some(first) = cells.first() {
        for (k, _) in pairs {
            if first.label(k).is_none() {
                let known: Vec<&str> = first.labels.iter().map(|(lk, _)| lk.as_str()).collect();
                return Err(format!(
                    "filter: unknown axis '{k}' (known: {})",
                    known.join(", ")
                ));
            }
        }
    }
    let kept: Vec<SweepCell> = cells
        .into_iter()
        .filter(|c| pairs.iter().all(|(k, v)| c.label(k) == Some(v.as_str())))
        .collect();
    if kept.is_empty() {
        return Err(format!(
            "filter: no cells match '{}'",
            filter_label(pairs)
        ));
    }
    Ok(kept)
}

/// A declarative parameter grid over [`SimConfig`]s.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    /// Defaults for every knob the axes do not touch.
    pub base: SimConfig,
    /// Scenario axis (scripted dynamics; `None` = static simulation).
    /// In grid YAML the entries are scenario file paths or the literal
    /// `none`; cells are labeled by scenario name.
    pub scenarios: Vec<Option<Scenario>>,
    /// Autoscale axis (elastic target pools; `None` = fixed fleet). In
    /// grid YAML the entries are autoscale file paths or the literal
    /// `none`; cells are labeled by block name.
    pub autoscales: Vec<Option<AutoscaleConfig>>,
    /// Request-class axis (multi-tenant tiers; `None` = single-tenant).
    /// In grid YAML the entries are classes file paths or the literal
    /// `none`; cells are labeled by block name.
    pub classes: Vec<Option<ClassesConfig>>,
    /// Edge–cloud RTT axis, ms.
    pub rtt_ms: Vec<f64>,
    /// Jitter axis, ms.
    pub jitter_ms: Vec<f64>,
    /// Arrival-rate axis, requests/s.
    pub rate_per_s: Vec<f64>,
    /// Dataset axis (gsm8k / cnndm / humaneval).
    pub datasets: Vec<String>,
    /// Routing-policy axis.
    pub routing: Vec<RoutingKind>,
    /// Batching-policy axis.
    pub batching: Vec<BatchingKind>,
    /// Window-policy axis.
    pub windows: Vec<WindowKind>,
    /// Round execution-mode axis (sequential | pipelined).
    pub execution: Vec<ExecutionMode>,
    /// Target-count axis (cluster scale).
    pub targets: Vec<usize>,
    /// Drafter-count axis (cluster scale).
    pub drafters: Vec<usize>,
    /// Seed axis (innermost: replicas of one config are adjacent).
    pub seeds: Vec<u64>,
    /// Run cells in streaming-metrics mode (bounded memory).
    pub streaming: bool,
}

impl SweepGrid {
    /// Grid with every axis pinned to the base config's value.
    pub fn new(base: SimConfig) -> SweepGrid {
        SweepGrid {
            scenarios: vec![base.scenario.clone()],
            autoscales: vec![base.autoscale.clone()],
            classes: vec![base.classes.clone()],
            rtt_ms: vec![base.network.rtt_ms],
            jitter_ms: vec![base.network.jitter_ms],
            rate_per_s: vec![base.workload.rate_per_s],
            datasets: vec![base.workload.dataset.clone()],
            routing: vec![base.routing],
            batching: vec![base.batching],
            windows: vec![base.window.clone()],
            execution: vec![base.execution],
            targets: vec![base.n_targets()],
            drafters: vec![base.n_drafters()],
            seeds: vec![base.seed],
            streaming: false,
            base,
        }
    }

    /// Number of cells the grid expands to.
    pub fn n_cells(&self) -> usize {
        self.scenarios.len()
            * self.autoscales.len()
            * self.classes.len()
            * self.datasets.len()
            * self.routing.len()
            * self.batching.len()
            * self.windows.len()
            * self.execution.len()
            * self.targets.len()
            * self.drafters.len()
            * self.rtt_ms.len()
            * self.jitter_ms.len()
            * self.rate_per_s.len()
            * self.seeds.len()
    }

    /// Parse a grid document (see `examples/sweep_grid.yaml`):
    ///
    /// ```yaml
    /// base:            # optional; same schema as `dsd simulate` configs
    ///   workload:
    ///     requests: 2000
    /// sweep:
    ///   rtt_ms: [5, 20, 80]
    ///   rate_per_s: [20, 40]
    ///   window: [static, static:6, fused]
    ///   seeds: [1, 2]
    /// streaming: true  # optional, default false
    /// ```
    pub fn from_yaml(text: &str) -> Result<SweepGrid, String> {
        let doc = yaml::parse(text).map_err(|e| e.to_string())?;
        // Strict at the document level too: a misspelled `sweep:` would
        // otherwise silently collapse the grid to one cell.
        match &doc {
            Json::Obj(pairs) => {
                for (k, _) in pairs {
                    if !["base", "sweep", "streaming"].contains(&k.as_str()) {
                        return Err(format!(
                            "sweep grid: unknown top-level key '{k}' \
                             (known: base, sweep, streaming)"
                        ));
                    }
                }
            }
            Json::Null => {}
            _ => return Err("sweep grid: expected a mapping document".into()),
        }
        let base = match doc.get("base") {
            Some(b) => SimConfig::from_json(b)?,
            None => SimConfig::builder().build(),
        };
        let mut grid = SweepGrid::new(base);
        if let Some(x) = doc.get("streaming") {
            grid.streaming = x
                .as_bool()
                .ok_or_else(|| "sweep grid: 'streaming' must be a boolean".to_string())?;
        }
        let Some(sweep) = doc.get("sweep") else {
            return Ok(grid);
        };
        const KNOWN: &[&str] = &[
            "scenario", "autoscale", "classes", "rtt_ms", "jitter_ms", "rate_per_s",
            "dataset", "routing", "batching", "window", "execution", "targets",
            "drafters", "seeds",
        ];
        if let Json::Obj(pairs) = sweep {
            for (k, _) in pairs {
                if !KNOWN.contains(&k.as_str()) {
                    return Err(format!(
                        "sweep: unknown axis '{k}' (known: {})",
                        KNOWN.join(", ")
                    ));
                }
            }
        } else {
            return Err("sweep: expected a mapping of axes".into());
        }
        if let Some(v) = sweep.get("scenario") {
            grid.scenarios = str_axis("scenario", v)?
                .iter()
                .map(|s| {
                    if s.as_str() == "none" {
                        Ok(None)
                    } else {
                        Scenario::from_yaml_file(s).map(Some)
                    }
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(v) = sweep.get("autoscale") {
            grid.autoscales = str_axis("autoscale", v)?
                .iter()
                .map(|s| {
                    if s.as_str() == "none" {
                        Ok(None)
                    } else {
                        AutoscaleConfig::from_yaml_file(s).map(Some)
                    }
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(v) = sweep.get("classes") {
            grid.classes = str_axis("classes", v)?
                .iter()
                .map(|s| {
                    if s.as_str() == "none" {
                        Ok(None)
                    } else {
                        ClassesConfig::from_yaml_file(s).map(Some)
                    }
                })
                .collect::<Result<_, String>>()?;
        }
        if let Some(v) = sweep.get("rtt_ms") {
            grid.rtt_ms = f64_axis("rtt_ms", v)?;
        }
        if let Some(v) = sweep.get("jitter_ms") {
            grid.jitter_ms = f64_axis("jitter_ms", v)?;
        }
        if let Some(v) = sweep.get("rate_per_s") {
            grid.rate_per_s = f64_axis("rate_per_s", v)?;
        }
        if let Some(v) = sweep.get("dataset") {
            grid.datasets = str_axis("dataset", v)?;
        }
        if let Some(v) = sweep.get("routing") {
            grid.routing = str_axis("routing", v)?
                .iter()
                .map(|s| parse_routing(s))
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = sweep.get("batching") {
            grid.batching = str_axis("batching", v)?
                .iter()
                .map(|s| parse_batching(s))
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = sweep.get("window") {
            grid.windows = str_axis("window", v)?
                .iter()
                .map(|s| parse_window_axis(s))
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = sweep.get("execution") {
            grid.execution = str_axis("execution", v)?
                .iter()
                .map(|s| ExecutionMode::parse(s).map_err(|e| format!("sweep: {e}")))
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = sweep.get("targets") {
            grid.targets = usize_axis("targets", v)?;
        }
        if let Some(v) = sweep.get("drafters") {
            grid.drafters = usize_axis("drafters", v)?;
        }
        if let Some(v) = sweep.get("seeds") {
            grid.seeds = u64_axis("seeds", v)?;
        }
        Ok(grid)
    }

    /// Load a grid from a YAML file.
    pub fn from_yaml_file(path: &str) -> Result<SweepGrid, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::from_yaml(&text)
    }

    /// Expand into concrete cells, validating every config.
    pub fn expand(&self) -> Result<Vec<SweepCell>, String> {
        if self.n_cells() == 0 {
            return Err("sweep: a swept axis is empty".into());
        }
        let mut cells = Vec::with_capacity(self.n_cells());
        for scenario in &self.scenarios {
            for autoscale in &self.autoscales {
                for classes in &self.classes {
                    for ds in &self.datasets {
                        for &routing in &self.routing {
                            for &batching in &self.batching {
                                for window in &self.windows {
                                    for &execution in &self.execution {
                                        for &n_targets in &self.targets {
                                            for &n_drafters in &self.drafters {
                                                for &rtt in &self.rtt_ms {
                                                    for &jitter in &self.jitter_ms {
                                                        for &rate in &self.rate_per_s {
                                                            for &seed in &self.seeds {
                                                                let cfg = self.cell_config(
                                                                    scenario, autoscale,
                                                                    classes, ds, routing,
                                                                    batching, window,
                                                                    execution,
                                                                    n_targets, n_drafters,
                                                                    rtt, jitter, rate, seed,
                                                                )?;
                                                                let mut labels = vec![
                                                                    (
                                                                        "scenario".to_string(),
                                                                        scenario_label(scenario),
                                                                    ),
                                                                    (
                                                                        "autoscale".to_string(),
                                                                        autoscale_label(autoscale),
                                                                    ),
                                                                    (
                                                                        "classes".to_string(),
                                                                        classes_label(classes),
                                                                    ),
                                                                ];
                                                                labels.extend(labels_for(
                                                                    ds, routing, batching,
                                                                    window, execution,
                                                                    n_targets,
                                                                    n_drafters, rtt, jitter,
                                                                    rate, seed,
                                                                ));
                                                                cells.push(SweepCell {
                                                                    index: cells.len(),
                                                                    labels,
                                                                    cfg,
                                                                });
                                                            }
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(cells)
    }

    #[allow(clippy::too_many_arguments)]
    fn cell_config(
        &self,
        scenario: &Option<Scenario>,
        autoscale: &Option<AutoscaleConfig>,
        classes: &Option<ClassesConfig>,
        dataset: &str,
        routing: RoutingKind,
        batching: BatchingKind,
        window: &WindowKind,
        execution: ExecutionMode,
        n_targets: usize,
        n_drafters: usize,
        rtt: f64,
        jitter: f64,
        rate: f64,
        seed: u64,
    ) -> Result<SimConfig, String> {
        let mut cfg = self.base.clone();
        cfg.scenario = scenario.clone();
        cfg.autoscale = autoscale.clone();
        cfg.classes = classes.clone();
        cfg.seed = seed;
        cfg.workload.dataset = dataset.to_string();
        cfg.workload.rate_per_s = rate;
        cfg.routing = routing;
        cfg.batching = batching;
        cfg.window = window.clone();
        cfg.execution = execution;
        cfg.network.rtt_ms = rtt;
        cfg.network.jitter_ms = jitter;
        scale_pools(&mut cfg.target_pools, n_targets, "targets")?;
        scale_pools(&mut cfg.drafter_pools, n_drafters, "drafters")?;
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Stable label for a scenario axis entry.
pub fn scenario_label(s: &Option<Scenario>) -> String {
    match s {
        Some(s) => s.name.clone(),
        None => "none".into(),
    }
}

/// Stable label for an autoscale axis entry.
pub fn autoscale_label(a: &Option<AutoscaleConfig>) -> String {
    match a {
        Some(a) => a.name.clone(),
        None => "none".into(),
    }
}

/// Stable label for a request-classes axis entry.
pub fn classes_label(c: &Option<ClassesConfig>) -> String {
    match c {
        Some(c) => c.name.clone(),
        None => "none".into(),
    }
}

/// Resize a pool list to `want` devices by adjusting the first slice
/// (later slices — and their link overrides — are preserved). A no-op
/// when the total already matches, so heterogeneous base pools survive
/// single-valued scale axes untouched.
fn scale_pools(
    pools: &mut [crate::config::PoolSpec],
    want: usize,
    what: &str,
) -> Result<(), String> {
    let total: usize = pools.iter().map(|p| p.count).sum();
    if total == want {
        return Ok(());
    }
    let Some(first) = pools.first_mut() else {
        return Err(format!("sweep: cannot scale empty {what} pools"));
    };
    let rest = total - first.count;
    if want < rest {
        return Err(format!(
            "sweep: {what}={want} smaller than the {rest} devices in trailing pool slices"
        ));
    }
    first.count = want - rest;
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn labels_for(
    dataset: &str,
    routing: RoutingKind,
    batching: BatchingKind,
    window: &WindowKind,
    execution: ExecutionMode,
    n_targets: usize,
    n_drafters: usize,
    rtt: f64,
    jitter: f64,
    rate: f64,
    seed: u64,
) -> Vec<(String, String)> {
    vec![
        ("dataset".into(), dataset.to_string()),
        ("routing".into(), routing_label(routing).into()),
        ("batching".into(), batching_label(batching).into()),
        ("window".into(), window_label(window)),
        ("execution".into(), execution.label().into()),
        ("targets".into(), n_targets.to_string()),
        ("drafters".into(), n_drafters.to_string()),
        ("rtt_ms".into(), format!("{rtt}")),
        ("jitter_ms".into(), format!("{jitter}")),
        ("rate_per_s".into(), format!("{rate}")),
        ("seed".into(), seed.to_string()),
    ]
}

/// Stable label for a routing kind.
pub fn routing_label(k: RoutingKind) -> &'static str {
    match k {
        RoutingKind::Random => "random",
        RoutingKind::RoundRobin => "round_robin",
        RoutingKind::Jsq => "jsq",
    }
}

/// Stable label for a batching kind.
pub fn batching_label(k: BatchingKind) -> &'static str {
    match k {
        BatchingKind::Fifo => "fifo",
        BatchingKind::Lab => "lab",
    }
}

/// Stable label for a window kind.
pub fn window_label(w: &WindowKind) -> String {
    match w {
        WindowKind::Static(g) => format!("static{g}"),
        WindowKind::Dynamic { .. } => "dynamic".into(),
        WindowKind::Awc { .. } => "awc".into(),
        WindowKind::FusedOnly => "fused".into(),
    }
}

/// Window axis entry: `static`, `static:<γ>`, `dynamic`, `awc`, `fused`.
pub fn parse_window_axis(s: &str) -> Result<WindowKind, String> {
    if let Some(g) = s.strip_prefix("static:") {
        let g: u32 = g.parse().map_err(|_| format!("window: bad gamma '{g}'"))?;
        return Ok(WindowKind::Static(g.max(1)));
    }
    crate::config::parse_window(s, 4, None)
}

fn axis_items<'j>(name: &str, v: &'j Json) -> Result<Vec<&'j Json>, String> {
    match v {
        Json::Arr(xs) if xs.is_empty() => Err(format!("sweep: axis '{name}' is empty")),
        Json::Arr(xs) => Ok(xs.iter().collect()),
        // A bare scalar pins the axis to one value.
        other => Ok(vec![other]),
    }
}

fn f64_axis(name: &str, v: &Json) -> Result<Vec<f64>, String> {
    axis_items(name, v)?
        .into_iter()
        .map(|x| {
            x.as_f64()
                .ok_or_else(|| format!("sweep: axis '{name}' expects numbers"))
        })
        .collect()
}

fn usize_axis(name: &str, v: &Json) -> Result<Vec<usize>, String> {
    axis_items(name, v)?
        .into_iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| format!("sweep: axis '{name}' expects non-negative integers"))
        })
        .collect()
}

fn u64_axis(name: &str, v: &Json) -> Result<Vec<u64>, String> {
    axis_items(name, v)?
        .into_iter()
        .map(|x| {
            x.as_u64()
                .ok_or_else(|| format!("sweep: axis '{name}' expects non-negative integers"))
        })
        .collect()
}

fn str_axis(name: &str, v: &Json) -> Result<Vec<String>, String> {
    axis_items(name, v)?
        .into_iter()
        .map(|x| {
            x.as_str()
                .map(String::from)
                .ok_or_else(|| format!("sweep: axis '{name}' expects strings"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_yaml() -> &'static str {
        "\
base:
  workload:
    requests: 16
    rate_per_s: 10
  cluster:
    targets:
      - count: 2
        gpu: a100
        tp: 4
        model: llama2-70b
    drafters:
      - count: 8
        gpu: a40
        model: llama2-7b
sweep:
  rtt_ms: [5, 40]
  rate_per_s: [10, 20]
  window: [static, fused]
  seeds: [1, 2]
streaming: true
"
    }

    #[test]
    fn yaml_grid_expands_cross_product() {
        let grid = SweepGrid::from_yaml(small_yaml()).unwrap();
        assert!(grid.streaming);
        assert_eq!(grid.n_cells(), 16);
        let cells = grid.expand().unwrap();
        assert_eq!(cells.len(), 16);
        // Indices are positional and labels track expansion order:
        // window is outer relative to rtt, seeds are innermost.
        assert_eq!(cells[0].index, 0);
        assert_eq!(cells[0].cfg.seed, 1);
        assert_eq!(cells[1].cfg.seed, 2);
        assert_eq!(cells[0].cfg.network.rtt_ms, 5.0);
        assert_eq!(cells[4].cfg.network.rtt_ms, 40.0);
        assert!(matches!(cells[0].cfg.window, WindowKind::Static(4)));
        assert!(matches!(cells[8].cfg.window, WindowKind::FusedOnly));
        let label = |c: &SweepCell, k: &str| {
            c.labels
                .iter()
                .find(|(lk, _)| lk == k)
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        assert_eq!(label(&cells[0], "window"), "static4");
        assert_eq!(label(&cells[8], "window"), "fused");
        assert_eq!(label(&cells[2], "rate_per_s"), "20");
    }

    #[test]
    fn scalar_axis_and_defaults() {
        let grid = SweepGrid::from_yaml("sweep:\n  rtt_ms: 30\n").unwrap();
        assert_eq!(grid.rtt_ms, vec![30.0]);
        assert!(!grid.streaming);
        // Unswept axes pin the base values.
        assert_eq!(grid.seeds, vec![42]);
        assert_eq!(grid.n_cells(), 1);
    }

    #[test]
    fn unknown_axis_rejected() {
        let err = SweepGrid::from_yaml("sweep:\n  rttms: [1]\n").unwrap_err();
        assert!(err.contains("unknown axis"), "{err}");
    }

    #[test]
    fn unknown_top_level_key_rejected() {
        // A misspelled `sweep:` must not silently become a 1-cell grid.
        let err = SweepGrid::from_yaml("sweeps:\n  rtt_ms: [1, 2]\n").unwrap_err();
        assert!(err.contains("unknown top-level key"), "{err}");
    }

    #[test]
    fn non_bool_streaming_rejected() {
        let err = SweepGrid::from_yaml("streaming: 1\n").unwrap_err();
        assert!(err.contains("streaming"), "{err}");
        // Empty document is still a valid 1-cell grid.
        assert_eq!(SweepGrid::from_yaml("").unwrap().n_cells(), 1);
    }

    #[test]
    fn bad_axis_values_rejected() {
        assert!(SweepGrid::from_yaml("sweep:\n  rtt_ms: [a]\n").is_err());
        assert!(SweepGrid::from_yaml("sweep:\n  window: [nope]\n").is_err());
        assert!(SweepGrid::from_yaml("sweep:\n  routing: [nope]\n").is_err());
    }

    #[test]
    fn window_axis_syntax() {
        assert!(matches!(parse_window_axis("static:6"), Ok(WindowKind::Static(6))));
        assert!(matches!(parse_window_axis("static"), Ok(WindowKind::Static(4))));
        assert!(matches!(parse_window_axis("fused"), Ok(WindowKind::FusedOnly)));
        assert!(parse_window_axis("static:x").is_err());
    }

    #[test]
    fn cluster_scale_axis_resizes_first_slice() {
        let mut grid = SweepGrid::new(SimConfig::builder().requests(8).build());
        grid.targets = vec![2, 6];
        grid.seeds = vec![1];
        let cells = grid.expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].cfg.n_targets(), 2);
        assert_eq!(cells[1].cfg.n_targets(), 6);
        // Drafter pools untouched (single-valued axis, same total).
        assert_eq!(cells[0].cfg.n_drafters(), 100);
    }

    #[test]
    fn filter_parses_and_selects() {
        let grid = SweepGrid::from_yaml(small_yaml()).unwrap();
        let cells = grid.expand().unwrap();
        let pairs = parse_filter("rtt_ms=5, seed=1").unwrap();
        let kept = filter_cells(cells.clone(), &pairs).unwrap();
        // 16 cells / (2 rtt × 2 seeds) = 4 survivors.
        assert_eq!(kept.len(), 4);
        for c in &kept {
            assert_eq!(c.label("rtt_ms"), Some("5"));
            assert_eq!(c.label("seed"), Some("1"));
        }
        // Original grid indices survive filtering.
        assert!(kept.windows(2).all(|w| w[0].index < w[1].index));
        assert_ne!(kept[1].index, 1);
    }

    #[test]
    fn filter_label_is_order_canonical() {
        let a = parse_filter("seed=1,rtt_ms=5").unwrap();
        let b = parse_filter("rtt_ms=5,seed=1").unwrap();
        assert_eq!(filter_label(&a), filter_label(&b));
        assert_eq!(filter_label(&a), "rtt_ms=5,seed=1");
    }

    #[test]
    fn bad_filters_rejected() {
        assert!(parse_filter("").is_err());
        assert!(parse_filter("rtt_ms").is_err());
        assert!(parse_filter("=5").is_err());
        let grid = SweepGrid::from_yaml(small_yaml()).unwrap();
        let cells = grid.expand().unwrap();
        // Unknown axis key.
        let err = filter_cells(cells.clone(), &parse_filter("rttms=5").unwrap()).unwrap_err();
        assert!(err.contains("unknown axis"), "{err}");
        // No match.
        let err = filter_cells(cells, &parse_filter("rtt_ms=999").unwrap()).unwrap_err();
        assert!(err.contains("no cells match"), "{err}");
    }

    #[test]
    fn scenario_axis_expands_outermost_and_labels_cells() {
        use crate::scenario::{Scenario, ScenarioEvent, TimedEvent};
        let mut grid = SweepGrid::new(SimConfig::builder().requests(8).build());
        grid.seeds = vec![1, 2];
        grid.scenarios = vec![
            None,
            Some(Scenario {
                name: "flap".into(),
                arrivals: None,
                events: vec![TimedEvent {
                    at_ms: 100.0,
                    event: ScenarioEvent::LinkDegrade {
                        pool: None,
                        rtt_mult: 4.0,
                        jitter_mult: 1.0,
                        bandwidth_mult: 1.0,
                    },
                }],
            }),
        ];
        assert_eq!(grid.n_cells(), 4);
        let cells = grid.expand().unwrap();
        assert_eq!(cells.len(), 4);
        // Scenario is the outermost axis: seeds iterate inside it.
        assert_eq!(cells[0].label("scenario"), Some("none"));
        assert_eq!(cells[1].label("scenario"), Some("none"));
        assert_eq!(cells[2].label("scenario"), Some("flap"));
        assert_eq!(cells[3].label("scenario"), Some("flap"));
        assert!(cells[0].cfg.scenario.is_none());
        assert_eq!(cells[2].cfg.scenario.as_ref().unwrap().name, "flap");
        assert_eq!(cells[2].cfg.seed, 1);
        // The scenario axis filters like any other.
        let kept = filter_cells(cells, &parse_filter("scenario=flap").unwrap()).unwrap();
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn scenario_axis_from_yaml_loads_files() {
        let dir = std::env::temp_dir().join(format!(
            "dsd-grid-scn-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("burst.yaml");
        std::fs::write(
            &path,
            "arrivals:\n  kind: mmpp\n  rate_lo_per_s: 10\n  rate_hi_per_s: 60\n  dwell_lo_ms: 3000\n  dwell_hi_ms: 1000\n",
        )
        .unwrap();
        let y = format!(
            "base:\n  workload:\n    requests: 8\nsweep:\n  scenario: [none, {}]\n",
            path.display()
        );
        let grid = SweepGrid::from_yaml(&y).unwrap();
        assert_eq!(grid.scenarios.len(), 2);
        assert!(grid.scenarios[0].is_none());
        // File stem becomes the scenario name (no name: key in the file).
        assert_eq!(grid.scenarios[1].as_ref().unwrap().name, "burst");
        assert_eq!(grid.n_cells(), 2);
        // A missing file is an error, not a silent no-scenario cell.
        let bad = "sweep:\n  scenario: [/nonexistent/scn.yaml]\n";
        assert!(SweepGrid::from_yaml(bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn autoscale_axis_expands_and_labels_cells() {
        use crate::autoscale::{AutoscaleConfig, ScalingPolicy};
        let mut grid = SweepGrid::new(
            SimConfig::builder().targets(4).requests(8).build(),
        );
        grid.seeds = vec![1, 2];
        grid.autoscales = vec![
            None,
            Some(AutoscaleConfig {
                name: "elastic".into(),
                policy: ScalingPolicy::default_reactive(),
                min_targets: 1,
                max_targets: Some(4),
                initial_targets: Some(2),
                ..AutoscaleConfig::default()
            }),
        ];
        assert_eq!(grid.n_cells(), 4);
        let cells = grid.expand().unwrap();
        assert_eq!(cells.len(), 4);
        // Autoscale sits just inside scenario: seeds iterate inside it.
        assert_eq!(cells[0].label("autoscale"), Some("none"));
        assert_eq!(cells[1].label("autoscale"), Some("none"));
        assert_eq!(cells[2].label("autoscale"), Some("elastic"));
        assert_eq!(cells[3].label("autoscale"), Some("elastic"));
        assert!(cells[0].cfg.autoscale.is_none());
        assert_eq!(cells[2].cfg.autoscale.as_ref().unwrap().name, "elastic");
        assert_eq!(cells[2].cfg.seed, 1);
        // The axis filters like any other.
        let kept = filter_cells(cells, &parse_filter("autoscale=elastic").unwrap()).unwrap();
        assert_eq!(kept.len(), 2);
        // YAML: a missing file is an error, not a silent fixed-fleet cell.
        let bad = "sweep:\n  autoscale: [/nonexistent/auto.yaml]\n";
        assert!(SweepGrid::from_yaml(bad).is_err());
        // And the literal `none` pins the fixed fleet.
        let g = SweepGrid::from_yaml("sweep:\n  autoscale: [none]\n").unwrap();
        assert_eq!(g.autoscales, vec![None]);
    }

    #[test]
    fn classes_axis_expands_and_labels_cells() {
        use crate::config::{ClassSpec, ClassesConfig};
        use crate::metrics::SloSpec;
        use crate::scenario::ArrivalProcess;
        let mut grid = SweepGrid::new(SimConfig::builder().requests(8).build());
        grid.seeds = vec![1, 2];
        grid.classes = vec![
            None,
            Some(ClassesConfig {
                name: "two_tier".into(),
                tiers: vec![
                    ClassSpec {
                        name: "interactive".into(),
                        arrivals: ArrivalProcess::Constant { rate_per_s: 10.0 },
                        slo: SloSpec::INTERACTIVE,
                    },
                    ClassSpec {
                        name: "batch".into(),
                        arrivals: ArrivalProcess::Constant { rate_per_s: 5.0 },
                        slo: SloSpec::RELAXED,
                    },
                ],
                priority_admission: true,
                defer_batch_threshold: None,
            }),
        ];
        assert_eq!(grid.n_cells(), 4);
        let cells = grid.expand().unwrap();
        // Classes sits just inside autoscale: seeds iterate inside it.
        assert_eq!(cells[0].label("classes"), Some("none"));
        assert_eq!(cells[1].label("classes"), Some("none"));
        assert_eq!(cells[2].label("classes"), Some("two_tier"));
        assert_eq!(cells[3].label("classes"), Some("two_tier"));
        assert!(cells[0].cfg.classes.is_none());
        assert_eq!(cells[2].cfg.classes.as_ref().unwrap().n_classes(), 2);
        assert_eq!(cells[2].cfg.seed, 1);
        // The axis filters like any other.
        let kept = filter_cells(cells, &parse_filter("classes=two_tier").unwrap()).unwrap();
        assert_eq!(kept.len(), 2);
        // YAML: a missing file is an error, not a silent single-tenant cell.
        let bad = "sweep:\n  classes: [/nonexistent/classes.yaml]\n";
        assert!(SweepGrid::from_yaml(bad).is_err());
        // And the literal `none` pins single-tenant serving.
        let g = SweepGrid::from_yaml("sweep:\n  classes: [none]\n").unwrap();
        assert_eq!(g.classes, vec![None]);
    }

    #[test]
    fn execution_axis_expands_and_labels_cells() {
        let mut grid = SweepGrid::new(SimConfig::builder().requests(8).build());
        grid.seeds = vec![1, 2];
        grid.execution = vec![ExecutionMode::Sequential, ExecutionMode::Pipelined];
        assert_eq!(grid.n_cells(), 4);
        let cells = grid.expand().unwrap();
        // Execution sits just inside window: seeds iterate inside it.
        assert_eq!(cells[0].label("execution"), Some("sequential"));
        assert_eq!(cells[1].label("execution"), Some("sequential"));
        assert_eq!(cells[2].label("execution"), Some("pipelined"));
        assert_eq!(cells[3].label("execution"), Some("pipelined"));
        assert_eq!(cells[0].cfg.execution, ExecutionMode::Sequential);
        assert_eq!(cells[2].cfg.execution, ExecutionMode::Pipelined);
        assert_eq!(cells[2].cfg.seed, 1);
        // The axis filters like any other.
        let kept = filter_cells(cells, &parse_filter("execution=pipelined").unwrap()).unwrap();
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn execution_axis_from_yaml() {
        let grid =
            SweepGrid::from_yaml("sweep:\n  execution: [sequential, pipelined]\n").unwrap();
        assert_eq!(
            grid.execution,
            vec![ExecutionMode::Sequential, ExecutionMode::Pipelined]
        );
        assert_eq!(grid.n_cells(), 2);
        // An unswept grid pins the base mode (sequential by default).
        let pinned = SweepGrid::from_yaml("sweep:\n  rtt_ms: [5]\n").unwrap();
        assert_eq!(pinned.execution, vec![ExecutionMode::Sequential]);
        // Unknown mode names are rejected with the parse error.
        let err = SweepGrid::from_yaml("sweep:\n  execution: [overlapped]\n").unwrap_err();
        assert!(err.contains("unknown execution mode"), "{err}");
    }

    #[test]
    fn scale_below_trailing_slices_rejected() {
        use crate::experiments::common::cloud_pool_20;
        let mut base = SimConfig::builder().requests(8).build();
        base.target_pools = cloud_pool_20();
        let mut grid = SweepGrid::new(base);
        // cloud_pool_20 = slices of 8 + 6 + 6; scaling to 5 < 12 trailing.
        grid.targets = vec![5];
        assert!(grid.expand().is_err());
    }
}
