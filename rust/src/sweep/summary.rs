//! Sweep result aggregation: deterministic JSON emission and an ASCII
//! table for terminals.

use super::runner::CellResult;
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

/// Ordered collection of executed cells plus run metadata.
#[derive(Clone, Debug)]
pub struct SweepSummary {
    /// Cell results in grid expansion order.
    pub cells: Vec<CellResult>,
    /// Whether cells ran in streaming-metrics mode.
    pub streaming: bool,
    /// Canonical `--filter` selector when the cells are an axis-filtered
    /// subset of their grid; `None` for full-grid summaries. Filtered
    /// summaries are labeled partial in JSON and table output, but stay
    /// byte-deterministic for a given (grid, filter) pair.
    pub filter: Option<String>,
}

impl SweepSummary {
    /// Wrap runner output (full-grid summary).
    pub fn new(cells: Vec<CellResult>, streaming: bool) -> SweepSummary {
        SweepSummary { cells, streaming, filter: None }
    }

    /// Mark this summary as an axis-filtered partial run.
    pub fn with_filter(mut self, filter: Option<String>) -> SweepSummary {
        self.filter = filter;
        self
    }

    /// Cells that failed to run.
    pub fn n_failed(&self) -> usize {
        self.cells.iter().filter(|c| c.outcome.is_err()).count()
    }

    /// Axis keys whose value varies across cells (the interesting
    /// columns; single-cell summaries report every axis).
    pub fn varying_axes(&self) -> Vec<String> {
        let Some(first) = self.cells.first() else {
            return Vec::new();
        };
        if self.cells.len() == 1 {
            return first.labels.iter().map(|(k, _)| k.clone()).collect();
        }
        first
            .labels
            .iter()
            .filter(|(k, v)| {
                self.cells
                    .iter()
                    .any(|c| c.label(k).is_some_and(|cv| cv != v))
            })
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Deterministic JSON: cells in index order, insertion-ordered keys,
    /// no wall-clock fields — repeated runs emit identical bytes
    /// regardless of thread count.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj().with("streaming", self.streaming.into());
        if let Some(f) = &self.filter {
            // Present only on filtered runs, so full-grid summaries keep
            // their historical byte layout.
            j.set("partial", true.into());
            j.set("filter", f.as_str().into());
        }
        j.with("cells", (self.cells.len() as u64).into())
            .with("failed", (self.n_failed() as u64).into())
            .with(
                "results",
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|c| {
                            let mut labels = Json::obj();
                            for (k, v) in &c.labels {
                                labels.set(k, v.as_str().into());
                            }
                            let row = Json::obj()
                                .with("index", (c.index as u64).into())
                                .with("labels", labels);
                            match &c.outcome {
                                Ok(m) => row.with("metrics", m.to_json()),
                                Err(e) => row.with("error", e.as_str().into()),
                            }
                        })
                        .collect(),
                ),
            )
    }

    /// Render an ASCII table of the varying axes plus headline metrics.
    pub fn render_table(&self) -> String {
        let axes = self.varying_axes();
        let mut headers: Vec<&str> = vec!["cell"];
        headers.extend(axes.iter().map(String::as_str));
        headers.extend([
            "done", "tput r/s", "ttft ms", "p99 ttft", "tpot ms", "p99 tpot", "acc", "util",
        ]);
        let mut table = Table::new(&headers).with_title(&format!(
            "sweep — {} cells{}{}",
            self.cells.len(),
            if self.streaming { " (streaming)" } else { "" },
            match &self.filter {
                Some(f) => format!(" (partial: {f})"),
                None => String::new(),
            }
        ));
        for c in &self.cells {
            let mut row = vec![c.index.to_string()];
            for a in &axes {
                row.push(c.label(a).unwrap_or_default().to_string());
            }
            match &c.outcome {
                Ok(m) => row.extend([
                    m.completed.to_string(),
                    fnum(m.throughput_rps, 1),
                    fnum(m.mean_ttft_ms, 0),
                    fnum(m.p99_ttft_ms, 0),
                    fnum(m.mean_tpot_ms, 1),
                    fnum(m.p99_tpot_ms, 1),
                    fnum(m.mean_acceptance, 2),
                    fnum(m.target_utilization, 2),
                ]),
                Err(e) => {
                    row.push(format!("error: {e}"));
                    while row.len() < headers.len() {
                        row.push(String::new());
                    }
                }
            }
            table.row(row);
        }
        table.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::runner::CellMetrics;

    fn metrics(x: f64) -> CellMetrics {
        CellMetrics {
            completed: 10,
            throughput_rps: x,
            token_throughput: 100.0,
            target_utilization: 0.5,
            mean_ttft_ms: 100.0,
            p99_ttft_ms: 200.0,
            mean_tpot_ms: 20.0,
            p99_tpot_ms: 40.0,
            mean_e2e_ms: 500.0,
            mean_acceptance: 0.8,
            mean_queue_delay_ms: 1.0,
            mean_net_delay_ms: 5.0,
            sim_duration_ms: 1000.0,
            events_processed: 1234,
            mean_features: [0.4, 0.8, 10.0, 20.0, 4.0],
            time_series: None,
            autoscale: None,
            slo_interactive: None,
            per_class: None,
        }
    }

    fn cell(i: usize, rtt: &str, ok: bool) -> CellResult {
        CellResult {
            index: i,
            labels: vec![
                ("dataset".into(), "gsm8k".into()),
                ("rtt_ms".into(), rtt.into()),
            ],
            outcome: if ok {
                Ok(metrics(10.0 + i as f64))
            } else {
                Err("boom".into())
            },
        }
    }

    #[test]
    fn varying_axes_detected() {
        let s = SweepSummary::new(vec![cell(0, "5", true), cell(1, "40", true)], false);
        assert_eq!(s.varying_axes(), vec!["rtt_ms".to_string()]);
        let single = SweepSummary::new(vec![cell(0, "5", true)], false);
        assert_eq!(single.varying_axes().len(), 2);
    }

    #[test]
    fn json_shape_and_determinism() {
        let s = SweepSummary::new(vec![cell(0, "5", true), cell(1, "40", false)], true);
        let j = s.to_json();
        assert_eq!(j.get("cells").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("failed").unwrap().as_u64(), Some(1));
        let rows = j.get("results").unwrap().as_arr().unwrap();
        assert!(rows[0].get("metrics").is_some());
        assert!(rows[1].get("error").is_some());
        assert_eq!(
            s.to_json().to_string_pretty(),
            j.to_string_pretty(),
            "emission is deterministic"
        );
    }

    #[test]
    fn table_renders_errors_inline() {
        let s = SweepSummary::new(vec![cell(0, "5", true), cell(1, "40", false)], false);
        let t = s.render_table();
        assert!(t.contains("error: boom"));
        assert!(t.contains("rtt_ms"));
    }

    #[test]
    fn filtered_summary_labeled_partial() {
        let s = SweepSummary::new(vec![cell(0, "5", true)], false)
            .with_filter(Some("rtt_ms=5".into()));
        let j = s.to_json();
        assert_eq!(j.get("partial").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("filter").unwrap().as_str(), Some("rtt_ms=5"));
        assert!(s.render_table().contains("partial: rtt_ms=5"));
        // Unfiltered summaries keep the historical layout: no keys added.
        let full = SweepSummary::new(vec![cell(0, "5", true)], false);
        assert!(full.to_json().get("partial").is_none());
        assert!(full.to_json().get("filter").is_none());
    }
}
