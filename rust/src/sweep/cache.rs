//! Per-cell result caching for resumable sweeps.
//!
//! Every sweep cell gets a stable **content key**: the 128-bit FNV hash
//! of the canonical JSON of its fully resolved [`SimConfig`], the metric
//! mode (streaming or full), and the simulator version tag. Finished
//! cells persist as `<cells_dir>/<key>.json` the moment they complete,
//! so a killed sweep loses at most the in-flight cells; re-invoking the
//! same grid loads hits from disk, executes only the misses, and splices
//! both into a byte-identical summary.
//!
//! Because keys are content-addressed (grid position does not enter the
//! hash), one cell directory serves many overlapping grids: a filtered
//! partial run (`--filter`), a widened axis, or a different expansion
//! order all reuse whatever cells they share with previous runs.
//!
//! Invalidation is implicit: anything that changes the resolved config
//! changes the key, and [`SIM_VERSION_TAG`] folds simulator semantics
//! into the key, so bumping the tag orphans every older entry (see
//! `CACHE.md` at the repository root). Corrupt or truncated cell files
//! are detected on load and fall back to re-execution with a warning.
//!
//! Failed cells persist too, as retry-counted markers
//! ([`CellCache::store_failure`]): a failing cell re-executes on each
//! resume until [`MAX_FAILED_ATTEMPTS`] executions have failed, after
//! which the stored error is surfaced directly — a permanently broken
//! cell stops burning simulator time, and the error survives the
//! process that produced it.

use super::runner::CellMetrics;
use crate::config::SimConfig;
use crate::util::hash::content_hash_hex;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Simulator semantics version. Part of every cell key: bump this when a
/// change alters simulation *results* for an unchanged config (the
/// golden-report snapshot drifting is the usual signal), so stale cached
/// cells can never be spliced into new summaries.
pub const SIM_VERSION_TAG: &str = "dsd-sim-1";

/// Bounded retry policy for cached failures: a cell that keeps failing
/// re-executes on each resume until its persisted attempt count reaches
/// this bound; after that the stored error is surfaced without
/// re-entering the simulator (no more re-executing forever — and no
/// silent infinite retry loops on permanently broken cells).
pub const MAX_FAILED_ATTEMPTS: u32 = 3;

/// Content key of one sweep cell: canonical JSON of the resolved config
/// plus metric mode plus [`SIM_VERSION_TAG`], hashed to 32 hex chars.
///
/// One-shot form; sweep workers deriving keys for many cells should hold
/// a [`CellKeyer`], which produces byte-identical keys without the
/// per-cell wrapper construction and string allocations.
pub fn cell_key(cfg: &SimConfig, streaming: bool) -> String {
    let doc = Json::obj()
        .with("version", SIM_VERSION_TAG.into())
        .with("streaming", streaming.into())
        .with("config", cfg.to_canonical_json());
    content_hash_hex(doc.to_string_canonical().as_bytes())
}

/// Reusable cell-key deriver: the invariant portion of the key document
/// is precomputed once, so per-cell derivation only serializes the parts
/// that actually vary (the config axes).
///
/// Canonical (sorted-key) order of the wrapper document is
/// `"config" < "streaming" < "version"`, so its canonical bytes are
/// exactly `{"config":<canonical cfg>,"streaming":<b>,"version":"…"}` —
/// a constant prefix and suffix around the config serialization. Both
/// are frozen at construction; [`CellKeyer::key`] writes the config into
/// a reused buffer between them. Keys are asserted byte-identical to
/// [`cell_key`] (and to the original clone-and-sort serialization path)
/// in this module's tests — cache entries written under either path
/// address the same cells.
pub struct CellKeyer {
    /// `{"config":` — invariant across every cell.
    prefix: &'static str,
    /// `,"streaming":<b>,"version":"<tag>"}` — invariant per keyer.
    suffix: String,
    /// Reused serialization buffer (grows to the largest config seen).
    buf: String,
}

impl CellKeyer {
    /// A keyer for one metric mode (streaming or full).
    pub fn new(streaming: bool) -> CellKeyer {
        CellKeyer {
            prefix: "{\"config\":",
            suffix: format!(",\"streaming\":{streaming},\"version\":\"{SIM_VERSION_TAG}\"}}"),
            buf: String::new(),
        }
    }

    /// Derive the content key for one cell — byte-identical to
    /// [`cell_key`]`(cfg, streaming)`.
    pub fn key(&mut self, cfg: &SimConfig) -> String {
        self.buf.clear();
        self.buf.push_str(self.prefix);
        cfg.to_canonical_json().write_canonical_into(&mut self.buf);
        self.buf.push_str(&self.suffix);
        content_hash_hex(self.buf.as_bytes())
    }
}

/// Outcome of a cache probe.
#[derive(Debug)]
pub enum CacheLookup {
    /// Valid entry: reuse these metrics without executing the cell.
    Hit(CellMetrics),
    /// No entry on disk.
    Miss,
    /// A persisted failure marker: the cell errored `attempts` times.
    /// Below [`MAX_FAILED_ATTEMPTS`] the cell retries (incrementing the
    /// count on another failure); at or above it the stored error is
    /// surfaced without re-execution.
    Failed {
        /// The last execution's error message.
        error: String,
        /// How many executions have failed so far.
        attempts: u32,
    },
    /// An entry exists but is unreadable / truncated / inconsistent;
    /// the cell must re-execute (and the reason is worth a warning).
    Corrupt(String),
}

/// Accounting from one [`CellCache::gc`] pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcStats {
    /// Valid entries left in place.
    pub kept: usize,
    /// Files removed (orphaned, corrupt, version-mismatched, stale tmp).
    pub pruned: usize,
    /// Files that should have been removed but could not be.
    pub failed: usize,
}

impl GcStats {
    /// One-line human rendering.
    pub fn describe(&self) -> String {
        format!(
            "{} entries kept, {} pruned{}",
            self.kept,
            self.pruned,
            if self.failed > 0 {
                format!(", {} could not be removed", self.failed)
            } else {
                String::new()
            }
        )
    }
}

/// On-disk cell store: one JSON file per finished cell, named by its
/// content key.
#[derive(Clone, Debug)]
pub struct CellCache {
    dir: PathBuf,
}

impl CellCache {
    /// Open (creating if needed) a cell directory.
    pub fn open(dir: &Path) -> Result<CellCache, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("cache: create {}: {e}", dir.display()))?;
        Ok(CellCache { dir: dir.to_path_buf() })
    }

    /// The directory cells persist into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of the entry for `key`.
    pub fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{key}.json"))
    }

    /// Number of entries currently on disk (diagnostics).
    pub fn n_entries(&self) -> usize {
        std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter(|e| {
                    e.as_ref()
                        .ok()
                        .and_then(|e| e.path().extension().map(|x| x == "json"))
                        .unwrap_or(false)
                })
                .count()
            })
            .unwrap_or(0)
    }

    /// Probe the cache for `key`.
    pub fn load(&self, key: &str) -> CacheLookup {
        let path = self.path_for(key);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return CacheLookup::Miss,
            Err(e) => return CacheLookup::Corrupt(format!("read {}: {e}", path.display())),
        };
        let doc = match Json::parse(&text) {
            Ok(d) => d,
            Err(e) => return CacheLookup::Corrupt(format!("{}: {e}", path.display())),
        };
        // The key is re-checked so a renamed / mismatched file can never
        // masquerade as a different cell.
        if doc.get("key").and_then(Json::as_str) != Some(key) {
            return CacheLookup::Corrupt(format!("{}: key mismatch", path.display()));
        }
        if doc.get("version").and_then(Json::as_str) != Some(SIM_VERSION_TAG) {
            // Unreachable for files written by this binary (the tag is in
            // the hash), but a defense against hand-edited entries.
            return CacheLookup::Corrupt(format!("{}: version mismatch", path.display()));
        }
        if let Some(f) = doc.get("failed") {
            let (error, attempts) = match (
                f.get("error").and_then(Json::as_str),
                f.get("attempts").and_then(Json::as_u64),
            ) {
                (Some(e), Some(a)) => (e.to_string(), a as u32),
                _ => {
                    return CacheLookup::Corrupt(format!(
                        "{}: bad failure record",
                        path.display()
                    ))
                }
            };
            return CacheLookup::Failed { error, attempts };
        }
        match doc.get("metrics").and_then(CellMetrics::from_json) {
            Some(m) => CacheLookup::Hit(m),
            None => CacheLookup::Corrupt(format!("{}: bad metrics record", path.display())),
        }
    }

    /// Persist a finished cell. Written atomically (tmp file + rename)
    /// so a kill mid-write leaves no half-entry behind under `key`.
    pub fn store(
        &self,
        key: &str,
        labels: &[(String, String)],
        metrics: &CellMetrics,
    ) -> Result<(), String> {
        let doc = Self::entry_doc(key, labels).with("metrics", metrics.to_json());
        self.write_atomic(key, doc)
    }

    /// Persist a *failed* cell as a retry-counted failure marker
    /// (`{"failed": {"error", "attempts"}}`). Overwrites any previous
    /// marker under the key, so the attempt count advances monotonically
    /// across resumes; a later success simply overwrites the marker with
    /// real metrics.
    pub fn store_failure(
        &self,
        key: &str,
        labels: &[(String, String)],
        error: &str,
        attempts: u32,
    ) -> Result<(), String> {
        let doc = Self::entry_doc(key, labels).with(
            "failed",
            Json::obj()
                .with("error", error.into())
                .with("attempts", attempts.into()),
        );
        self.write_atomic(key, doc)
    }

    fn entry_doc(key: &str, labels: &[(String, String)]) -> Json {
        let mut label_obj = Json::obj();
        for (k, v) in labels {
            label_obj.set(k, v.as_str().into());
        }
        Json::obj()
            .with("key", key.into())
            .with("version", SIM_VERSION_TAG.into())
            .with("labels", label_obj)
    }

    fn write_atomic(&self, key: &str, doc: Json) -> Result<(), String> {
        let path = self.path_for(key);
        // Unique tmp name per write: a grid with duplicate cells (e.g. a
        // repeated seed) can store the same key from two workers at
        // once, and interleaved writes to one tmp file would corrupt
        // the renamed entry.
        static WRITE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = WRITE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tmp = self
            .dir
            .join(format!("{key}.json.tmp.{}.{seq}", std::process::id()));
        // Serialize into a thread-local reused buffer: a worker storing
        // thousands of cells reallocates the text once, not per cell.
        // `write_pretty_into` appends the exact bytes `to_string_pretty`
        // returned before, so on-disk entries are unchanged.
        thread_local! {
            static BUF: std::cell::RefCell<String> =
                const { std::cell::RefCell::new(String::new()) };
        }
        BUF.with(|b| {
            let mut text = b.borrow_mut();
            text.clear();
            doc.write_pretty_into(&mut text);
            text.push('\n');
            std::fs::write(&tmp, text.as_bytes())
                .map_err(|e| format!("cache: write {}: {e}", tmp.display()))
        })?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("cache: rename to {}: {e}", path.display()))
    }

    /// Garbage-collect the cell directory (`dsd sweep --gc <dir>`).
    ///
    /// Removes every file the current binary could never splice into a
    /// summary: entries whose [`SIM_VERSION_TAG`] no longer matches
    /// (orphans of a tag bump), corrupt/truncated/misnamed entries, and
    /// stale `*.json.tmp.*` files left by a kill mid-write. When
    /// `valid_keys` is given (the key set of a current grid expansion),
    /// readable entries outside that set are pruned too, narrowing the
    /// directory to exactly the given grid. Files that are not cache
    /// entries at all (no `.json` suffix) are left untouched.
    pub fn gc(
        &self,
        valid_keys: Option<&std::collections::HashSet<String>>,
    ) -> GcStats {
        let mut stats = GcStats::default();
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return stats;
        };
        // Deterministic pass order (read_dir order is fs-dependent).
        let mut paths: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
        paths.sort();
        for path in paths {
            let Some(name) = path.file_name().and_then(|n| n.to_str()).map(String::from)
            else {
                continue;
            };
            let keep = if name.contains(".json.tmp.") {
                false // stale atomic-write temp from a killed run
            } else if let Some(key) = name.strip_suffix(".json") {
                match self.load(key) {
                    // Failure markers are valid entries too: pruning one
                    // would reset its retry budget.
                    CacheLookup::Hit(_) | CacheLookup::Failed { .. } => {
                        valid_keys.is_none_or(|ks| ks.contains(key))
                    }
                    // Unreadable under the current binary: version
                    // mismatch, truncation, or a misnamed entry.
                    CacheLookup::Corrupt(_) | CacheLookup::Miss => false,
                }
            } else {
                continue; // not a cache artifact
            };
            if keep {
                stats.kept += 1;
            } else if std::fs::remove_file(&path).is_ok() {
                stats.pruned += 1;
            } else {
                stats.failed += 1;
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BatchingKind, RoutingKind, WindowKind};
    use crate::util::prop::{run_prop, Gen};

    fn base_cfg() -> SimConfig {
        SimConfig::builder()
            .seed(5)
            .targets(2)
            .drafters(10)
            .requests(16)
            .rate_per_s(12.0)
            .build()
    }

    #[test]
    fn key_shape_and_determinism() {
        let k1 = cell_key(&base_cfg(), false);
        let k2 = cell_key(&base_cfg(), false);
        assert_eq!(k1, k2);
        assert_eq!(k1.len(), 32);
        assert!(k1.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn streaming_mode_is_part_of_the_key() {
        assert_ne!(cell_key(&base_cfg(), false), cell_key(&base_cfg(), true));
    }

    /// The key a [`CellKeyer`] derives is byte-identical to [`cell_key`]
    /// AND to the fully-legacy serialization path (deep clone-and-sort,
    /// then compact) — the three-way check pins both this PR's
    /// optimizations (wrapper precompute, no-clone canonical writer) to
    /// the original bytes, so existing cell directories stay valid.
    #[test]
    fn keyer_matches_one_shot_and_legacy_paths() {
        for streaming in [false, true] {
            let mut keyer = CellKeyer::new(streaming);
            for cfg in [
                base_cfg(),
                SimConfig::builder().seed(99).targets(4).drafters(3).requests(8).build(),
                SimConfig::from_yaml(
                    "seed: 7\nnetwork:\n  rtt_ms: 35\nworkload:\n  requests: 24\n",
                )
                .unwrap(),
            ] {
                let fast = keyer.key(&cfg);
                assert_eq!(fast, cell_key(&cfg, streaming));
                let legacy_doc = Json::obj()
                    .with("version", SIM_VERSION_TAG.into())
                    .with("streaming", streaming.into())
                    .with("config", cfg.to_canonical_json());
                let legacy_bytes = legacy_doc.canonicalize().to_string_compact();
                assert_eq!(fast, content_hash_hex(legacy_bytes.as_bytes()));
            }
        }
    }

    /// Buffer reuse across cells must never leak bytes between keys: a
    /// long config followed by a short one hashes exactly what a fresh
    /// keyer would.
    #[test]
    fn keyer_buffer_reuse_does_not_leak_across_cells() {
        let long = SimConfig::from_yaml(
            "seed: 1\nnetwork:\n  rtt_ms: 20\n  jitter_ms: 2\nworkload:\n  requests: 64\n  dataset: cnndm\n",
        )
        .unwrap();
        let short = base_cfg();
        let mut reused = CellKeyer::new(false);
        let k_long = reused.key(&long);
        let k_short = reused.key(&short);
        assert_eq!(k_long, CellKeyer::new(false).key(&long));
        assert_eq!(k_short, CellKeyer::new(false).key(&short));
        assert_ne!(k_long, k_short);
    }

    #[test]
    fn yaml_field_order_does_not_change_the_key() {
        let a = SimConfig::from_yaml(
            "seed: 3\nnetwork:\n  rtt_ms: 20\n  jitter_ms: 1\nworkload:\n  requests: 50\n",
        )
        .unwrap();
        let b = SimConfig::from_yaml(
            "workload:\n  requests: 50\nnetwork:\n  jitter_ms: 1\n  rtt_ms: 20\nseed: 3\n",
        )
        .unwrap();
        assert_eq!(cell_key(&a, false), cell_key(&b, false));
    }

    /// Property: document key order never affects the key; any single
    /// axis perturbation (rtt, jitter, rate, seed, policy, scale) always
    /// does. Random configs drive both halves from one generator so the
    /// cases replay by seed.
    #[test]
    fn prop_key_stability_and_axis_sensitivity() {
        run_prop("cell-key stability/sensitivity", 60, |g: &mut Gen| {
            let seed = g.u64_in(0, 1 << 40);
            let rtt = g.f64_in(0.0, 200.0);
            let jitter = g.f64_in(0.0, 10.0);
            let rate = g.f64_in(1.0, 100.0);
            let targets = g.usize_in(1, 6);
            let drafters = g.usize_in(1, 40);
            let routing = *g.pick(&[RoutingKind::Random, RoutingKind::RoundRobin, RoutingKind::Jsq]);
            let batching = *g.pick(&[BatchingKind::Fifo, BatchingKind::Lab]);
            let dataset = g.pick(&["gsm8k", "cnndm", "humaneval"]).to_string();
            let build = |seed: u64,
                         rtt: f64,
                         jitter: f64,
                         rate: f64,
                         targets: usize,
                         drafters: usize,
                         routing: RoutingKind,
                         batching: BatchingKind,
                         dataset: &str,
                         window: WindowKind| {
                SimConfig::builder()
                    .seed(seed)
                    .rtt_ms(rtt)
                    .jitter_ms(jitter)
                    .rate_per_s(rate)
                    .targets(targets)
                    .drafters(drafters)
                    .routing(routing)
                    .batching(batching)
                    .dataset(dataset)
                    .window(window)
                    .requests(32)
                    .build()
            };
            let base = build(
                seed, rtt, jitter, rate, targets, drafters, routing, batching, &dataset,
                WindowKind::Static(4),
            );
            let key = cell_key(&base, false);
            // Identical reconstruction ⇒ identical key.
            let again = build(
                seed, rtt, jitter, rate, targets, drafters, routing, batching, &dataset,
                WindowKind::Static(4),
            );
            assert_eq!(key, cell_key(&again, false), "key not a pure function of config");
            // Single-axis perturbations ⇒ different keys.
            let perturbed = [
                build(seed ^ 1, rtt, jitter, rate, targets, drafters, routing, batching, &dataset, WindowKind::Static(4)),
                build(seed, rtt + 0.125, jitter, rate, targets, drafters, routing, batching, &dataset, WindowKind::Static(4)),
                build(seed, rtt, jitter + 0.125, rate, targets, drafters, routing, batching, &dataset, WindowKind::Static(4)),
                build(seed, rtt, jitter, rate + 0.125, targets, drafters, routing, batching, &dataset, WindowKind::Static(4)),
                build(seed, rtt, jitter, rate, targets + 1, drafters, routing, batching, &dataset, WindowKind::Static(4)),
                build(seed, rtt, jitter, rate, targets, drafters + 1, routing, batching, &dataset, WindowKind::Static(4)),
                build(seed, rtt, jitter, rate, targets, drafters, routing, batching, &dataset, WindowKind::Static(5)),
                build(seed, rtt, jitter, rate, targets, drafters, routing, batching, &dataset, WindowKind::FusedOnly),
            ];
            for (i, p) in perturbed.iter().enumerate() {
                assert_ne!(key, cell_key(p, false), "perturbation {i} did not change the key");
            }
            let other_routing = match routing {
                RoutingKind::Jsq => RoutingKind::Random,
                _ => RoutingKind::Jsq,
            };
            let p = build(seed, rtt, jitter, rate, targets, drafters, other_routing, batching, &dataset, WindowKind::Static(4));
            assert_ne!(key, cell_key(&p, false), "routing change did not change the key");
            let other_batching = match batching {
                BatchingKind::Fifo => BatchingKind::Lab,
                BatchingKind::Lab => BatchingKind::Fifo,
            };
            let p = build(seed, rtt, jitter, rate, targets, drafters, routing, other_batching, &dataset, WindowKind::Static(4));
            assert_ne!(key, cell_key(&p, false), "batching change did not change the key");
        });
    }

    /// Property: shuffling the key order of a JSON config document never
    /// changes the cell key (exercises `Gen::permutation`).
    #[test]
    fn prop_json_document_order_irrelevant() {
        run_prop("cell-key doc order", 40, |g: &mut Gen| {
            let sections: Vec<(String, Json)> = vec![
                ("seed".into(), Json::Num(g.u64_in(0, 1000) as f64)),
                (
                    "network".into(),
                    Json::obj()
                        .with("rtt_ms", g.f64_in(0.0, 100.0).into())
                        .with("jitter_ms", g.f64_in(0.0, 5.0).into()),
                ),
                (
                    "workload".into(),
                    Json::obj()
                        .with("requests", Json::Num(g.usize_in(8, 200) as f64))
                        .with("rate_per_s", g.f64_in(1.0, 50.0).into()),
                ),
            ];
            let in_order = Json::Obj(sections.clone());
            let perm = g.permutation(sections.len());
            let shuffled = Json::Obj(perm.iter().map(|&i| sections[i].clone()).collect());
            let a = SimConfig::from_json(&in_order).unwrap();
            let b = SimConfig::from_json(&shuffled).unwrap();
            assert_eq!(cell_key(&a, false), cell_key(&b, false));
        });
    }

    #[test]
    fn store_load_roundtrip_and_corruption() {
        let dir = std::env::temp_dir().join(format!(
            "dsd-cellcache-unit-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = CellCache::open(&dir).unwrap();
        let key = cell_key(&base_cfg(), false);
        assert!(matches!(cache.load(&key), CacheLookup::Miss));

        let m = CellMetrics {
            completed: 16,
            throughput_rps: 11.5,
            token_throughput: 400.0,
            target_utilization: 0.5,
            mean_ttft_ms: 120.0,
            p99_ttft_ms: 300.0,
            mean_tpot_ms: 25.0,
            p99_tpot_ms: 60.0,
            mean_e2e_ms: 900.0,
            mean_acceptance: f64::NAN, // fused-style NaN must round-trip
            mean_queue_delay_ms: 2.0,
            mean_net_delay_ms: 6.0,
            sim_duration_ms: 1500.0,
            events_processed: 999,
            mean_features: [0.25, 0.8, 10.0, 25.0, 4.0],
            time_series: None,
            autoscale: None,
            slo_interactive: None,
            per_class: None,
        };
        let labels = vec![("rtt_ms".to_string(), "10".to_string())];
        cache.store(&key, &labels, &m).unwrap();
        assert_eq!(cache.n_entries(), 1);
        match cache.load(&key) {
            CacheLookup::Hit(got) => {
                assert_eq!(got.completed, 16);
                assert!(got.mean_acceptance.is_nan());
                assert_eq!(got.mean_features, m.mean_features);
                // Byte-stable re-emission: the reloaded metrics must
                // serialize exactly like the originals.
                assert_eq!(
                    got.to_json().to_string_pretty(),
                    m.to_json().to_string_pretty()
                );
            }
            other => panic!("expected hit, got {other:?}"),
        }

        // Truncation ⇒ Corrupt, never a bogus Hit.
        let path = cache.path_for(&key);
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(matches!(cache.load(&key), CacheLookup::Corrupt(_)));

        // A valid file under the wrong name ⇒ Corrupt (key mismatch).
        std::fs::write(&path, &full).unwrap();
        let wrong = cache.path_for(&"0".repeat(32));
        std::fs::copy(&path, &wrong).unwrap();
        assert!(matches!(cache.load(&"0".repeat(32)), CacheLookup::Corrupt(_)));

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failure_markers_roundtrip_and_count_attempts() {
        let dir = std::env::temp_dir().join(format!(
            "dsd-cellcache-fail-unit-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = CellCache::open(&dir).unwrap();
        let key = cell_key(&base_cfg(), false);
        cache.store_failure(&key, &[], "unknown dataset 'nope'", 1).unwrap();
        match cache.load(&key) {
            CacheLookup::Failed { error, attempts } => {
                assert_eq!(error, "unknown dataset 'nope'");
                assert_eq!(attempts, 1);
            }
            other => panic!("expected failure marker, got {other:?}"),
        }
        // Overwriting advances the attempt count.
        cache.store_failure(&key, &[], "unknown dataset 'nope'", 2).unwrap();
        assert!(matches!(cache.load(&key), CacheLookup::Failed { attempts: 2, .. }));
        // A later success replaces the marker entirely.
        let m = CellMetrics {
            completed: 1,
            throughput_rps: 1.0,
            token_throughput: 1.0,
            target_utilization: 0.1,
            mean_ttft_ms: 1.0,
            p99_ttft_ms: 1.0,
            mean_tpot_ms: 1.0,
            p99_tpot_ms: 1.0,
            mean_e2e_ms: 1.0,
            mean_acceptance: 0.5,
            mean_queue_delay_ms: 0.0,
            mean_net_delay_ms: 0.0,
            sim_duration_ms: 1.0,
            events_processed: 1,
            mean_features: [0.0; 5],
            time_series: None,
            autoscale: None,
            slo_interactive: None,
            per_class: None,
        };
        cache.store(&key, &[], &m).unwrap();
        assert!(matches!(cache.load(&key), CacheLookup::Hit(_)));
        // A malformed failure record is Corrupt, never a bogus Failed.
        cache.store_failure(&key, &[], "x", 1).unwrap();
        let text = std::fs::read_to_string(cache.path_for(&key)).unwrap();
        std::fs::write(cache.path_for(&key), text.replace("\"attempts\"", "\"atempts\""))
            .unwrap();
        assert!(matches!(cache.load(&key), CacheLookup::Corrupt(_)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_keeps_failure_markers_in_grid() {
        let dir = std::env::temp_dir().join(format!(
            "dsd-cellcache-gc-fail-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = CellCache::open(&dir).unwrap();
        let key = cell_key(&base_cfg(), false);
        cache.store_failure(&key, &[], "boom", 2).unwrap();
        let stats = cache.gc(None);
        assert_eq!(stats, GcStats { kept: 1, pruned: 0, failed: 0 });
        assert!(matches!(cache.load(&key), CacheLookup::Failed { attempts: 2, .. }));
        // Out-of-grid failure markers prune like any other entry.
        let none: std::collections::HashSet<String> = std::collections::HashSet::new();
        let stats = cache.gc(Some(&none));
        assert_eq!(stats, GcStats { kept: 0, pruned: 1, failed: 0 });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_prunes_unreadable_and_out_of_grid_entries() {
        let dir = std::env::temp_dir().join(format!(
            "dsd-cellcache-gc-unit-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = CellCache::open(&dir).unwrap();
        let key = cell_key(&base_cfg(), false);
        let m = CellMetrics {
            completed: 4,
            throughput_rps: 1.0,
            token_throughput: 10.0,
            target_utilization: 0.5,
            mean_ttft_ms: 10.0,
            p99_ttft_ms: 20.0,
            mean_tpot_ms: 1.0,
            p99_tpot_ms: 2.0,
            mean_e2e_ms: 50.0,
            mean_acceptance: 0.8,
            mean_queue_delay_ms: 0.1,
            mean_net_delay_ms: 0.2,
            sim_duration_ms: 100.0,
            events_processed: 42,
            mean_features: [0.1, 0.2, 0.3, 0.4, 0.5],
            time_series: None,
            autoscale: None,
            slo_interactive: None,
            per_class: None,
        };
        cache.store(&key, &[], &m).unwrap();
        // Orphans: wrong-name copy, old version tag, stale tmp file, and
        // a non-cache file that must be left alone.
        std::fs::copy(cache.path_for(&key), cache.path_for(&"0".repeat(32))).unwrap();
        let old_key = "f".repeat(32);
        std::fs::write(
            cache.path_for(&old_key),
            format!("{{\"key\": \"{old_key}\", \"version\": \"dsd-sim-0\"}}\n"),
        )
        .unwrap();
        std::fs::write(dir.join(format!("{key}.json.tmp.1.0")), "partial").unwrap();
        std::fs::write(dir.join("README"), "not a cell").unwrap();

        // Without a key set: keeps every readable entry, prunes the rest.
        let stats = cache.gc(None);
        assert_eq!(stats, GcStats { kept: 1, pruned: 3, failed: 0 });
        assert!(cache.path_for(&key).exists());
        assert!(dir.join("README").exists());
        assert!(matches!(cache.load(&key), CacheLookup::Hit(_)));

        // With an empty valid set: the surviving entry is out-of-grid.
        let none: std::collections::HashSet<String> = std::collections::HashSet::new();
        let stats = cache.gc(Some(&none));
        assert_eq!(stats, GcStats { kept: 0, pruned: 1, failed: 0 });
        assert_eq!(cache.n_entries(), 0);

        let _ = std::fs::remove_dir_all(&dir);
    }
}
