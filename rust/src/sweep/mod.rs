//! Fleet-scale scenario sweeps (ROADMAP: "as many scenarios as you can
//! imagine", paper §5's figure grids generalized).
//!
//! A [`SweepGrid`] declares value lists over RTT, jitter, arrival rate,
//! dataset, the three policy families, and cluster scale; [`run_grid`]
//! expands the cross product and executes one seeded simulator per cell
//! on a `std::thread` pool. Results are keyed by cell index, so output
//! is bit-stable regardless of thread count or scheduling; pairing a
//! grid with streaming metrics (`streaming: true`) bounds per-cell
//! memory so individual cells can simulate millions of requests.
//!
//! Entry points: `dsd sweep --grid <grid.yaml>` on the CLI,
//! [`SweepGrid`] + [`run_grid`] from library code (see
//! `examples/fleet_sweep.rs`), and [`crate::experiments::fig6`] which
//! runs its RTT sweep through this runner.

pub mod grid;
pub mod runner;
pub mod summary;

pub use grid::{SweepCell, SweepGrid};
pub use runner::{default_threads, run_cells, run_grid, CellMetrics, CellResult};
pub use summary::SweepSummary;
