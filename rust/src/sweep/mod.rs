//! Fleet-scale scenario sweeps (ROADMAP: "as many scenarios as you can
//! imagine", paper §5's figure grids generalized).
//!
//! A [`SweepGrid`] declares value lists over RTT, jitter, arrival rate,
//! dataset, the three policy families, and cluster scale; [`run_grid`]
//! expands the cross product and executes one seeded simulator per cell
//! on a `std::thread` pool. Results are keyed by cell index, so output
//! is bit-stable regardless of thread count or scheduling; pairing a
//! grid with streaming metrics (`streaming: true`) bounds per-cell
//! memory so individual cells can simulate millions of requests.
//!
//! Sweeps larger than a process lifetime run through the [`cache`]
//! layer: every cell has a stable content key (canonical JSON of its
//! resolved config + metric mode + simulator version tag), finished
//! cells persist to `<dir>/cells/<key>.json` as they complete, and
//! re-invocations load hits, execute only misses, and splice both into
//! a byte-identical summary ([`run_grid_cached`] / [`run_cells_cached`]).
//! Axis selection for partial runs is [`grid::parse_filter`] /
//! [`grid::filter_cells`] (`--filter` on the CLI); filtered summaries
//! are labeled partial. The AWC training-dataset generator
//! ([`crate::awc::generate_dataset`]) rides the same expansion + cached
//! runner, so dataset sweeps inherit caching and resume for free.
//!
//! Entry points: `dsd sweep --grid <grid.yaml> [--out-dir <dir>]
//! [--resume <dir>] [--filter k=v,...] [--gc <dir>]` on the CLI,
//! [`SweepGrid`] + [`run_grid`] from library code (see
//! `examples/fleet_sweep.rs`), and every runner-backed experiment
//! family (fig5, fig6, fig7/8, fig9/10, table2 — see
//! [`crate::experiments`]), all of which batch their cells through
//! [`run_cells_cached`]. [`CellCache::gc`] prunes entries orphaned by a
//! [`SIM_VERSION_TAG`] bump (or narrowed out of a grid).

pub mod cache;
pub mod grid;
pub mod runner;
pub mod shard;
pub mod summary;

pub use cache::{cell_key, CacheLookup, CellCache, CellKeyer, GcStats, SIM_VERSION_TAG};
pub use grid::{
    autoscale_label, classes_label, filter_cells, filter_label, parse_filter,
    scenario_label, SweepCell, SweepGrid,
};
pub use runner::{
    default_threads, run_cells, run_cells_cached, run_grid, run_grid_cached,
    CellMetrics, CellResult, ClassCellMetrics, RunStats,
};
pub use shard::{
    find_manifests, grid_fingerprint, merge_shard_dirs, shard_cells, MergeReport,
    ShardManifest, ShardSpec,
};
pub use summary::SweepSummary;
