//! The "agility" experiment family (`dsd reproduce agility`): how fast
//! does each window policy recover throughput after a disturbance?
//!
//! The paper's headline claim is *agile* edge-cloud serving; this family
//! quantifies it with the scenario engine. Two disturbances, scripted
//! with [`crate::scenario`]:
//!
//! * **link-degrade** — at one third of the run the edge–cloud RTT jumps
//!   8× (and jitter 2×); at two thirds the link restores. An adaptive
//!   window policy shrinks γ (or goes fused) and keeps tokens flowing; a
//!   fixed γ pays the inflated round trip on every window.
//! * **flash-crowd** — the arrival rate triples for the middle third of
//!   the run. Recovery is measured from the end of the burst: how long
//!   until the backlog *drains*.
//!
//! Per (scenario × policy × seed) cell the windowed
//! [`TimeSeriesSummary`](crate::metrics::TimeSeriesSummary) provides
//! both signals, and each scenario uses the one that can actually
//! differentiate policies ([`Recovery`]): for the link-degrade dip,
//! time until completion throughput returns to ≥ [`RECOVERY_FRACTION`]
//! of the pre-disturbance baseline
//! ([`TimeSeriesSummary::recovery_ms_after`]); for the flash crowd,
//! time until the active-request count drains back to ≈ its baseline
//! ([`TimeSeriesSummary::drain_ms_after`]) — during a drain the
//! *completion* rate sits at service capacity, at or above an
//! underloaded baseline, so a throughput threshold would report instant
//! "recovery" for every policy alike. The interquartile steady-state
//! estimator is deliberately *not* used here — these runs are
//! non-stationary by construction (see the caveat on
//! [`SystemMetrics::throughput_rps`](crate::metrics::SystemMetrics)).
//!
//! Cells run through the cached sweep runner, so the family inherits
//! `--cache-dir`, `--threads`, and `--streaming` like every other
//! figure.

use super::common::{mean_metric, point_grid, run_points, save_rows, ExpContext, Row, Scale};
use crate::config::{BatchingKind, RoutingKind, SimConfig, WindowKind};
use crate::metrics::TimeSeriesSummary;
use crate::scenario::{ArrivalProcess, Scenario, ScenarioEvent, TimedEvent};
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};

/// A policy counts as recovered once windowed throughput reaches this
/// fraction of the pre-disturbance baseline (throughput-dip scenarios).
pub const RECOVERY_FRACTION: f64 = 0.8;

/// A backlog counts as drained once the active-request count falls to
/// this multiple of the pre-disturbance baseline (plus a small absolute
/// slack for near-empty baselines).
pub const DRAIN_FACTOR: f64 = 1.25;

/// How time-to-recover is measured for one scenario.
#[derive(Clone, Copy, Debug)]
pub enum Recovery {
    /// First post-event window back at ≥ [`RECOVERY_FRACTION`] ×
    /// baseline completion throughput.
    Throughput {
        /// Simulated time the recovery scan starts from, ms.
        from_ms: f64,
    },
    /// First post-event window whose active-request count is back at ≤
    /// [`DRAIN_FACTOR`] × baseline active (+2 requests of slack).
    ActiveDrain {
        /// Simulated time the drain scan starts from, ms.
        from_ms: f64,
    },
}

/// Nominal arrival rate, requests/second.
const RATE_PER_S: f64 = 40.0;
/// Full-scale request count (span = requests / rate ≈ 120 s).
const REQUESTS_FULL: usize = 4_800;

/// The policy axis: AWC vs the fixed-γ and threshold baselines.
pub fn policies() -> Vec<(&'static str, WindowKind)> {
    vec![
        ("static4", WindowKind::Static(4)),
        ("dynamic", WindowKind::Dynamic { init: 4, lo: 0.25, hi: 0.75 }),
        ("awc", WindowKind::Awc { weights_path: None }),
    ]
}

/// Disturbance timing for a given scale: the event window spans the
/// middle third of the expected run.
fn span_thirds(scale: Scale) -> (f64, f64, usize) {
    let requests = scale.n(REQUESTS_FULL);
    let span_ms = requests as f64 / RATE_PER_S * 1_000.0;
    (span_ms / 3.0, span_ms * 2.0 / 3.0, requests)
}

/// The two scripted disturbances, plus how each one's recovery is
/// measured.
pub fn scenarios(scale: Scale) -> Vec<(&'static str, Scenario, Recovery)> {
    let (t1, t2, _) = span_thirds(scale);
    vec![
        (
            "link-degrade",
            Scenario {
                name: "link-degrade".into(),
                arrivals: None,
                events: vec![
                    TimedEvent {
                        at_ms: t1,
                        event: ScenarioEvent::LinkDegrade {
                            pool: None,
                            rtt_mult: 8.0,
                            jitter_mult: 2.0,
                            bandwidth_mult: 1.0,
                        },
                    },
                    TimedEvent { at_ms: t2, event: ScenarioEvent::LinkRestore { pool: None } },
                ],
            },
            // Adaptation is what's measured: the throughput-recovery
            // scan starts at the degrade step itself.
            Recovery::Throughput { from_ms: t1 },
        ),
        (
            "flash-crowd",
            Scenario {
                name: "flash-crowd".into(),
                arrivals: Some(ArrivalProcess::Spike {
                    base_per_s: RATE_PER_S,
                    peak_per_s: RATE_PER_S * 3.0,
                    t_start_ms: t1,
                    t_end_ms: t2,
                }),
                events: Vec::new(),
            },
            // Backlog drain is what's measured: the scan starts when the
            // burst ends, on the active-request series (completion
            // throughput during a drain runs at service capacity and
            // cannot distinguish policies).
            Recovery::ActiveDrain { from_ms: t2 },
        ),
    ]
}

/// One (scenario × policy) result row, seed-averaged.
#[derive(Clone, Debug)]
pub struct AgilityRow {
    /// Scenario name.
    pub scenario: &'static str,
    /// Policy name.
    pub policy: &'static str,
    /// Mean windowed throughput before the disturbance, req/s.
    pub baseline_rps: f64,
    /// Mean windowed throughput inside the disturbance interval
    /// `[t1, t2)` — the degraded-link period / the burst window, req/s.
    pub disturbed_rps: f64,
    /// Mean time-to-recover, ms (seed-averaged; infinite when any seed
    /// never recovers within its run).
    pub recovery_ms: f64,
    /// End-to-end mean TPOT across the whole run, ms.
    pub mean_tpot_ms: f64,
}

/// Baseline config: the scenario is the only thing that varies besides
/// the window policy.
fn base_config(scale: Scale, window: WindowKind, scenario: Scenario, seed: u64) -> SimConfig {
    let (_, _, requests) = span_thirds(scale);
    let mut cfg = SimConfig::builder()
        .seed(seed)
        .targets(4)
        .drafters(48)
        .requests(requests)
        .rate_per_s(RATE_PER_S)
        .rtt_ms(10.0)
        .dataset("gsm8k")
        .routing(RoutingKind::Jsq)
        .batching(BatchingKind::Lab)
        .window(window)
        .build();
    cfg.scenario = Some(scenario);
    cfg
}

/// Recovery metrics of one cell's time series. The disturbance spans
/// `[t1_ms, t2_ms)` for both scenarios (degraded-link period / burst
/// window), so `disturbed_rps` is comparable across rows; the recovery
/// signal and scan start are per-scenario ([`Recovery`]).
fn cell_recovery(
    ts: &TimeSeriesSummary,
    t1_ms: f64,
    t2_ms: f64,
    recovery: Recovery,
) -> (f64, f64, Option<f64>) {
    let baseline = ts.mean_throughput_between(0.0, t1_ms).unwrap_or(0.0);
    let disturbed = ts.mean_throughput_between(t1_ms, t2_ms).unwrap_or(0.0);
    let recovered = match recovery {
        Recovery::Throughput { from_ms } => {
            ts.recovery_ms_after(from_ms, baseline * RECOVERY_FRACTION)
        }
        Recovery::ActiveDrain { from_ms } => {
            let base_active = ts.mean_active_between(0.0, t1_ms).unwrap_or(0.0);
            ts.drain_ms_after(from_ms, base_active * DRAIN_FACTOR + 2.0)
        }
    };
    (baseline, disturbed, recovered)
}

/// Run the full family on the cached runner: every (scenario × policy)
/// grid batches through one `run_points` call per scenario, sharing the
/// thread pool and the cell cache.
pub fn sweep_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> Vec<AgilityRow> {
    let (t1, t2, _) = span_thirds(scale);
    let mut rows = Vec::new();
    for (sname, scenario, recovery) in scenarios(scale) {
        let grids: Vec<_> = policies()
            .iter()
            .map(|(_, w)| {
                point_grid(
                    base_config(scale, w.clone(), scenario.clone(), seeds[0]),
                    seeds,
                    ctx.streaming,
                )
            })
            .collect();
        let (points, stats) = run_points(&grids, seeds.len(), ctx);
        if ctx.cache.is_some() {
            eprintln!("[agility] {sname}: {}", stats.describe());
        }
        for (&(pname, _), cells) in policies().iter().zip(&points) {
            let per_seed: Vec<(f64, f64, Option<f64>)> = cells
                .iter()
                .map(|m| {
                    let ts = m
                        .time_series
                        .as_ref()
                        .expect("scenario cells carry a time series");
                    cell_recovery(ts, t1, t2, recovery)
                })
                .collect();
            let recovery_ms = if per_seed.iter().any(|&(_, _, r)| r.is_none()) {
                f64::INFINITY
            } else {
                mean(&per_seed.iter().map(|&(_, _, r)| r.unwrap()).collect::<Vec<_>>())
            };
            rows.push(AgilityRow {
                scenario: sname,
                policy: pname,
                baseline_rps: mean(&per_seed.iter().map(|&(b, _, _)| b).collect::<Vec<_>>()),
                disturbed_rps: mean(&per_seed.iter().map(|&(_, d, _)| d).collect::<Vec<_>>()),
                recovery_ms,
                mean_tpot_ms: mean_metric(cells, |m| m.mean_tpot_ms),
            });
        }
    }
    rows
}

/// Run and render.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let rows = sweep_cached(scale, seeds, ctx);
    let mut table = Table::new(&[
        "scenario",
        "policy",
        "baseline r/s",
        "disturbed r/s",
        "recover ms",
        "tpot ms",
    ])
    .with_title(&format!(
        "Agility — link-degrade: back to {:.0}% of baseline throughput; \
         flash-crowd: backlog drained to {:.2}x baseline active",
        RECOVERY_FRACTION * 100.0,
        DRAIN_FACTOR
    ));
    let mut out_rows = Vec::new();
    for r in &rows {
        table.row(vec![
            r.scenario.into(),
            r.policy.into(),
            fnum(r.baseline_rps, 1),
            fnum(r.disturbed_rps, 1),
            if r.recovery_ms.is_finite() {
                fnum(r.recovery_ms, 0)
            } else {
                "never".into()
            },
            fnum(r.mean_tpot_ms, 1),
        ]);
        out_rows.push(Row {
            exp: "agility".into(),
            labels: vec![
                ("scenario".into(), r.scenario.into()),
                ("policy".into(), r.policy.into()),
            ],
            values: vec![
                ("baseline_rps".into(), r.baseline_rps),
                ("disturbed_rps".into(), r.disturbed_rps),
                ("recovery_ms".into(), r.recovery_ms),
                ("mean_tpot_ms".into(), r.mean_tpot_ms),
            ],
        });
    }
    save_rows("agility", &out_rows);
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_family_produces_all_rows() {
        let rows = sweep_cached(Scale(0.05), &[1], &ExpContext::default());
        assert_eq!(rows.len(), scenarios(Scale(0.05)).len() * policies().len());
        for r in &rows {
            assert!(r.baseline_rps > 0.0, "{}/{}: baseline", r.scenario, r.policy);
            assert!(r.mean_tpot_ms > 0.0);
            // Recovery is either a finite positive duration or "never"
            // within this (tiny) horizon — both are valid outcomes; what
            // must hold is that the metric is well-defined.
            assert!(
                r.recovery_ms > 0.0 || r.recovery_ms.is_infinite(),
                "{}/{}: recovery {}",
                r.scenario,
                r.policy,
                r.recovery_ms
            );
        }
    }

    #[test]
    fn flash_crowd_window_measurement_is_well_defined() {
        // Sanity on the measurement itself: during a 3× burst the
        // per-window completion throughput stays in the same order of
        // magnitude as baseline (the system keeps completing work while
        // the backlog forms) — i.e. the windowed series actually
        // measured the disturbance interval rather than empty windows.
        let rows = sweep_cached(Scale(0.1), &[2], &ExpContext::default());
        let fc: Vec<&AgilityRow> =
            rows.iter().filter(|r| r.scenario == "flash-crowd").collect();
        assert!(!fc.is_empty());
        for r in fc {
            assert!(
                r.disturbed_rps > r.baseline_rps * 0.5,
                "{}: disturbed {} vs baseline {}",
                r.policy,
                r.disturbed_rps,
                r.baseline_rps
            );
        }
    }
}
