//! The "pipeline" experiment family (`dsd reproduce pipeline`): where
//! does pipelined speculation beat the sequential
//! draft → ship → wait round trip?
//!
//! Sequential execution leaves the drafter idle for a full RTT (plus
//! uplink serialization) every round; pipelined execution
//! ([`ExecutionMode::Pipelined`]) spends that window drafting the next
//! speculative block, at the price of re-drafting — metered as
//! `wasted_draft_tokens` / `wasted_uplink_ms` — whenever the in-flight
//! verdict comes back a rejection. Neither mode dominates:
//!
//! * **high RTT / slow uplink** — the hidden wait is long, so the
//!   overlap gain swamps the occasional wasted draft and pipelined
//!   wins on TPOT;
//! * **low RTT under load** — there is little wait to hide, but the
//!   speculative drafts still occupy edge drafters that other requests
//!   are queueing for, so pipelining can give *back* throughput;
//! * **window γ** scales both sides: a larger static window lengthens
//!   the draft being overlapped *and* the work thrown away per
//!   rejection.
//!
//! The family sweeps that three-axis frontier — RTT × uplink bandwidth
//! × static window γ — running each knob point under both execution
//! modes on the paper's §5.2 cluster, and reports per point the
//! sequential vs pipelined mean TPOT and throughput plus the TPOT
//! speedup. A footer summarizes the crossover frontier: for each
//! (bandwidth, γ) column, the smallest RTT at which pipelined first
//! wins.
//!
//! Both modes of a knob point share one config that differs only in
//! the `execution:` key, so every row difference is attributable to
//! execution mode — the same differs-only-in-the-knob discipline as
//! the fairness family's admission strategies.
//!
//! Cells run through the cached sweep runner, so the family inherits
//! `--cache-dir`, `--threads`, and `--streaming` like every other
//! figure.

use super::common::{mean_metric, paper_config, point_grid, run_points, save_rows, ExpContext, Row, Scale};
use crate::config::{BatchingKind, RoutingKind, SimConfig, WindowKind};
use crate::specdec::ExecutionMode;
use crate::util::table::{fnum, Table};

/// Swept round-trip times, ms (LAN edge → metro → cross-region).
const RTTS: [f64; 3] = [5.0, 40.0, 160.0];
/// Swept uplink bandwidths, Mbit/s (constrained cellular vs broadband).
const BANDWIDTHS: [f64; 2] = [2.0, 100.0];
/// Swept static speculation windows.
const GAMMAS: [usize; 2] = [2, 8];
/// Edge drafter count (the §5.2 default fleet, shared with fig5/fig6).
const DRAFTERS: usize = 60;

/// The knob axis in declaration (and row) order: RTT outermost, then
/// bandwidth, then γ.
pub fn knob_points() -> Vec<(f64, f64, usize)> {
    let mut pts = Vec::new();
    for &rtt in &RTTS {
        for &bw in &BANDWIDTHS {
            for &gamma in &GAMMAS {
                pts.push((rtt, bw, gamma));
            }
        }
    }
    pts
}

/// One knob point's config under one execution mode. Everything except
/// `execution` (and the knob values themselves) is the paper default.
pub fn point_config(
    rtt_ms: f64,
    bandwidth_mbps: f64,
    gamma: usize,
    mode: ExecutionMode,
    scale: Scale,
    seed: u64,
) -> SimConfig {
    let mut cfg = paper_config(
        "gsm8k",
        DRAFTERS,
        rtt_ms,
        RoutingKind::Jsq,
        BatchingKind::Lab,
        WindowKind::Static(gamma),
        scale,
        seed,
    );
    cfg.network.bandwidth_mbps = bandwidth_mbps;
    cfg.execution = mode;
    cfg
}

/// One knob point's result row, seed-averaged across both modes.
#[derive(Clone, Debug)]
pub struct PipelineRow {
    /// Round-trip time, ms.
    pub rtt_ms: f64,
    /// Uplink bandwidth, Mbit/s.
    pub bandwidth_mbps: f64,
    /// Static speculation window.
    pub gamma: usize,
    /// Sequential-mode mean TPOT, ms.
    pub seq_tpot_ms: f64,
    /// Pipelined-mode mean TPOT, ms.
    pub pipe_tpot_ms: f64,
    /// Sequential-mode throughput, req/s.
    pub seq_throughput_rps: f64,
    /// Pipelined-mode throughput, req/s.
    pub pipe_throughput_rps: f64,
}

impl PipelineRow {
    /// TPOT speedup of pipelined over sequential (>1 ⇒ pipelined wins).
    pub fn speedup(&self) -> f64 {
        self.seq_tpot_ms / self.pipe_tpot_ms
    }

    /// Which mode wins this point on mean TPOT.
    pub fn winner(&self) -> &'static str {
        if self.pipe_tpot_ms < self.seq_tpot_ms {
            "pipelined"
        } else {
            "sequential"
        }
    }
}

/// Run the full family on the cached runner: two grids (sequential,
/// pipelined) per knob point, batched through a single `run_points`
/// call sharing the thread pool and the cell cache.
pub fn sweep_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> Vec<PipelineRow> {
    let pts = knob_points();
    let mut grids = Vec::with_capacity(pts.len() * 2);
    for &(rtt, bw, gamma) in &pts {
        for mode in [ExecutionMode::Sequential, ExecutionMode::Pipelined] {
            grids.push(point_grid(
                point_config(rtt, bw, gamma, mode, scale, seeds[0]),
                seeds,
                ctx.streaming,
            ));
        }
    }
    let (points, stats) = run_points(&grids, seeds.len(), ctx);
    if ctx.cache.is_some() {
        eprintln!("[pipeline] {}", stats.describe());
    }
    pts.iter()
        .zip(points.chunks(2))
        .map(|(&(rtt, bw, gamma), pair)| PipelineRow {
            rtt_ms: rtt,
            bandwidth_mbps: bw,
            gamma,
            seq_tpot_ms: mean_metric(&pair[0], |m| m.mean_tpot_ms),
            pipe_tpot_ms: mean_metric(&pair[1], |m| m.mean_tpot_ms),
            seq_throughput_rps: mean_metric(&pair[0], |m| m.throughput_rps),
            pipe_throughput_rps: mean_metric(&pair[1], |m| m.throughput_rps),
        })
        .collect()
}

/// The crossover frontier: for each (bandwidth, γ) column in
/// declaration order, the smallest swept RTT at which pipelined first
/// beats sequential on mean TPOT (rows are RTT-sorted by
/// construction), or a note that it never does.
pub fn frontier_lines(rows: &[PipelineRow]) -> String {
    let mut out = String::from("crossover frontier (mean TPOT):\n");
    for &bw in &BANDWIDTHS {
        for &gamma in &GAMMAS {
            let first_win = rows
                .iter()
                .filter(|r| r.bandwidth_mbps == bw && r.gamma == gamma)
                .find(|r| r.winner() == "pipelined");
            match first_win {
                Some(r) => out.push_str(&format!(
                    "  bw {} Mbps, γ={}: pipelined wins from rtt ≥ {} ms\n",
                    fnum(bw, 0),
                    gamma,
                    fnum(r.rtt_ms, 0)
                )),
                None => out.push_str(&format!(
                    "  bw {} Mbps, γ={}: sequential wins at every swept rtt\n",
                    fnum(bw, 0),
                    gamma
                )),
            }
        }
    }
    out
}

/// Run and render.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let rows = sweep_cached(scale, seeds, ctx);
    let mut table = Table::new(&[
        "rtt ms",
        "bw Mbps",
        "γ",
        "seq tpot ms",
        "pipe tpot ms",
        "speedup",
        "seq tput r/s",
        "pipe tput r/s",
        "winner",
    ])
    .with_title(
        "Pipelined vs sequential speculation — TPOT crossover over \
         RTT × uplink bandwidth × window γ",
    );
    let mut out_rows = Vec::new();
    for r in &rows {
        table.row(vec![
            fnum(r.rtt_ms, 0),
            fnum(r.bandwidth_mbps, 0),
            format!("{}", r.gamma),
            fnum(r.seq_tpot_ms, 2),
            fnum(r.pipe_tpot_ms, 2),
            fnum(r.speedup(), 3),
            fnum(r.seq_throughput_rps, 1),
            fnum(r.pipe_throughput_rps, 1),
            r.winner().into(),
        ]);
        out_rows.push(Row {
            exp: "pipeline".into(),
            labels: vec![
                ("rtt_ms".into(), fnum(r.rtt_ms, 0)),
                ("bandwidth_mbps".into(), fnum(r.bandwidth_mbps, 0)),
                ("gamma".into(), format!("{}", r.gamma)),
                ("winner".into(), r.winner().into()),
            ],
            values: vec![
                ("seq_tpot_ms".into(), r.seq_tpot_ms),
                ("pipe_tpot_ms".into(), r.pipe_tpot_ms),
                ("speedup".into(), r.speedup()),
                ("seq_throughput_rps".into(), r.seq_throughput_rps),
                ("pipe_throughput_rps".into(), r.pipe_throughput_rps),
            ],
        });
    }
    save_rows("pipeline", &out_rows);
    let mut out = table.render();
    out.push_str(&frontier_lines(&rows));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_family_produces_all_rows_in_knob_order() {
        let scale = Scale(0.05);
        let rows = sweep_cached(scale, &[1], &ExpContext::default());
        let pts = knob_points();
        assert_eq!(rows.len(), pts.len());
        for (r, &(rtt, bw, gamma)) in rows.iter().zip(&pts) {
            assert_eq!(r.rtt_ms, rtt);
            assert_eq!(r.bandwidth_mbps, bw);
            assert_eq!(r.gamma, gamma);
            assert!(
                r.seq_tpot_ms.is_finite() && r.seq_tpot_ms > 0.0,
                "seq tpot at rtt={rtt} bw={bw} γ={gamma}: {}",
                r.seq_tpot_ms
            );
            assert!(
                r.pipe_tpot_ms.is_finite() && r.pipe_tpot_ms > 0.0,
                "pipe tpot at rtt={rtt} bw={bw} γ={gamma}: {}",
                r.pipe_tpot_ms
            );
            assert!(r.seq_throughput_rps > 0.0 && r.pipe_throughput_rps > 0.0);
            assert!(r.speedup().is_finite() && r.speedup() > 0.0);
        }
        let frontier = frontier_lines(&rows);
        assert!(frontier.contains("crossover frontier"));
        assert_eq!(
            frontier.lines().count(),
            1 + BANDWIDTHS.len() * GAMMAS.len(),
            "one frontier line per (bandwidth, γ) column"
        );
    }

    #[test]
    fn mode_configs_differ_only_in_execution() {
        // Byte-level discipline: a knob point's two configs must render
        // identical canonical JSON once the pipelined one is switched
        // back to sequential — so any row difference is the execution
        // mode's doing, nothing else's.
        let scale = Scale(0.05);
        for &(rtt, bw, gamma) in &knob_points() {
            let seq = point_config(rtt, bw, gamma, ExecutionMode::Sequential, scale, 1);
            let pipe = point_config(rtt, bw, gamma, ExecutionMode::Pipelined, scale, 1);
            assert_eq!(seq.execution, ExecutionMode::Sequential);
            assert_eq!(pipe.execution, ExecutionMode::Pipelined);
            let seq_json = seq.to_canonical_json().to_string_compact();
            let pipe_json = pipe.to_canonical_json().to_string_compact();
            assert!(!seq_json.contains("\"execution\""));
            assert!(pipe_json.contains("\"execution\":\"pipelined\""));
            let mut neutered = pipe.clone();
            neutered.execution = ExecutionMode::Sequential;
            assert_eq!(
                seq_json,
                neutered.to_canonical_json().to_string_compact(),
                "rtt={rtt} bw={bw} γ={gamma}: configs differ beyond execution"
            );
        }
    }

    #[test]
    fn high_rtt_slow_link_favors_pipelining() {
        // The family's reason to exist: at the harshest swept corner
        // (cross-region RTT over the constrained uplink, wide window)
        // the hidden round-trip wait is the dominant TPOT term, so
        // pipelined must not meaningfully lose to sequential there. A
        // 10% multiplicative tolerance absorbs batch-composition noise
        // at tiny scale — the crossover *magnitude* is the golden's
        // job, not this test's.
        let scale = Scale(0.05);
        let rows = sweep_cached(scale, &[1], &ExpContext::default());
        let corner = rows
            .iter()
            .find(|r| {
                r.rtt_ms == RTTS[RTTS.len() - 1]
                    && r.bandwidth_mbps == BANDWIDTHS[0]
                    && r.gamma == GAMMAS[GAMMAS.len() - 1]
            })
            .expect("harshest knob point present");
        assert!(
            corner.pipe_tpot_ms <= corner.seq_tpot_ms * 1.10,
            "pipelined {} vs sequential {} at the harshest corner",
            corner.pipe_tpot_ms,
            corner.seq_tpot_ms
        );
    }
}
