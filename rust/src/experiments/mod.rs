//! The experiment harness: one module per table/figure in the paper's
//! evaluation (§5), each regenerating the corresponding rows/series.
//! `dsd reproduce --exp <id>` is the CLI entry; `rust/benches/bench_*`
//! time the same code paths.
//!
//! Every runner-backed family (fig5, fig6, fig7/8, fig9/10, table2, the
//! scenario-driven `agility` family, the autoscale-driven
//! `elasticity` family, the multi-tenant `fairness` family, and the
//! execution-mode `pipeline` family)
//! executes through `sweep::run_cells_cached`, so all of them inherit
//! `--cache-dir` (content-addressed per-cell persistence + kill-resume),
//! `--threads`, and `--streaming` (bounded-memory cells for 1M+ request
//! scales). The experiment modules themselves are grid declarations plus
//! formatting.

pub mod agility;
pub mod common;
pub mod elasticity;
pub mod fairness;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7_8;
pub mod fig9_10;
pub mod pipeline;
pub mod table2;

pub use common::{ExpContext, Scale};

/// Knobs `dsd reproduce` forwards to the runner-backed families.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunOptions {
    /// Worker threads (0 = one per core, capped at 8 like the direct
    /// library entry points).
    pub threads: usize,
    /// Run cells in bounded-memory streaming-metrics mode.
    pub streaming: bool,
}

impl RunOptions {
    fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::sweep::default_threads().min(8)
        } else {
            self.threads
        }
    }
}

/// Run one experiment by id; returns its rendered report.
pub fn run_experiment(exp: &str, scale: Scale, seeds: &[u64]) -> Result<String, String> {
    run_experiment_cached(exp, scale, seeds, None)
}

/// [`run_experiment`] with an optional sweep cell-cache directory
/// (`dsd reproduce --cache-dir <dir>`).
pub fn run_experiment_cached(
    exp: &str,
    scale: Scale,
    seeds: &[u64],
    cache_dir: Option<&std::path::Path>,
) -> Result<String, String> {
    run_experiment_opts(exp, scale, seeds, cache_dir, RunOptions::default())
}

/// Full-control entry: every runner-backed experiment persists its cells
/// under `<cache_dir>/<exp>/` and skips anything already computed —
/// re-rendering a figure after a crash, or with more seeds, only runs
/// the delta — and honors the thread/streaming knobs.
pub fn run_experiment_opts(
    exp: &str,
    scale: Scale,
    seeds: &[u64],
    cache_dir: Option<&std::path::Path>,
    opts: RunOptions,
) -> Result<String, String> {
    let run_one = |name: &str| -> Result<String, String> {
        if name == "fig4" {
            // Fig 4 is a single annotated run, not a sweep family.
            return Ok(fig4::run(seeds[0]).0);
        }
        let cache = match cache_dir {
            Some(dir) => Some(crate::sweep::CellCache::open(&dir.join(name))?),
            None => None,
        };
        let ctx = ExpContext {
            threads: opts.resolved_threads(),
            cache: cache.as_ref(),
            streaming: opts.streaming,
            stats: Default::default(),
        };
        Ok(match name {
            "fig5" => fig5::run_cached(scale, seeds, &ctx),
            "fig6" => fig6::run_cached(scale, seeds, &ctx),
            "fig7_8" => fig7_8::run_cached(scale, seeds, &ctx),
            "fig9_10" => fig9_10::run_cached(scale, seeds, &ctx),
            "table2" => table2::run_cached(scale, seeds, &ctx),
            "agility" => agility::run_cached(scale, seeds, &ctx),
            "elasticity" => elasticity::run_cached(scale, seeds, &ctx),
            "fairness" => fairness::run_cached(scale, seeds, &ctx),
            "pipeline" => pipeline::run_cached(scale, seeds, &ctx),
            other => unreachable!("unrouted experiment '{other}'"),
        })
    };
    Ok(match exp {
        "fig4" | "fig5" | "fig6" | "table2" | "agility" | "elasticity" | "fairness"
        | "pipeline" => run_one(exp)?,
        "fig7" | "fig8" | "fig7_8" => run_one("fig7_8")?,
        "fig9" | "fig10" | "fig9_10" => run_one("fig9_10")?,
        "all" => {
            let mut out = String::new();
            for e in [
                "fig4", "fig5", "fig6", "fig7_8", "fig9_10", "table2", "agility",
                "elasticity", "fairness", "pipeline",
            ] {
                out.push_str(&run_one(e)?);
                out.push('\n');
            }
            out
        }
        other => {
            return Err(format!(
                "unknown experiment '{other}' (try: fig4 fig5 fig6 fig7 fig9 table2 \
                 agility elasticity fairness pipeline all)"
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_rejected() {
        assert!(run_experiment("fig99", Scale::tiny(), &[1]).is_err());
    }

    #[test]
    fn aliases_route_to_canonical_families() {
        // Aliased ids render the same report as the canonical id.
        let a = run_experiment("fig9", Scale(0.02), &[1]).unwrap();
        let b = run_experiment("fig9_10", Scale(0.02), &[1]).unwrap();
        assert_eq!(a, b);
    }
}
