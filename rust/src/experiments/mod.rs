//! The experiment harness: one module per table/figure in the paper's
//! evaluation (§5), each regenerating the corresponding rows/series.
//! `dsd reproduce --exp <id>` is the CLI entry; `rust/benches/bench_*`
//! time the same code paths.

pub mod common;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7_8;
pub mod fig9_10;
pub mod table2;

pub use common::Scale;

/// Run one experiment by id; returns its rendered report.
pub fn run_experiment(exp: &str, scale: Scale, seeds: &[u64]) -> Result<String, String> {
    run_experiment_cached(exp, scale, seeds, None)
}

/// [`run_experiment`] with an optional sweep cell-cache directory
/// (`dsd reproduce --cache-dir <dir>`). Experiments that execute on the
/// sweep runner (currently fig6) persist their cells under
/// `<dir>/<exp>/` and skip anything already computed — re-rendering a
/// figure after a crash, or with more seeds, only runs the delta.
pub fn run_experiment_cached(
    exp: &str,
    scale: Scale,
    seeds: &[u64],
    cache_dir: Option<&std::path::Path>,
) -> Result<String, String> {
    Ok(match exp {
        "fig4" => fig4::run(seeds[0]).0,
        "fig5" => fig5::run(scale, seeds),
        "fig6" => {
            let cache = match cache_dir {
                Some(dir) => Some(crate::sweep::CellCache::open(&dir.join("fig6"))?),
                None => None,
            };
            fig6::run_cached(scale, seeds, cache.as_ref())
        }
        "fig7" | "fig8" | "fig7_8" => fig7_8::run(scale, seeds),
        "fig9" | "fig10" | "fig9_10" => fig9_10::run(scale, seeds),
        "table2" => table2::run(scale, seeds),
        "all" => {
            let mut out = String::new();
            for e in ["fig4", "fig5", "fig6", "fig7_8", "fig9_10", "table2"] {
                out.push_str(&run_experiment_cached(e, scale, seeds, cache_dir)?);
                out.push('\n');
            }
            out
        }
        other => {
            return Err(format!(
                "unknown experiment '{other}' (try: fig4 fig5 fig6 fig7 fig9 table2 all)"
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_rejected() {
        assert!(run_experiment("fig99", Scale::tiny(), &[1]).is_err());
    }
}
