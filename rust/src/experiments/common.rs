//! Shared infrastructure for the paper-reproduction experiments: the
//! paper's cluster definitions (§5.2), per-dataset workload operating
//! points, and result-row plumbing.

use crate::config::{
    BatchingKind, PoolSpec, RoutingKind, SimConfig, WindowKind,
};
use crate::metrics::SimReport;
use crate::sim::Simulator;
use crate::sweep::cache::CellCache;
use crate::sweep::runner::{default_threads, run_cells_cached, CellMetrics, RunStats};
use crate::sweep::{SweepCell, SweepGrid};
use crate::util::json::Json;

/// Scale factor applied to request counts (1.0 = paper scale). Tests use
/// small factors so experiments still finish in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct Scale(pub f64);

impl Scale {
    /// Paper scale.
    pub fn full() -> Scale {
        Scale(1.0)
    }
    /// Reduced scale for CI/tests.
    pub fn tiny() -> Scale {
        Scale(0.08)
    }
    /// Scale a request count.
    pub fn n(&self, full: usize) -> usize {
        ((full as f64 * self.0).round() as usize).max(8)
    }
}

/// The heterogeneous Cloud Pool of §5.2: 20 servers hosting Llama2-70B,
/// Llama3-70B and Qwen-72B across 4×A100, 4×H100 and 4×A6000 gangs.
pub fn cloud_pool_20() -> Vec<PoolSpec> {
    use crate::cluster::gpu::{A100, A6000, H100};
    use crate::cluster::model::{LLAMA2_70B, LLAMA3_70B, QWEN_72B};
    vec![
        PoolSpec { count: 8, gpu: &A100, tp: 4, model: &LLAMA2_70B, link: None },
        PoolSpec { count: 6, gpu: &H100, tp: 4, model: &QWEN_72B, link: None },
        PoolSpec { count: 6, gpu: &A6000, tp: 4, model: &LLAMA3_70B, link: None },
    ]
}

/// The Edge Pool of §5.2: `n` GPUs split evenly between A40s and V100s,
/// serving Llama2-7B, Qwen-7B and Llama3.1-8B draft models evenly.
pub fn edge_pool(n: usize) -> Vec<PoolSpec> {
    use crate::cluster::gpu::{A40, V100};
    use crate::cluster::model::{LLAMA2_7B, LLAMA31_8B, QWEN_7B};
    let per = (n / 6).max(1);
    let rem = n.saturating_sub(per * 5);
    vec![
        PoolSpec { count: per, gpu: &A40, tp: 1, model: &LLAMA2_7B, link: None },
        PoolSpec { count: per, gpu: &A40, tp: 1, model: &QWEN_7B, link: None },
        PoolSpec { count: per, gpu: &A40, tp: 1, model: &LLAMA31_8B, link: None },
        PoolSpec { count: per, gpu: &V100, tp: 1, model: &LLAMA2_7B, link: None },
        PoolSpec { count: per, gpu: &V100, tp: 1, model: &QWEN_7B, link: None },
        PoolSpec { count: rem, gpu: &V100, tp: 1, model: &LLAMA31_8B, link: None },
    ]
}

/// Per-dataset operating point: request count from §5.2 (400 GSM8K,
/// 400 CNN/DailyMail, 100 HumanEval prompts) and an arrival rate placing
/// the default cluster near its capacity knee, where policy quality is
/// visible (the paper's throughput regime).
pub fn workload_point(dataset: &str) -> (usize, f64) {
    // Rates are chosen so the default cluster operates at/near target
    // saturation — the paper's regime (its CNN/DM TTFTs of 1.6–3.0 s and
    // HumanEval TTFTs of 0.8–2.6 s only arise with queueing).
    match dataset {
        "gsm8k" => (400, 60.0),
        "cnndm" => (400, 16.0),
        "humaneval" => (100, 32.0),
        _ => (200, 20.0),
    }
}

/// Build the paper's default large-cluster config.
pub fn paper_config(
    dataset: &str,
    n_drafters: usize,
    rtt_ms: f64,
    routing: RoutingKind,
    batching: BatchingKind,
    window: WindowKind,
    scale: Scale,
    seed: u64,
) -> SimConfig {
    // Scaling shrinks the request *count* (wall-clock) but never the
    // arrival rate: the operating point (offered load vs capacity) is
    // what produces the paper's shapes.
    let (req_full, rate) = workload_point(dataset);
    let mut cfg = SimConfig::builder()
        .seed(seed)
        .dataset(dataset)
        .requests(scale.n(req_full))
        .rate_per_s(rate)
        .rtt_ms(rtt_ms)
        .routing(routing)
        .batching(batching)
        .window(window)
        .build();
    cfg.target_pools = cloud_pool_20();
    cfg.drafter_pools = edge_pool(n_drafters);
    cfg
}

/// Execution context for experiments that run on the cached sweep
/// runner (`dsd reproduce --cache-dir / --threads / --streaming`).
pub struct ExpContext<'a> {
    /// Worker threads for the runner.
    pub threads: usize,
    /// Optional cell cache: re-running a figure (or widening its seed
    /// list) only executes cells the cache has not seen, and a killed
    /// run resumes from whatever already finished.
    pub cache: Option<&'a CellCache>,
    /// Run cells in bounded-memory streaming-metrics mode (1M+ request
    /// cells; `throughput_rps` becomes the naive completions/duration
    /// ratio — see `metrics::StreamingReport`).
    pub streaming: bool,
    /// Accounting accumulated over every [`run_points`] batch executed
    /// with this context. The kill-and-resume tests read
    /// `ctx.stats.get().executed` to prove a warm cache re-executes
    /// zero cells.
    pub stats: std::cell::Cell<RunStats>,
}

impl Default for ExpContext<'_> {
    fn default() -> Self {
        ExpContext {
            threads: default_threads().min(8),
            cache: None,
            streaming: false,
            stats: std::cell::Cell::new(RunStats::default()),
        }
    }
}

impl<'a> ExpContext<'a> {
    /// Context with an optional cache and defaults elsewhere.
    pub fn with_cache(cache: Option<&'a CellCache>) -> ExpContext<'a> {
        ExpContext {
            cache,
            ..ExpContext::default()
        }
    }

    /// Fold one runner batch's accounting into the accumulated stats.
    pub fn absorb_stats(&self, stats: RunStats) {
        let mut acc = self.stats.get();
        acc.absorb(stats);
        self.stats.set(acc);
    }
}

/// One experiment scenario as a sweep grid: a concrete config replicated
/// over the seed axis (the grid's only swept axis, so cells expand in
/// seed order).
pub fn point_grid(cfg: SimConfig, seeds: &[u64], streaming: bool) -> SweepGrid {
    let mut g = SweepGrid::new(cfg);
    g.seeds = seeds.to_vec();
    g.streaming = streaming;
    g
}

/// Expand scenario grids (declaration order) into one cell list with
/// globally unique indices and execute every cell through the cached
/// runner in a single batch — the whole figure shares the thread pool,
/// and every cell inherits content-addressed caching and kill-resume.
/// Returns `result[point]` = per-seed metrics in seed order, plus run
/// accounting. Every grid must expand to exactly `per_point` cells.
pub fn run_points(
    grids: &[SweepGrid],
    per_point: usize,
    ctx: &ExpContext,
) -> (Vec<Vec<CellMetrics>>, RunStats) {
    let mut cells: Vec<SweepCell> = Vec::new();
    for g in grids {
        let expanded = g.expand().expect("experiment grid expands");
        assert_eq!(expanded.len(), per_point, "experiment point cell count");
        for mut c in expanded {
            c.index = cells.len();
            cells.push(c);
        }
    }
    let (results, stats) = run_cells_cached(&cells, ctx.streaming, ctx.threads, ctx.cache);
    ctx.absorb_stats(stats);
    let points = results
        .chunks(per_point)
        .map(|chunk| chunk.iter().map(|c| c.metrics().clone()).collect())
        .collect();
    (points, stats)
}

/// Mean of one metric across a point's seed replicas (same arithmetic —
/// and therefore the same floating-point rounding — as [`mean_of`] over
/// per-seed reports).
pub fn mean_metric(cells: &[CellMetrics], f: impl Fn(&CellMetrics) -> f64) -> f64 {
    crate::util::stats::mean(&cells.iter().map(f).collect::<Vec<_>>())
}

/// Run a config with several seeds; returns per-seed reports (the paper
/// averages over random seeds, §5).
pub fn run_seeds(cfg: &SimConfig, seeds: &[u64]) -> Vec<SimReport> {
    seeds
        .iter()
        .map(|&s| {
            let mut c = cfg.clone();
            c.seed = s;
            Simulator::new(c).run()
        })
        .collect()
}

/// Mean of a metric across reports.
pub fn mean_of(reports: &[SimReport], f: impl Fn(&SimReport) -> f64) -> f64 {
    crate::util::stats::mean(&reports.iter().map(f).collect::<Vec<_>>())
}

/// A generic experiment result row for JSON export.
#[derive(Clone, Debug)]
pub struct Row {
    /// Experiment id (e.g. `"fig5"`).
    pub exp: String,
    /// Row labels (dataset, policy, x-value...).
    pub labels: Vec<(String, String)>,
    /// Metric values.
    pub values: Vec<(String, f64)>,
}

impl Row {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj().with("exp", self.exp.as_str().into());
        for (k, v) in &self.labels {
            j.set(k, v.as_str().into());
        }
        for (k, v) in &self.values {
            j.set(k, (*v).into());
        }
        j
    }
}

/// Write rows to `data/results/<exp>.jsonl` (best effort).
pub fn save_rows(exp: &str, rows: &[Row]) {
    let dir = std::path::Path::new("data/results");
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let path = dir.join(format!("{exp}.jsonl"));
    let mut out = String::new();
    for r in rows {
        out.push_str(&r.to_json().to_string_compact());
        out.push('\n');
    }
    let _ = std::fs::write(path, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_match_paper_counts() {
        let cloud: usize = cloud_pool_20().iter().map(|p| p.count).sum();
        assert_eq!(cloud, 20);
        let edge: usize = edge_pool(600).iter().map(|p| p.count).sum();
        assert_eq!(edge, 600);
        let edge: usize = edge_pool(1000).iter().map(|p| p.count).sum();
        assert_eq!(edge, 1000);
    }

    #[test]
    fn paper_config_builds_and_runs_tiny() {
        let cfg = paper_config(
            "gsm8k",
            60,
            10.0,
            RoutingKind::Jsq,
            BatchingKind::Lab,
            WindowKind::Static(4),
            Scale(0.05),
            1,
        );
        let rep = Simulator::new(cfg).run();
        assert!(rep.system.completed > 0);
    }

    #[test]
    fn scale_floors_request_count() {
        assert_eq!(Scale(0.001).n(400), 8);
        assert_eq!(Scale::full().n(400), 400);
    }

    #[test]
    fn run_points_is_bit_identical_to_run_seeds() {
        // The runner-backed path must reproduce the direct per-seed
        // path exactly: same configs, same simulator entry, same
        // floating-point trajectory.
        let cfg = paper_config(
            "gsm8k",
            60,
            10.0,
            RoutingKind::Jsq,
            BatchingKind::Lab,
            WindowKind::Static(4),
            Scale(0.03),
            1,
        );
        let seeds = [1u64, 2];
        let reps = run_seeds(&cfg, &seeds);
        let grids = vec![point_grid(cfg, &seeds, false)];
        let (points, stats) = run_points(&grids, seeds.len(), &ExpContext::default());
        assert_eq!(stats.total, 2);
        assert_eq!(points.len(), 1);
        for (rep, m) in reps.iter().zip(&points[0]) {
            assert_eq!(rep.system.completed as u64, m.completed);
            assert_eq!(rep.system.events_processed, m.events_processed);
            assert!((rep.system.throughput_rps - m.throughput_rps).abs() < 1e-12);
            assert!((rep.mean_ttft() - m.mean_ttft_ms).abs() < 1e-12);
            assert!((rep.mean_tpot() - m.mean_tpot_ms).abs() < 1e-12);
            assert!((rep.mean_e2e() - m.mean_e2e_ms).abs() < 1e-12);
        }
    }
}
