//! Figure 4 — GPU-level calibration: DSD-Sim's predicted prefill/decode
//! latencies vs "real hardware" measurements for Qwen-7B, Qwen-72B,
//! Llama2-7B, Llama2-70B on A40/A100/H100, over GSM8K-like prompts with
//! error bars across 100 requests.
//!
//! Paper result: prefill MAE ≈ 7.4%, decode MAE ≈ 5.2%, predictions
//! systematically *below* measurements (VIDUR omits NCCL + non-kernel
//! time).

use super::common::{save_rows, Row};
use crate::cluster::gpu::{A100, A40, H100};
use crate::cluster::model::{LLAMA2_70B, LLAMA2_7B, QWEN_72B, QWEN_7B};
use crate::cluster::{GpuSpec, ModelSpec};
use crate::hwmodel::{Hardware, HardwareOracle, Op, Predictor};
use crate::trace::GSM8K;
use crate::util::rng::Pcg64;
use crate::util::table::{fnum, Table};

/// The model/GPU pairs of Fig. 4 (each model on its natural tier).
fn configurations() -> Vec<(&'static ModelSpec, &'static GpuSpec, u32)> {
    vec![
        (&QWEN_7B, &A40, 1),
        (&LLAMA2_7B, &A40, 1),
        (&QWEN_7B, &A100, 1),
        (&LLAMA2_7B, &A100, 1),
        (&QWEN_72B, &A100, 4),
        (&LLAMA2_70B, &A100, 4),
        (&QWEN_72B, &H100, 4),
        (&LLAMA2_70B, &H100, 4),
    ]
}

/// Run the calibration; returns (table text, prefill MAE %, decode MAE %).
pub fn run(seed: u64) -> (String, f64, f64) {
    let predictor = Predictor::new();
    let mut oracle = HardwareOracle::new(seed);
    let mut rng = Pcg64::new(seed ^ 0xF16_4);
    let mut table = Table::new(&[
        "model/gpu",
        "op",
        "predicted ms",
        "measured ms",
        "±std",
        "err %",
    ])
    .with_title("Fig 4 — GPU-level calibration (predicted vs measured)");
    let mut rows = Vec::new();
    let mut prefill_errs = Vec::new();
    let mut decode_errs = Vec::new();

    for (model, gpu, tp) in configurations() {
        let hw = Hardware { gpu, tp };
        // GSM8K-like prompt lengths drive the op shapes (paper: all
        // models benchmarked on GSM8K prompts).
        let mut lens = Vec::new();
        for _ in 0..100 {
            let l = rng
                .lognormal(GSM8K.prompt_mu_sigma.0, GSM8K.prompt_mu_sigma.1)
                .round()
                .clamp(GSM8K.prompt_range.0 as f64, GSM8K.prompt_range.1 as f64);
            lens.push(l as u32);
        }
        let mean_len = (lens.iter().sum::<u32>() / lens.len() as u32).max(1);

        for (op_name, op) in [
            ("prefill", Op::Prefill { tokens: mean_len * 8, batch: 8 }),
            ("decode", Op::Decode { batch: 8, avg_ctx: mean_len + 64 }),
        ] {
            let predicted = predictor.predict(op, model, hw);
            let (measured, std) = oracle.measure_stats(op, model, hw, 100);
            let err = (measured - predicted).abs() / measured * 100.0;
            if op_name == "prefill" {
                prefill_errs.push(err);
            } else {
                decode_errs.push(err);
            }
            let label = format!("{}/{}x{}", model.name, tp, gpu.name);
            table.row(vec![
                label.clone(),
                op_name.into(),
                fnum(predicted, 2),
                fnum(measured, 2),
                fnum(std, 2),
                fnum(err, 1),
            ]);
            rows.push(Row {
                exp: "fig4".into(),
                labels: vec![
                    ("model".into(), model.name.into()),
                    ("gpu".into(), gpu.name.into()),
                    ("op".into(), op_name.into()),
                ],
                values: vec![
                    ("predicted_ms".into(), predicted),
                    ("measured_ms".into(), measured),
                    ("std_ms".into(), std),
                    ("err_pct".into(), err),
                ],
            });
        }
    }
    let mae_prefill = crate::util::stats::mean(&prefill_errs);
    let mae_decode = crate::util::stats::mean(&decode_errs);
    save_rows("fig4", &rows);
    let mut out = table.render();
    out.push_str(&format!(
        "\nMAE: prefill {:.1}% (paper ≈7.4%), decode {:.1}% (paper ≈5.2%); \
         predictions are systematically below measurements (omitted NCCL/non-kernel time)\n",
        mae_prefill, mae_decode
    ));
    (out, mae_prefill, mae_decode)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_reproduces_paper_shape() {
        let (text, mae_prefill, mae_decode) = run(42);
        assert!(text.contains("llama2-70b"));
        // Paper band: single-digit MAE, decode tighter than ~15%.
        assert!(mae_prefill > 0.5 && mae_prefill < 15.0, "prefill MAE {mae_prefill}");
        assert!(mae_decode > 0.5 && mae_decode < 15.0, "decode MAE {mae_decode}");
    }

    #[test]
    fn predictions_systematically_low() {
        // Re-run and check sign of the bias, the paper's key observation.
        let predictor = Predictor::new();
        let mut oracle = HardwareOracle::new(7);
        let mut low = 0;
        let mut total = 0;
        for (model, gpu, tp) in configurations() {
            let hw = Hardware { gpu, tp };
            let op = Op::Decode { batch: 8, avg_ctx: 128 };
            let p = predictor.predict(op, model, hw);
            let (m, _) = oracle.measure_stats(op, model, hw, 50);
            total += 1;
            if p < m {
                low += 1;
            }
        }
        assert_eq!(low, total, "every prediction should undershoot");
    }
}
