//! The "fairness" experiment family (`dsd reproduce fairness`): does a
//! batch-tier flash crowd starve interactive TTFT, and how much does
//! priority-aware admission buy back?
//!
//! One two-tier workload (a `classes:` block) serves every strategy:
//!
//! * an **interactive** tier arriving at a constant rate with the
//!   [`SloSpec::INTERACTIVE`] thresholds, and
//! * a **batch** tier whose own arrival process is a flash crowd — an
//!   8× [`ArrivalProcess::Spike`] over the middle third of the run —
//!   measured against [`SloSpec::RELAXED`].
//!
//! Three admission strategies serve it on the same fixed 4-target fleet:
//!
//! * **fifo** — class-blind admission (`priority_admission: false`):
//!   the multi-tenant run degenerates to arrival order, so the spike's
//!   batch requests queue ahead of interactive ones;
//! * **priority** — `priority_admission: true`: target queues are
//!   viewed highest-tier-first at batch formation (stable within a
//!   tier, so FIFO order inside each class survives);
//! * **priority_defer** — priority admission plus
//!   `defer_batch_threshold`: while the interactive backlog exceeds the
//!   threshold, batch-tier work is held out of batches entirely
//!   (unless it is all the queue holds — deferral never deadlocks).
//!
//! Per strategy the row reports each tier's seed-averaged mean TTFT and
//! SLO attainment (from the per-class breakdown the sweep runner
//! surfaces as [`CellMetrics::per_class`]) plus whole-run windowed
//! throughput, so the cost of defending the interactive tier — batch
//! TTFT and any throughput give-back — sits next to the benefit.
//!
//! Cells run through the cached sweep runner, so the family inherits
//! `--cache-dir`, `--threads`, and `--streaming` like every other
//! figure.

use super::common::{point_grid, run_points, save_rows, ExpContext, Row, Scale};
use crate::config::{
    BatchingKind, ClassSpec, ClassesConfig, RoutingKind, SimConfig, WindowKind,
};
use crate::metrics::SloSpec;
use crate::scenario::ArrivalProcess;
use crate::sweep::runner::CellMetrics;
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};

/// Interactive-tier arrival rate, requests/second (constant).
const INTERACTIVE_RATE: f64 = 12.0;
/// Batch-tier baseline rate, requests/second.
const BATCH_BASE: f64 = 6.0;
/// Batch-tier flash-crowd peak rate, requests/second.
const BATCH_PEAK: f64 = 48.0;
/// Full-scale request count across both tiers.
const REQUESTS_FULL: usize = 2_400;
/// Fixed fleet size (no autoscale in this family).
const FLEET: usize = 4;
/// Interactive backlog above which `priority_defer` holds batch work
/// back from admission.
const DEFER_THRESHOLD: usize = 4;

/// Expected run span at a scale, ms. The spike window is placed against
/// the run's *mean* combined rate (spike included), so the middle third
/// of the request budget really does land inside it.
fn span_ms(scale: Scale) -> f64 {
    let mean_rate = INTERACTIVE_RATE + BATCH_BASE + (BATCH_PEAK - BATCH_BASE) / 3.0;
    scale.n(REQUESTS_FULL) as f64 / mean_rate * 1_000.0
}

/// The shared two-tier workload with one strategy's admission knobs.
fn classes(scale: Scale, name: &str, priority: bool, defer: Option<usize>) -> ClassesConfig {
    let span = span_ms(scale);
    ClassesConfig {
        name: name.into(),
        tiers: vec![
            ClassSpec {
                name: "interactive".into(),
                arrivals: ArrivalProcess::Constant { rate_per_s: INTERACTIVE_RATE },
                slo: SloSpec::INTERACTIVE,
            },
            ClassSpec {
                name: "batch".into(),
                arrivals: ArrivalProcess::Spike {
                    base_per_s: BATCH_BASE,
                    peak_per_s: BATCH_PEAK,
                    t_start_ms: span / 3.0,
                    t_end_ms: span * 2.0 / 3.0,
                },
                slo: SloSpec::RELAXED,
            },
        ],
        priority_admission: priority,
        defer_batch_threshold: defer,
    }
}

/// The admission-strategy axis.
pub fn strategies(scale: Scale) -> Vec<(&'static str, ClassesConfig)> {
    vec![
        ("fifo", classes(scale, "fifo", false, None)),
        ("priority", classes(scale, "priority", true, None)),
        (
            "priority_defer",
            classes(scale, "priority_defer", true, Some(DEFER_THRESHOLD)),
        ),
    ]
}

/// One strategy's result row, seed-averaged.
#[derive(Clone, Debug)]
pub struct FairnessRow {
    /// Admission strategy name.
    pub strategy: &'static str,
    /// Interactive-tier mean TTFT, ms.
    pub interactive_ttft_ms: f64,
    /// Interactive-tier SLO attainment fraction.
    pub interactive_slo: f64,
    /// Batch-tier mean TTFT, ms.
    pub batch_ttft_ms: f64,
    /// Batch-tier SLO attainment fraction.
    pub batch_slo: f64,
    /// Mean windowed completion throughput over the run, req/s.
    pub throughput_rps: f64,
}

/// Baseline config: only the `classes:` block varies across strategies.
fn base_config(scale: Scale, classes: ClassesConfig, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::builder()
        .seed(seed)
        .targets(FLEET)
        .drafters(32)
        .requests(scale.n(REQUESTS_FULL))
        .rate_per_s(INTERACTIVE_RATE + BATCH_BASE)
        .rtt_ms(10.0)
        .dataset("gsm8k")
        .routing(RoutingKind::Jsq)
        .batching(BatchingKind::Lab)
        .window(WindowKind::Static(4))
        .build();
    cfg.classes = Some(classes);
    cfg
}

/// One tier's (mean TTFT, SLO attainment) from a cell's per-class
/// breakdown.
fn tier_reading(m: &CellMetrics, tier: &str) -> (f64, f64) {
    let pc = m
        .per_class
        .as_ref()
        .expect("fairness cells carry per-class metrics");
    let c = pc
        .iter()
        .find(|c| c.name == tier)
        .expect("fairness tier present in breakdown");
    (c.mean_ttft_ms, c.slo_attainment)
}

/// Whole-run windowed throughput (the run is non-stationary, so the
/// interquartile estimator is invalid — same caveat as the elasticity
/// family).
fn cell_throughput(m: &CellMetrics) -> f64 {
    match m.time_series.as_ref() {
        Some(ts) => {
            let end = ts.window_ms * ts.windows.len() as f64;
            ts.mean_throughput_between(0.0, end.max(ts.window_ms))
                .unwrap_or(m.throughput_rps)
        }
        None => m.throughput_rps,
    }
}

/// Run the full family on the cached runner: one grid per strategy,
/// batched through a single `run_points` call sharing the thread pool
/// and the cell cache.
pub fn sweep_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> Vec<FairnessRow> {
    let grids: Vec<_> = strategies(scale)
        .into_iter()
        .map(|(_, cl)| point_grid(base_config(scale, cl, seeds[0]), seeds, ctx.streaming))
        .collect();
    let (points, stats) = run_points(&grids, seeds.len(), ctx);
    if ctx.cache.is_some() {
        eprintln!("[fairness] {}", stats.describe());
    }
    strategies(scale)
        .iter()
        .zip(&points)
        .map(|(&(name, _), cells)| {
            let int: Vec<_> = cells.iter().map(|m| tier_reading(m, "interactive")).collect();
            let bat: Vec<_> = cells.iter().map(|m| tier_reading(m, "batch")).collect();
            FairnessRow {
                strategy: name,
                interactive_ttft_ms: mean(&int.iter().map(|r| r.0).collect::<Vec<_>>()),
                interactive_slo: mean(&int.iter().map(|r| r.1).collect::<Vec<_>>()),
                batch_ttft_ms: mean(&bat.iter().map(|r| r.0).collect::<Vec<_>>()),
                batch_slo: mean(&bat.iter().map(|r| r.1).collect::<Vec<_>>()),
                throughput_rps: mean(&cells.iter().map(cell_throughput).collect::<Vec<_>>()),
            }
        })
        .collect()
}

/// Run and render.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let rows = sweep_cached(scale, seeds, ctx);
    let mut table = Table::new(&[
        "strategy",
        "int ttft ms",
        "int slo %",
        "batch ttft ms",
        "batch slo %",
        "tput r/s",
    ])
    .with_title(
        "Fairness — batch-tier flash crowd vs interactive TTFT under class-blind, \
         priority, and priority+deferral admission",
    );
    let mut out_rows = Vec::new();
    for r in &rows {
        table.row(vec![
            r.strategy.into(),
            fnum(r.interactive_ttft_ms, 1),
            fnum(r.interactive_slo * 100.0, 1),
            fnum(r.batch_ttft_ms, 1),
            fnum(r.batch_slo * 100.0, 1),
            fnum(r.throughput_rps, 1),
        ]);
        out_rows.push(Row {
            exp: "fairness".into(),
            labels: vec![("strategy".into(), r.strategy.into())],
            values: vec![
                ("interactive_ttft_ms".into(), r.interactive_ttft_ms),
                ("interactive_slo".into(), r.interactive_slo),
                ("batch_ttft_ms".into(), r.batch_ttft_ms),
                ("batch_slo".into(), r.batch_slo),
                ("throughput_rps".into(), r.throughput_rps),
            ],
        });
    }
    save_rows("fairness", &out_rows);
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_family_produces_all_rows() {
        let scale = Scale(0.05);
        let rows = sweep_cached(scale, &[1], &ExpContext::default());
        assert_eq!(rows.len(), strategies(scale).len());
        for r in &rows {
            assert!(
                r.interactive_ttft_ms.is_finite() && r.interactive_ttft_ms > 0.0,
                "{}: interactive ttft {}",
                r.strategy,
                r.interactive_ttft_ms
            );
            assert!(
                r.batch_ttft_ms.is_finite() && r.batch_ttft_ms > 0.0,
                "{}: batch ttft {}",
                r.strategy,
                r.batch_ttft_ms
            );
            assert!((0.0..=1.0).contains(&r.interactive_slo), "{}", r.strategy);
            assert!((0.0..=1.0).contains(&r.batch_slo), "{}", r.strategy);
            assert!(r.throughput_rps > 0.0, "{}: throughput", r.strategy);
        }
    }

    #[test]
    fn priority_admission_defends_interactive_ttft() {
        // The ISSUE's acceptance shape: under the batch flash crowd,
        // priority admission must not leave the interactive tier worse
        // off than class-blind FIFO, and deferral at least as good as
        // plain priority on TTFT (it strictly restricts batch
        // admission). Tiny-scale runs are deterministic per seed, so
        // these are exact orderings, with an epsilon for ties when the
        // spike never backs the queue up.
        let scale = Scale(0.05);
        let rows = sweep_cached(scale, &[3], &ExpContext::default());
        let get = |s: &str| rows.iter().find(|r| r.strategy == s).unwrap();
        let (fifo, pri, defer) = (get("fifo"), get("priority"), get("priority_defer"));
        assert!(
            pri.interactive_ttft_ms <= fifo.interactive_ttft_ms + 1e-9,
            "priority {} vs fifo {}",
            pri.interactive_ttft_ms,
            fifo.interactive_ttft_ms
        );
        assert!(
            defer.interactive_ttft_ms <= pri.interactive_ttft_ms + 1e-9,
            "defer {} vs priority {}",
            defer.interactive_ttft_ms,
            pri.interactive_ttft_ms
        );
        assert!(
            pri.interactive_slo >= fifo.interactive_slo - 1e-9,
            "priority slo {} vs fifo {}",
            pri.interactive_slo,
            fifo.interactive_slo
        );
    }

    #[test]
    fn strategy_blocks_only_differ_in_admission_knobs() {
        // All three strategies serve byte-identical tier declarations;
        // only the admission knobs (and the block name) vary — so any
        // row difference is attributable to admission, not workload.
        let scale = Scale(0.05);
        let strats = strategies(scale);
        let tiers0 = &strats[0].1.tiers;
        for (_, cl) in &strats[1..] {
            assert_eq!(cl.tiers.len(), tiers0.len());
            for (a, b) in cl.tiers.iter().zip(tiers0) {
                assert_eq!(a.name, b.name);
                assert_eq!(
                    a.arrivals.to_canonical_json().to_string_compact(),
                    b.arrivals.to_canonical_json().to_string_compact()
                );
            }
        }
        assert!(!strats[0].1.priority_admission);
        assert!(strats[1].1.priority_admission);
        assert_eq!(strats[2].1.defer_batch_threshold, Some(DEFER_THRESHOLD));
        for (_, cl) in &strats {
            cl.validate().expect("strategy classes block validates");
        }
    }
}
