//! Figures 7 & 8 — routing-policy scaling ablation: throughput (Fig 7)
//! and TPOT (Fig 8) as the number of draft clients grows 0.4k → 2k, for
//! Random / Round-Robin / JSQ routing.
//!
//! Paper shape: JSQ is best while resources are not saturated (TPOT
//! 5–20 ms lower, best throughput to ≈1k drafters) but saturates and is
//! caught (and crossed on TPOT) by Round-Robin at high load.
//!
//! Execution rides the cached sweep runner: one grid per
//! (routing, drafter-count) point — each point needs its own base config
//! because the edge pool layout and the offered load both scale with the
//! drafter count — and all cells batch through a single
//! `run_cells_cached` call.

use super::common::{
    mean_metric, paper_config, point_grid, run_points, save_rows, ExpContext, Row, Scale,
};
use crate::config::{BatchingKind, RoutingKind, WindowKind};
use crate::util::table::{fnum, Table};

/// Drafter counts of the sweep.
pub fn drafter_points() -> Vec<usize> {
    vec![400, 800, 1200, 1600, 2000]
}

/// The three routing policies.
pub fn routings() -> Vec<(&'static str, RoutingKind)> {
    vec![
        ("Random", RoutingKind::Random),
        ("RR", RoutingKind::RoundRobin),
        ("JSQ", RoutingKind::Jsq),
    ]
}

/// `result[routing][point] = (drafters, tput, tpot)`.
pub fn sweep(dataset: &str, scale: Scale, seeds: &[u64]) -> Vec<Vec<(usize, f64, f64)>> {
    sweep_cached(dataset, scale, seeds, &ExpContext::default())
}

/// [`sweep`] on an explicit runner context (threads / cell cache /
/// streaming mode).
pub fn sweep_cached(
    dataset: &str,
    scale: Scale,
    seeds: &[u64],
    ctx: &ExpContext,
) -> Vec<Vec<(usize, f64, f64)>> {
    let mut grids = Vec::new();
    for (_, routing) in routings() {
        for n in drafter_points() {
            let mut cfg = paper_config(
                dataset,
                n,
                10.0,
                routing,
                BatchingKind::Lab,
                WindowKind::Static(4),
                scale,
                seeds[0],
            );
            // Offered load scales with the edge pool so saturation
            // is reached within the sweep (paper: load tracks the
            // number of draft clients).
            cfg.workload.rate_per_s *= n as f64 / 600.0;
            grids.push(point_grid(cfg, seeds, ctx.streaming));
        }
    }
    let (points, stats) = run_points(&grids, seeds.len(), ctx);
    if ctx.cache.is_some() {
        eprintln!("[fig7_8] {dataset}: {}", stats.describe());
    }
    let npts = drafter_points().len();
    routings()
        .iter()
        .enumerate()
        .map(|(ri, _)| {
            drafter_points()
                .into_iter()
                .enumerate()
                .map(|(pi, n)| {
                    let cells = &points[ri * npts + pi];
                    (
                        n,
                        mean_metric(cells, |m| m.throughput_rps),
                        mean_metric(cells, |m| m.mean_tpot_ms),
                    )
                })
                .collect()
        })
        .collect()
}

/// Run and render both figures' series.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let mut out = String::new();
    let mut rows = Vec::new();
    for dataset in ["gsm8k", "humaneval", "cnndm"] {
        let results = sweep_cached(dataset, scale, seeds, ctx);
        let mut t7 = Table::new(&["drafters", "Random", "RR", "JSQ"])
            .with_title(&format!("Fig 7 — throughput vs draft clients ({dataset})"));
        let mut t8 = Table::new(&["drafters", "Random", "RR", "JSQ"])
            .with_title(&format!("Fig 8 — TPOT vs draft clients ({dataset})"));
        for (pi, &n) in drafter_points().iter().enumerate() {
            t7.row(vec![
                n.to_string(),
                fnum(results[0][pi].1, 1),
                fnum(results[1][pi].1, 1),
                fnum(results[2][pi].1, 1),
            ]);
            t8.row(vec![
                n.to_string(),
                fnum(results[0][pi].2, 1),
                fnum(results[1][pi].2, 1),
                fnum(results[2][pi].2, 1),
            ]);
            for (ri, (rname, _)) in routings().iter().enumerate() {
                rows.push(Row {
                    exp: "fig7_8".into(),
                    labels: vec![
                        ("dataset".into(), dataset.into()),
                        ("routing".into(), rname.to_string()),
                        ("drafters".into(), n.to_string()),
                    ],
                    values: vec![
                        ("throughput_rps".into(), results[ri][pi].1),
                        ("tpot_ms".into(), results[ri][pi].2),
                    ],
                });
            }
        }
        out.push_str(&t7.render());
        out.push('\n');
        out.push_str(&t8.render());
        out.push('\n');
    }
    save_rows("fig7_8", &rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsq_wins_at_low_load() {
        let results = sweep("gsm8k", Scale(0.1), &[2]);
        // At the smallest drafter count (unsaturated), JSQ TPOT must not
        // exceed Random's.
        let random_tpot = results[0][0].2;
        let jsq_tpot = results[2][0].2;
        assert!(
            jsq_tpot <= random_tpot * 1.05,
            "jsq {jsq_tpot} vs random {random_tpot}"
        );
    }

    #[test]
    fn throughput_grows_then_saturates() {
        let results = sweep("gsm8k", Scale(0.1), &[2]);
        for series in &results {
            let first = series.first().unwrap().1;
            let best = series.iter().map(|p| p.1).fold(0.0, f64::max);
            assert!(best >= first, "load growth must not reduce peak throughput");
        }
    }
}
