//! The "elasticity" experiment family (`dsd reproduce elasticity`):
//! what does elastic cloud capacity buy — and cost — under non-stationary
//! load?
//!
//! Three provisioning strategies serve the same workloads on the same
//! 4-target physical fleet:
//!
//! * **static4** — the fixed over-provisioned baseline: all four targets
//!   on for the whole run (a `scheduled` autoscale block with
//!   `min = max = initial = 4`, so its cost is metered identically);
//! * **reactive** — queue-depth/utilization thresholds with hysteresis
//!   and cooldown, starting from two targets;
//! * **predictive** — the arrival-trend extrapolating policy
//!   ([`ScalingPolicy::Predictive`]), also starting from two targets,
//!   which requests capacity one provisioning lead *before* the spike
//!   lands.
//!
//! Two scripted load shapes exercise them (DiP-SD-style provisioning ×
//! speculation interaction): a **flash crowd** (3× arrival burst over
//! the middle third) and a **diurnal** cycle (sinusoidal rate, two full
//! periods). Per (scenario × strategy × seed) cell the windowed
//! [`TimeSeriesSummary`](crate::metrics::TimeSeriesSummary) provides
//! throughput over the whole non-stationary run (the interquartile
//! estimator is invalid here — see the caveat on
//! [`SystemMetrics::throughput_rps`](crate::metrics::SystemMetrics)),
//! the interactive SLO attainment comes from the sink counters, and the
//! cost columns come from the autoscale meter
//! ([`AutoscaleMetrics`](crate::autoscale::AutoscaleMetrics)): mean
//! provisioned targets, cost per 1k tokens, and relative cost vs. the
//! static baseline.
//!
//! Cells run through the cached sweep runner, so the family inherits
//! `--cache-dir`, `--threads`, and `--streaming` like every other
//! figure.

use super::common::{point_grid, run_points, save_rows, ExpContext, Row, Scale};
use crate::autoscale::{AutoscaleConfig, ScalingPolicy};
use crate::config::{BatchingKind, RoutingKind, SimConfig, WindowKind};
use crate::scenario::{ArrivalProcess, Scenario};
use crate::sweep::runner::CellMetrics;
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};

/// Nominal arrival rate, requests/second.
const RATE_PER_S: f64 = 30.0;
/// Full-scale request count (span = requests / rate ≈ 120 s).
const REQUESTS_FULL: usize = 3_600;
/// Physical fleet size (the autoscale maximum).
const FLEET: usize = 4;

/// Expected run span at a scale, ms.
fn span_ms(scale: Scale) -> f64 {
    scale.n(REQUESTS_FULL) as f64 / RATE_PER_S * 1_000.0
}

/// The two non-stationary load shapes.
pub fn scenarios(scale: Scale) -> Vec<(&'static str, Scenario)> {
    let span = span_ms(scale);
    vec![
        (
            "flash-crowd",
            Scenario {
                name: "flash-crowd".into(),
                arrivals: Some(ArrivalProcess::Spike {
                    base_per_s: RATE_PER_S,
                    peak_per_s: RATE_PER_S * 3.0,
                    t_start_ms: span / 3.0,
                    t_end_ms: span * 2.0 / 3.0,
                }),
                events: Vec::new(),
            },
        ),
        (
            "diurnal",
            Scenario {
                name: "diurnal".into(),
                arrivals: Some(ArrivalProcess::Diurnal {
                    mean_per_s: RATE_PER_S,
                    amplitude_per_s: RATE_PER_S * 0.6,
                    period_ms: span / 2.0,
                }),
                events: Vec::new(),
            },
        ),
    ]
}

/// Shared autoscale timing (full-scale runs tick every 500 ms; even the
/// tiny CI scale gets a dozen ticks).
fn timing(base: AutoscaleConfig) -> AutoscaleConfig {
    AutoscaleConfig {
        eval_interval_ms: 500.0,
        cooldown_ms: 1_500.0,
        provision_delay_ms: 1_000.0,
        cost_per_target_s: 1.0,
        ..base
    }
}

/// The provisioning-strategy axis.
pub fn strategies() -> Vec<(&'static str, AutoscaleConfig)> {
    vec![
        (
            "static4",
            timing(AutoscaleConfig {
                name: "static4".into(),
                policy: ScalingPolicy::Scheduled,
                min_targets: FLEET,
                max_targets: Some(FLEET),
                initial_targets: Some(FLEET),
                ..AutoscaleConfig::default()
            }),
        ),
        (
            "reactive",
            timing(AutoscaleConfig {
                name: "reactive".into(),
                policy: ScalingPolicy::Reactive {
                    up_queue_depth: 6.0,
                    down_queue_depth: 1.0,
                    down_utilization: 0.35,
                },
                min_targets: 1,
                max_targets: Some(FLEET),
                initial_targets: Some(2),
                ..AutoscaleConfig::default()
            }),
        ),
        (
            "predictive",
            timing(AutoscaleConfig {
                name: "predictive".into(),
                policy: ScalingPolicy::Predictive {
                    window_ticks: 4,
                    up_backlog_per_target: 6.0,
                    down_backlog_per_target: 1.0,
                },
                min_targets: 1,
                max_targets: Some(FLEET),
                initial_targets: Some(2),
                ..AutoscaleConfig::default()
            }),
        ),
    ]
}

/// One (scenario × strategy) result row, seed-averaged.
#[derive(Clone, Debug)]
pub struct ElasticityRow {
    /// Scenario name.
    pub scenario: &'static str,
    /// Provisioning strategy name.
    pub policy: &'static str,
    /// Mean windowed completion throughput over the run, req/s.
    pub throughput_rps: f64,
    /// Interactive-tier SLO attainment fraction.
    pub slo_interactive: f64,
    /// Time-averaged provisioned target count.
    pub mean_targets: f64,
    /// Cost per 1 000 generated tokens.
    pub cost_per_1k_tokens: f64,
    /// Total cost relative to the static baseline of the same scenario
    /// (1.0 = identical; the baseline's own row shows 1.0).
    pub cost_vs_static: f64,
    /// Seed-averaged absolute cost (basis of `cost_vs_static`).
    pub cost: f64,
}

/// Baseline config: only the scenario and the autoscale block vary.
fn base_config(scale: Scale, scenario: Scenario, auto: AutoscaleConfig, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::builder()
        .seed(seed)
        .targets(FLEET)
        .drafters(32)
        .requests(scale.n(REQUESTS_FULL))
        .rate_per_s(RATE_PER_S)
        .rtt_ms(10.0)
        .dataset("gsm8k")
        .routing(RoutingKind::Jsq)
        .batching(BatchingKind::Lab)
        .window(WindowKind::Static(4))
        .build();
    cfg.scenario = Some(scenario);
    cfg.autoscale = Some(auto);
    cfg
}

/// Per-cell readings the rows average.
fn cell_readings(m: &CellMetrics) -> (f64, f64, f64, f64, f64) {
    let ts = m.time_series.as_ref().expect("elasticity cells carry a time series");
    let end = ts.window_ms * ts.windows.len() as f64;
    let tput = ts.mean_throughput_between(0.0, end.max(ts.window_ms)).unwrap_or(0.0);
    let auto = m.autoscale.as_ref().expect("elasticity cells carry autoscale metrics");
    let duration_s = (m.sim_duration_ms / 1_000.0).max(1e-9);
    (
        tput,
        m.slo_interactive.expect("elasticity cells carry SLO attainment"),
        auto.target_seconds / duration_s,
        auto.cost_per_1k_tokens,
        auto.cost,
    )
}

/// Run the full family on the cached runner: every (scenario ×
/// strategy) grid batches through one `run_points` call per scenario,
/// sharing the thread pool and the cell cache.
pub fn sweep_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> Vec<ElasticityRow> {
    let mut rows = Vec::new();
    for (sname, scenario) in scenarios(scale) {
        let grids: Vec<_> = strategies()
            .iter()
            .map(|(_, auto)| {
                point_grid(
                    base_config(scale, scenario.clone(), auto.clone(), seeds[0]),
                    seeds,
                    ctx.streaming,
                )
            })
            .collect();
        let (points, stats) = run_points(&grids, seeds.len(), ctx);
        if ctx.cache.is_some() {
            eprintln!("[elasticity] {sname}: {}", stats.describe());
        }
        let mut scenario_rows = Vec::new();
        for (&(pname, _), cells) in strategies().iter().zip(&points) {
            let readings: Vec<_> = cells.iter().map(cell_readings).collect();
            scenario_rows.push(ElasticityRow {
                scenario: sname,
                policy: pname,
                throughput_rps: mean(&readings.iter().map(|r| r.0).collect::<Vec<_>>()),
                slo_interactive: mean(&readings.iter().map(|r| r.1).collect::<Vec<_>>()),
                mean_targets: mean(&readings.iter().map(|r| r.2).collect::<Vec<_>>()),
                cost_per_1k_tokens: mean(&readings.iter().map(|r| r.3).collect::<Vec<_>>()),
                cost_vs_static: f64::NAN, // filled below
                cost: mean(&readings.iter().map(|r| r.4).collect::<Vec<_>>()),
            });
        }
        let static_cost = scenario_rows
            .iter()
            .find(|r| r.policy == "static4")
            .map(|r| r.cost)
            .unwrap_or(f64::NAN);
        for r in &mut scenario_rows {
            r.cost_vs_static = r.cost / static_cost;
        }
        rows.extend(scenario_rows);
    }
    rows
}

/// Run and render.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let rows = sweep_cached(scale, seeds, ctx);
    let mut table = Table::new(&[
        "scenario",
        "policy",
        "tput r/s",
        "slo %",
        "targets",
        "cost/1k tok",
        "vs static",
    ])
    .with_title(
        "Elasticity — static over-provisioning vs reactive vs predictive autoscaling \
         (windowed throughput, interactive SLO attainment, provisioned-capacity cost)",
    );
    let mut out_rows = Vec::new();
    for r in &rows {
        table.row(vec![
            r.scenario.into(),
            r.policy.into(),
            fnum(r.throughput_rps, 1),
            fnum(r.slo_interactive * 100.0, 1),
            fnum(r.mean_targets, 2),
            fnum(r.cost_per_1k_tokens, 3),
            fnum(r.cost_vs_static, 2),
        ]);
        out_rows.push(Row {
            exp: "elasticity".into(),
            labels: vec![
                ("scenario".into(), r.scenario.into()),
                ("policy".into(), r.policy.into()),
            ],
            values: vec![
                ("throughput_rps".into(), r.throughput_rps),
                ("slo_interactive".into(), r.slo_interactive),
                ("mean_targets".into(), r.mean_targets),
                ("cost_per_1k_tokens".into(), r.cost_per_1k_tokens),
                ("cost_vs_static".into(), r.cost_vs_static),
                ("cost".into(), r.cost),
            ],
        });
    }
    save_rows("elasticity", &out_rows);
    table.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_family_produces_all_rows() {
        let rows = sweep_cached(Scale(0.05), &[1], &ExpContext::default());
        assert_eq!(rows.len(), scenarios(Scale(0.05)).len() * strategies().len());
        for r in &rows {
            assert!(r.throughput_rps > 0.0, "{}/{}: throughput", r.scenario, r.policy);
            assert!(
                (0.0..=1.0).contains(&r.slo_interactive),
                "{}/{}: slo {}",
                r.scenario,
                r.policy,
                r.slo_interactive
            );
            assert!(
                r.mean_targets >= 1.0 - 1e-9 && r.mean_targets <= FLEET as f64 + 1e-9,
                "{}/{}: targets {}",
                r.scenario,
                r.policy,
                r.mean_targets
            );
            assert!(r.cost.is_finite() && r.cost > 0.0);
            assert!(r.cost_vs_static.is_finite());
        }
    }

    #[test]
    fn static_baseline_pays_for_the_full_fleet_and_elastic_never_pays_more() {
        let rows = sweep_cached(Scale(0.05), &[2], &ExpContext::default());
        for (sname, _) in scenarios(Scale(0.05)) {
            let get = |p: &str| {
                rows.iter()
                    .find(|r| r.scenario == sname && r.policy == p)
                    .unwrap()
            };
            let stat = get("static4");
            assert!(
                (stat.mean_targets - FLEET as f64).abs() < 1e-6,
                "{sname}: static fleet {}",
                stat.mean_targets
            );
            assert!((stat.cost_vs_static - 1.0).abs() < 1e-9);
            for p in ["reactive", "predictive"] {
                let r = get(p);
                // Elastic strategies are bounded by the same max fleet
                // and start at half of it, so they cannot meaningfully
                // out-spend the always-on baseline. Slack covers the
                // longer tail an under-provisioned ramp can cause (the
                // run ends at the last completion, and elastic runs
                // start with half the capacity).
                assert!(
                    r.cost_vs_static <= 1.25,
                    "{sname}/{p}: cost ratio {}",
                    r.cost_vs_static
                );
                assert!(r.mean_targets <= FLEET as f64 + 1e-9);
            }
        }
    }
}
