//! Figure 5 — end-to-end SLOs and throughput for accumulating policy
//! stacks on the 20-target / 600-drafter cluster at 10 ms RTT:
//!
//! * Default   : Random routing + FIFO + Static γ
//! * Setting 1 : JSQ + FIFO + Static γ
//! * Setting 2 : JSQ + LAB + Static γ
//! * Setting 3 : JSQ + LAB + Dynamic γ
//! * Setting 4 : JSQ + LAB + AWC
//!
//! Paper shape: each addition improves throughput and latency; on GSM8K
//! throughput climbs ≈25.1 → 28.1 req/s, TPOT drops ≈45 → 37 ms, with
//! AWC providing the main latency gain.
//!
//! Execution rides the cached sweep runner: one grid per policy stack
//! (the stacks are hand-picked routing × batching × window combinations,
//! not a cross product), all cells batched through a single
//! `run_cells_cached` call — so `dsd reproduce --exp fig5 --cache-dir`
//! resumes and skips like any sweep, and `--streaming` bounds per-cell
//! memory at any request count.

use super::common::{
    mean_metric, paper_config, point_grid, run_points, save_rows, ExpContext, Row, Scale,
};
use crate::config::{BatchingKind, RoutingKind, WindowKind};
use crate::util::table::{fnum, Table};

/// The five policy stacks in paper order.
pub fn stacks() -> Vec<(&'static str, RoutingKind, BatchingKind, WindowKind)> {
    vec![
        ("Default", RoutingKind::Random, BatchingKind::Fifo, WindowKind::Static(4)),
        ("Setting1", RoutingKind::Jsq, BatchingKind::Fifo, WindowKind::Static(4)),
        ("Setting2", RoutingKind::Jsq, BatchingKind::Lab, WindowKind::Static(4)),
        (
            "Setting3",
            RoutingKind::Jsq,
            BatchingKind::Lab,
            WindowKind::Dynamic { init: 4, lo: 0.25, hi: 0.75 },
        ),
        ("Setting4", RoutingKind::Jsq, BatchingKind::Lab, WindowKind::Awc { weights_path: None }),
    ]
}

/// One dataset's stack sweep; returns rows of
/// (stack, throughput, ttft, tpot).
pub fn sweep(dataset: &str, scale: Scale, seeds: &[u64]) -> Vec<(String, f64, f64, f64)> {
    sweep_cached(dataset, scale, seeds, &ExpContext::default())
}

/// [`sweep`] on an explicit runner context (threads / cell cache /
/// streaming mode).
pub fn sweep_cached(
    dataset: &str,
    scale: Scale,
    seeds: &[u64],
    ctx: &ExpContext,
) -> Vec<(String, f64, f64, f64)> {
    let grids: Vec<_> = stacks()
        .into_iter()
        .map(|(_, routing, batching, window)| {
            point_grid(
                paper_config(dataset, 600, 10.0, routing, batching, window, scale, seeds[0]),
                seeds,
                ctx.streaming,
            )
        })
        .collect();
    let (points, stats) = run_points(&grids, seeds.len(), ctx);
    if ctx.cache.is_some() {
        eprintln!("[fig5] {dataset}: {}", stats.describe());
    }
    stacks()
        .into_iter()
        .zip(points)
        .map(|((name, _, _, _), cells)| {
            (
                name.to_string(),
                mean_metric(&cells, |m| m.throughput_rps),
                mean_metric(&cells, |m| m.mean_ttft_ms),
                mean_metric(&cells, |m| m.mean_tpot_ms),
            )
        })
        .collect()
}

/// Run the full figure and render the paper-style table.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let mut out = String::new();
    let mut rows = Vec::new();
    for dataset in ["gsm8k", "cnndm", "humaneval"] {
        let mut table = Table::new(&["stack", "tput req/s", "TTFT ms", "TPOT ms"])
            .with_title(&format!("Fig 5 — policy stacks ({dataset})"));
        for (name, tput, ttft, tpot) in sweep_cached(dataset, scale, seeds, ctx) {
            table.row(vec![
                name.clone(),
                fnum(tput, 1),
                fnum(ttft, 0),
                fnum(tpot, 1),
            ]);
            rows.push(Row {
                exp: "fig5".into(),
                labels: vec![
                    ("dataset".into(), dataset.into()),
                    ("stack".into(), name),
                ],
                values: vec![
                    ("throughput_rps".into(), tput),
                    ("ttft_ms".into(), ttft),
                    ("tpot_ms".into(), tpot),
                ],
            });
        }
        out.push_str(&table.render());
        out.push('\n');
    }
    save_rows("fig5", &rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stacks_are_the_paper_stacks() {
        let s = stacks();
        assert_eq!(s.len(), 5);
        assert_eq!(s[0].0, "Default");
        assert!(matches!(s[0].1, RoutingKind::Random));
        assert!(matches!(s[4].3, WindowKind::Awc { .. }));
    }

    #[test]
    fn full_stack_beats_default_on_gsm8k() {
        // The paper's qualitative claim: accumulating the policies yields
        // steady improvement. Compare endpoints at reduced scale.
        let rows = sweep("gsm8k", Scale(0.15), &[1, 2]);
        let default = &rows[0];
        let setting4 = &rows[4];
        assert!(
            setting4.1 >= default.1 * 0.98,
            "throughput: default {} vs setting4 {}",
            default.1,
            setting4.1
        );
        assert!(
            setting4.3 <= default.3 * 1.05,
            "tpot: default {} vs setting4 {}",
            default.3,
            setting4.3
        );
    }

    #[test]
    fn streaming_context_runs() {
        let ctx = ExpContext {
            streaming: true,
            ..ExpContext::default()
        };
        let rows = sweep_cached("gsm8k", Scale(0.02), &[1], &ctx);
        assert_eq!(rows.len(), 5);
        for (_, tput, ttft, tpot) in rows {
            assert!(tput > 0.0 && ttft > 0.0 && tpot > 0.0);
        }
    }
}
