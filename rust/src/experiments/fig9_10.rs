//! Figures 9 & 10 — queueing/batching ablation: FIFO vs Length-Aware
//! Batching (LAB) across workloads and load levels.
//!
//! Paper shape: LAB's similar-length grouping cuts padding, lowering
//! TPOT by a small constant margin (≈1–2 ms) under moderate-to-high
//! load (Fig 9); both policies reach the same throughput ceiling once
//! the system saturates beyond ≈1k drafters (Fig 10) — queue order does
//! not create compute capacity.
//!
//! Execution rides the cached sweep runner: one grid per
//! (batching, drafter-count) point, every cell batched through a single
//! `run_cells_cached` call (same structure as Fig 7/8).

use super::common::{
    mean_metric, paper_config, point_grid, run_points, save_rows, ExpContext, Row, Scale,
};
use crate::config::{BatchingKind, RoutingKind, WindowKind};
use crate::util::table::{fnum, Table};

/// Drafter counts of the sweep (same axis as Fig 7/8).
pub fn drafter_points() -> Vec<usize> {
    vec![400, 800, 1200, 1600, 2000]
}

/// The two batching policies of the ablation (paper order).
pub fn batchings() -> Vec<BatchingKind> {
    vec![BatchingKind::Fifo, BatchingKind::Lab]
}

/// `result[policy][point] = (drafters, tput, tpot)`; policy 0 = FIFO,
/// 1 = LAB.
pub fn sweep(dataset: &str, scale: Scale, seeds: &[u64]) -> Vec<Vec<(usize, f64, f64)>> {
    sweep_cached(dataset, scale, seeds, &ExpContext::default())
}

/// [`sweep`] on an explicit runner context (threads / cell cache /
/// streaming mode).
pub fn sweep_cached(
    dataset: &str,
    scale: Scale,
    seeds: &[u64],
    ctx: &ExpContext,
) -> Vec<Vec<(usize, f64, f64)>> {
    let mut grids = Vec::new();
    for batching in batchings() {
        for n in drafter_points() {
            let mut cfg = paper_config(
                dataset,
                n,
                10.0,
                RoutingKind::Jsq,
                batching,
                WindowKind::Static(4),
                scale,
                seeds[0],
            );
            cfg.workload.rate_per_s *= n as f64 / 600.0;
            grids.push(point_grid(cfg, seeds, ctx.streaming));
        }
    }
    let (points, stats) = run_points(&grids, seeds.len(), ctx);
    if ctx.cache.is_some() {
        eprintln!("[fig9_10] {dataset}: {}", stats.describe());
    }
    let npts = drafter_points().len();
    batchings()
        .iter()
        .enumerate()
        .map(|(bi, _)| {
            drafter_points()
                .into_iter()
                .enumerate()
                .map(|(pi, n)| {
                    let cells = &points[bi * npts + pi];
                    (
                        n,
                        mean_metric(cells, |m| m.throughput_rps),
                        mean_metric(cells, |m| m.mean_tpot_ms),
                    )
                })
                .collect()
        })
        .collect()
}

/// Run and render both figures.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let mut out = String::new();
    let mut rows = Vec::new();
    for dataset in ["gsm8k", "humaneval", "cnndm"] {
        let results = sweep_cached(dataset, scale, seeds, ctx);
        let mut t9 = Table::new(&["drafters", "FIFO TPOT", "LAB TPOT", "Δ"])
            .with_title(&format!("Fig 9 — FIFO vs LAB latency ({dataset})"));
        let mut t10 = Table::new(&["drafters", "FIFO tput", "LAB tput"])
            .with_title(&format!("Fig 10 — FIFO vs LAB throughput ({dataset})"));
        for (pi, &n) in drafter_points().iter().enumerate() {
            let (fifo, lab) = (&results[0][pi], &results[1][pi]);
            t9.row(vec![
                n.to_string(),
                fnum(fifo.2, 1),
                fnum(lab.2, 1),
                fnum(lab.2 - fifo.2, 2),
            ]);
            t10.row(vec![n.to_string(), fnum(fifo.1, 1), fnum(lab.1, 1)]);
            rows.push(Row {
                exp: "fig9_10".into(),
                labels: vec![
                    ("dataset".into(), dataset.into()),
                    ("drafters".into(), n.to_string()),
                ],
                values: vec![
                    ("fifo_tput".into(), fifo.1),
                    ("lab_tput".into(), lab.1),
                    ("fifo_tpot".into(), fifo.2),
                    ("lab_tpot".into(), lab.2),
                ],
            });
        }
        out.push_str(&t9.render());
        out.push('\n');
        out.push_str(&t10.render());
        out.push('\n');
    }
    save_rows("fig9_10", &rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lab_does_not_hurt_latency() {
        // CNN/DM has the widest prompt-length spread, so padding —
        // and LAB's advantage — is largest there.
        let results = sweep("cnndm", Scale(0.1), &[4]);
        let fifo_mean: f64 =
            results[0].iter().map(|p| p.2).sum::<f64>() / results[0].len() as f64;
        let lab_mean: f64 =
            results[1].iter().map(|p| p.2).sum::<f64>() / results[1].len() as f64;
        assert!(
            lab_mean <= fifo_mean * 1.03,
            "lab {lab_mean} vs fifo {fifo_mean}"
        );
    }

    #[test]
    fn both_policies_complete_all_loads() {
        let results = sweep("gsm8k", Scale(0.08), &[4]);
        for series in &results {
            for &(_, tput, tpot) in series {
                assert!(tput > 0.0 && tpot > 0.0);
            }
        }
    }
}
