//! Figures 9 & 10 — queueing/batching ablation: FIFO vs Length-Aware
//! Batching (LAB) across workloads and load levels.
//!
//! Paper shape: LAB's similar-length grouping cuts padding, lowering
//! TPOT by a small constant margin (≈1–2 ms) under moderate-to-high
//! load (Fig 9); both policies reach the same throughput ceiling once
//! the system saturates beyond ≈1k drafters (Fig 10) — queue order does
//! not create compute capacity.

use super::common::{mean_of, paper_config, run_seeds, save_rows, Row, Scale};
use crate::config::{BatchingKind, RoutingKind, WindowKind};
use crate::util::table::{fnum, Table};

/// Drafter counts of the sweep (same axis as Fig 7/8).
pub fn drafter_points() -> Vec<usize> {
    vec![400, 800, 1200, 1600, 2000]
}

/// `result[policy][point] = (drafters, tput, tpot)`; policy 0 = FIFO,
/// 1 = LAB.
pub fn sweep(dataset: &str, scale: Scale, seeds: &[u64]) -> Vec<Vec<(usize, f64, f64)>> {
    [BatchingKind::Fifo, BatchingKind::Lab]
        .iter()
        .map(|&batching| {
            drafter_points()
                .into_iter()
                .map(|n| {
                    let mut cfg = paper_config(
                        dataset,
                        n,
                        10.0,
                        RoutingKind::Jsq,
                        batching,
                        WindowKind::Static(4),
                        scale,
                        seeds[0],
                    );
                    cfg.workload.rate_per_s *= n as f64 / 600.0;
                    let reps = run_seeds(&cfg, seeds);
                    (
                        n,
                        mean_of(&reps, |r| r.system.throughput_rps),
                        mean_of(&reps, |r| r.mean_tpot()),
                    )
                })
                .collect()
        })
        .collect()
}

/// Run and render both figures.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    let mut out = String::new();
    let mut rows = Vec::new();
    for dataset in ["gsm8k", "humaneval", "cnndm"] {
        let results = sweep(dataset, scale, seeds);
        let mut t9 = Table::new(&["drafters", "FIFO TPOT", "LAB TPOT", "Δ"])
            .with_title(&format!("Fig 9 — FIFO vs LAB latency ({dataset})"));
        let mut t10 = Table::new(&["drafters", "FIFO tput", "LAB tput"])
            .with_title(&format!("Fig 10 — FIFO vs LAB throughput ({dataset})"));
        for (pi, &n) in drafter_points().iter().enumerate() {
            let (fifo, lab) = (&results[0][pi], &results[1][pi]);
            t9.row(vec![
                n.to_string(),
                fnum(fifo.2, 1),
                fnum(lab.2, 1),
                fnum(lab.2 - fifo.2, 2),
            ]);
            t10.row(vec![n.to_string(), fnum(fifo.1, 1), fnum(lab.1, 1)]);
            rows.push(Row {
                exp: "fig9_10".into(),
                labels: vec![
                    ("dataset".into(), dataset.into()),
                    ("drafters".into(), n.to_string()),
                ],
                values: vec![
                    ("fifo_tput".into(), fifo.1),
                    ("lab_tput".into(), lab.1),
                    ("fifo_tpot".into(), fifo.2),
                    ("lab_tpot".into(), lab.2),
                ],
            });
        }
        out.push_str(&t9.render());
        out.push('\n');
        out.push_str(&t10.render());
        out.push('\n');
    }
    save_rows("fig9_10", &rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lab_does_not_hurt_latency() {
        // CNN/DM has the widest prompt-length spread, so padding —
        // and LAB's advantage — is largest there.
        let results = sweep("cnndm", Scale(0.1), &[4]);
        let fifo_mean: f64 =
            results[0].iter().map(|p| p.2).sum::<f64>() / results[0].len() as f64;
        let lab_mean: f64 =
            results[1].iter().map(|p| p.2).sum::<f64>() / results[1].len() as f64;
        assert!(
            lab_mean <= fifo_mean * 1.03,
            "lab {lab_mean} vs fifo {fifo_mean}"
        );
    }

    #[test]
    fn both_policies_complete_all_loads() {
        let results = sweep("gsm8k", Scale(0.08), &[4]);
        for series in &results {
            for &(_, tput, tpot) in series {
                assert!(tput > 0.0 && tpot > 0.0);
            }
        }
    }
}
