//! Table 2 — AWC vs the Static (γ=4) and Dynamic (Simple) window
//! baselines over four system configurations × three datasets.
//!
//! Configs: {20 targets / 600 drafters, 20 / 1000} × {10 ms, 30 ms} RTT.
//! Paper shape: AWC has the best throughput in 12/12 cells (+3–10% vs
//! Static), TTFT within ±4% of the best baseline, TPOT 6–10% lower.
//!
//! Execution rides the cached sweep runner: one grid per
//! (config, dataset, policy) cell, all 36 cells × seeds batched through
//! a single `run_cells_cached` call.

use super::common::{
    mean_metric, paper_config, point_grid, run_points, save_rows, ExpContext, Row, Scale,
};
use crate::config::{BatchingKind, RoutingKind, WindowKind};
use crate::util::table::{fnum, fpct, Table};

/// The four cluster configs of Table 2: (label, drafters, rtt).
pub fn configs() -> Vec<(&'static str, usize, f64)> {
    vec![
        ("C1 20T/600D 10ms", 600, 10.0),
        ("C2 20T/1000D 10ms", 1000, 10.0),
        ("C3 20T/600D 30ms", 600, 30.0),
        ("C4 20T/1000D 30ms", 1000, 30.0),
    ]
}

/// The three window policies (paper column order).
pub fn policies() -> Vec<(&'static str, WindowKind)> {
    vec![
        ("Static", WindowKind::Static(4)),
        ("Simple", WindowKind::Dynamic { init: 4, lo: 0.25, hi: 0.75 }),
        ("AWC", WindowKind::Awc { weights_path: None }),
    ]
}

/// Datasets in table column order.
const DATASETS: [&str; 3] = ["gsm8k", "humaneval", "cnndm"];

/// One cell's metrics.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    /// req/s.
    pub tput: f64,
    /// ms.
    pub ttft: f64,
    /// ms.
    pub tpot: f64,
}

/// Run the whole table; returns `result[config][dataset][policy]`.
pub fn sweep(scale: Scale, seeds: &[u64]) -> Vec<Vec<Vec<Cell>>> {
    sweep_cached(scale, seeds, &ExpContext::default())
}

/// [`sweep`] on an explicit runner context (threads / cell cache /
/// streaming mode).
pub fn sweep_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> Vec<Vec<Vec<Cell>>> {
    let mut grids = Vec::new();
    for &(_, drafters, rtt) in &configs() {
        for ds in DATASETS {
            for (_, w) in policies() {
                grids.push(point_grid(
                    paper_config(
                        ds,
                        drafters,
                        rtt,
                        RoutingKind::Jsq,
                        BatchingKind::Lab,
                        w,
                        scale,
                        seeds[0],
                    ),
                    seeds,
                    ctx.streaming,
                ));
            }
        }
    }
    let (points, stats) = run_points(&grids, seeds.len(), ctx);
    if ctx.cache.is_some() {
        eprintln!("[table2] {}", stats.describe());
    }
    let n_pol = policies().len();
    let n_ds = DATASETS.len();
    configs()
        .iter()
        .enumerate()
        .map(|(ci, _)| {
            (0..n_ds)
                .map(|di| {
                    (0..n_pol)
                        .map(|pi| {
                            let cells = &points[(ci * n_ds + di) * n_pol + pi];
                            Cell {
                                tput: mean_metric(cells, |m| m.throughput_rps),
                                ttft: mean_metric(cells, |m| m.mean_ttft_ms),
                                tpot: mean_metric(cells, |m| m.mean_tpot_ms),
                            }
                        })
                        .collect()
                })
                .collect()
        })
        .collect()
}

/// Run and render the paper-style table.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let results = sweep_cached(scale, seeds, ctx);
    let mut out = String::new();
    let mut rows = Vec::new();
    for (metric_idx, (metric, better_high)) in
        [("Throughput (req/s) ↑", true), ("TTFT (ms) ↓", false), ("TPOT (ms) ↓", false)]
            .iter()
            .enumerate()
    {
        let mut table = Table::new(&[
            "config", "dataset", "Static", "Simple", "AWC", "AWC vs Static",
        ])
        .with_title(&format!("Table 2 — {metric}"));
        for (ci, (clabel, _, _)) in configs().iter().enumerate() {
            for (di, ds) in DATASETS.iter().enumerate() {
                let cells = &results[ci][di];
                let get = |c: &Cell| match metric_idx {
                    0 => c.tput,
                    1 => c.ttft,
                    _ => c.tpot,
                };
                let s = get(&cells[0]);
                let d = get(&cells[1]);
                let a = get(&cells[2]);
                let delta = if *better_high {
                    (a - s) / s * 100.0
                } else {
                    (a - s) / s * 100.0
                };
                table.row(vec![
                    clabel.to_string(),
                    ds.to_string(),
                    fnum(s, 1),
                    fnum(d, 1),
                    fnum(a, 1),
                    fpct(delta),
                ]);
                rows.push(Row {
                    exp: "table2".into(),
                    labels: vec![
                        ("config".into(), clabel.to_string()),
                        ("dataset".into(), ds.to_string()),
                        ("metric".into(), metric.to_string()),
                    ],
                    values: vec![
                        ("static".into(), s),
                        ("simple".into(), d),
                        ("awc".into(), a),
                        ("awc_vs_static_pct".into(), delta),
                    ],
                });
            }
        }
        out.push_str(&table.render());
        out.push('\n');
    }
    save_rows("table2", &rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_runs_and_has_sane_cells() {
        let r = sweep(Scale(0.08), &[1]);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].len(), 3);
        assert_eq!(r[0][0].len(), 3);
        for cfg in &r {
            for ds in cfg {
                for cell in ds {
                    assert!(cell.tput > 0.0 && cell.tpot > 0.0 && cell.ttft > 0.0);
                }
            }
        }
    }

    #[test]
    fn higher_rtt_lowers_throughput() {
        let r = sweep(Scale(0.08), &[1]);
        // C1 (10ms) vs C3 (30ms), same drafters, per dataset.
        for di in 0..3 {
            let tput_10 = r[0][di][0].tput;
            let tput_30 = r[2][di][0].tput;
            assert!(
                tput_30 <= tput_10 * 1.1,
                "dataset {di}: rtt30 {tput_30} vs rtt10 {tput_10}"
            );
        }
    }
}
