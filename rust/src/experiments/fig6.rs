//! Figure 6 — distributed vs fused (cloud-only) execution as RTT grows.
//!
//! Paper shape: distributed wins at low RTT (edge drafting runs
//! concurrently with cloud verification and each verify covers several
//! tokens), degrades linearly as every speculation round pays the link;
//! fused is flat (work stays local). The curves cross around 50–60 ms.
//!
//! The RTT × mode × seed grid runs on the parallel sweep runner
//! ([`crate::sweep`]); cell ordering is deterministic, so the figure is
//! bit-identical across thread counts.

use super::common::{paper_config, save_rows, ExpContext, Row, Scale};
use crate::config::{BatchingKind, RoutingKind, WindowKind};
use crate::sweep::grid::window_label;
use crate::sweep::{run_grid_cached, CellResult, SweepGrid};
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};

/// RTT sweep values, ms.
pub fn rtt_points() -> Vec<f64> {
    vec![0.0, 10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0, 100.0]
}

/// Series produced per mode: (rtt, throughput, ttft, tpot).
pub type Series = Vec<(f64, f64, f64, f64)>;

/// Run both modes over the sweep (cells execute in parallel on the
/// sweep runner; results are selected back by their axis labels).
pub fn sweep(scale: Scale, seeds: &[u64]) -> (Series, Series) {
    sweep_cached(scale, seeds, &ExpContext::default())
}

/// [`sweep`] on an explicit runner context: re-running the figure (or
/// widening its seed list) against a cell cache only executes cells the
/// cache has not seen; `streaming` bounds per-cell memory.
pub fn sweep_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> (Series, Series) {
    let mut base = paper_config(
        "gsm8k",
        600,
        0.0,
        RoutingKind::Jsq,
        BatchingKind::Lab,
        WindowKind::Static(4),
        scale,
        seeds[0],
    );
    // Controlled operating point for this figure: an offered load
    // between the fused and distributed capacities, so the trade-off
    // (not pure saturation) is what's measured.
    base.workload.rate_per_s = 45.0;
    let mut grid = SweepGrid::new(base);
    grid.windows = vec![WindowKind::Static(4), WindowKind::FusedOnly];
    grid.rtt_ms = rtt_points();
    grid.seeds = seeds.to_vec();
    grid.streaming = ctx.streaming;
    let (cells, stats) = run_grid_cached(&grid, ctx.threads, ctx.cache).expect("fig6 grid");
    ctx.absorb_stats(stats);
    if ctx.cache.is_some() {
        eprintln!("[fig6] {}", stats.describe());
    }
    // Select cells by their axis labels (robust to any change in the
    // grid's expansion order) and average the seed replicas.
    let series = |wname: &str| -> Series {
        rtt_points()
            .into_iter()
            .map(|rtt| {
                let rtt_s = format!("{rtt}");
                let chunk: Vec<&CellResult> = cells
                    .iter()
                    .filter(|c| {
                        c.label("window") == Some(wname) && c.label("rtt_ms") == Some(&rtt_s)
                    })
                    .collect();
                assert_eq!(chunk.len(), seeds.len(), "fig6: missing cells for {wname}@{rtt_s}");
                let avg = |f: &dyn Fn(&CellResult) -> f64| {
                    mean(&chunk.iter().map(|c| f(c)).collect::<Vec<_>>())
                };
                (
                    rtt,
                    avg(&|c| c.metrics().throughput_rps),
                    avg(&|c| c.metrics().mean_ttft_ms),
                    avg(&|c| c.metrics().mean_tpot_ms),
                )
            })
            .collect()
    };
    (
        series(&window_label(&WindowKind::Static(4))),
        series(&window_label(&WindowKind::FusedOnly)),
    )
}

/// The RTT (midpoint) where distributed TPOT first exceeds fused TPOT,
/// if any — the paper's crossover diagnostic.
pub fn crossover_rtt(distributed: &Series, fused: &Series) -> Option<f64> {
    for (d, f) in distributed.iter().zip(fused) {
        if d.3 > f.3 {
            return Some(d.0);
        }
    }
    None
}

/// Run and render.
pub fn run(scale: Scale, seeds: &[u64]) -> String {
    run_cached(scale, seeds, &ExpContext::default())
}

/// [`run`] on an explicit runner context (`dsd reproduce --cache-dir`).
pub fn run_cached(scale: Scale, seeds: &[u64], ctx: &ExpContext) -> String {
    let (dist, fused) = sweep_cached(scale, seeds, ctx);
    let mut table = Table::new(&[
        "RTT ms",
        "dist tput",
        "fused tput",
        "dist TTFT",
        "fused TTFT",
        "dist TPOT",
        "fused TPOT",
    ])
    .with_title("Fig 6 — distributed (purple) vs fused (green) across RTT");
    let mut rows = Vec::new();
    for (d, f) in dist.iter().zip(&fused) {
        table.row(vec![
            fnum(d.0, 0),
            fnum(d.1, 1),
            fnum(f.1, 1),
            fnum(d.2, 0),
            fnum(f.2, 0),
            fnum(d.3, 1),
            fnum(f.3, 1),
        ]);
        rows.push(Row {
            exp: "fig6".into(),
            labels: vec![("rtt_ms".into(), format!("{}", d.0))],
            values: vec![
                ("dist_tput".into(), d.1),
                ("fused_tput".into(), f.1),
                ("dist_ttft".into(), d.2),
                ("fused_ttft".into(), f.2),
                ("dist_tpot".into(), d.3),
                ("fused_tpot".into(), f.3),
            ],
        });
    }
    save_rows("fig6", &rows);
    let mut out = table.render();
    match crossover_rtt(&dist, &fused) {
        Some(x) => out.push_str(&format!(
            "\nTPOT crossover at ≈{x:.0} ms RTT (paper: 50–60 ms)\n"
        )),
        None => out.push_str("\nno crossover within the sweep\n"),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributed_degrades_fused_flat() {
        // Full request count: tiny runs make fused residency (and with
        // it TPOT) depend on arrival staggering, masking the signal.
        let (dist, fused) = sweep(Scale(1.0), &[3]);
        let d_lo = dist.first().unwrap().3;
        let d_hi = dist.last().unwrap().3;
        assert!(d_hi > d_lo * 1.25, "distributed TPOT must grow: {d_lo} -> {d_hi}");
        // Fused work never crosses the link per token; the residual
        // variation at tiny scale comes from arrival staggering changing
        // resident batch sizes, not from the network itself.
        let f_lo = fused.first().unwrap().3;
        let f_hi = fused.last().unwrap().3;
        assert!(
            (f_hi - f_lo).abs() < f_lo * 0.25,
            "fused TPOT must stay ~flat: {f_lo} -> {f_hi}"
        );
        // And fused must not *degrade* with RTT (the paper's claim).
        assert!(f_hi < f_lo * 1.25);
    }

    #[test]
    fn distributed_wins_at_low_rtt() {
        let (dist, fused) = sweep(Scale(1.0), &[3]);
        // At the lowest RTT the distributed system must not lose on
        // throughput (the paper's low-RTT regime).
        assert!(
            dist[0].1 >= fused[0].1 * 0.95,
            "dist {} vs fused {}",
            dist[0].1,
            fused[0].1
        );
    }
}
