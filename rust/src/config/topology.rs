//! The `auto_topology` pass (paper §3.1): expand pool slices from the
//! configuration into explicit drafter and target device lists with fully
//! defined network connections — including per-drafter link parameters
//! when drafter pools carry [`LinkOverride`]s (heterogeneous edge
//! networks: fiber racks next to cellular devices in one deployment).

use super::schema::{LinkOverride, NetworkConfig, SimConfig};
use crate::cluster::{DeviceInstance, DevicePool, Role};

/// Fully resolved edge→cloud link parameters for one drafter — the same
/// shape (and serialization semantics) as the global [`NetworkConfig`],
/// just resolved per pool.
pub type LinkSpec = NetworkConfig;

/// Resolve an optional per-pool override against the global network
/// config.
fn resolve_link(net: &NetworkConfig, ov: Option<&LinkOverride>) -> LinkSpec {
    LinkSpec {
        rtt_ms: ov.and_then(|o| o.rtt_ms).unwrap_or(net.rtt_ms),
        jitter_ms: ov.and_then(|o| o.jitter_ms).unwrap_or(net.jitter_ms),
        bandwidth_mbps: ov
            .and_then(|o| o.bandwidth_mbps)
            .unwrap_or(net.bandwidth_mbps),
    }
}

/// Fully expanded deployment topology.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Cloud pool (targets), ids 0..n_targets.
    pub targets: DevicePool,
    /// Edge pool (drafters), ids 0..n_drafters.
    pub drafters: DevicePool,
    /// Per-drafter resolved links, parallel to `drafters.devices`.
    pub links: Vec<LinkSpec>,
    /// Global network defaults: the fallback for synthetic drafter ids
    /// (fused-only deployments with zero drafters) and the cold-start
    /// RTT prior for window-policy features.
    default_link: LinkSpec,
}

impl Topology {
    /// Expand a [`SimConfig`] into explicit device pools.
    pub fn expand(cfg: &SimConfig) -> Result<Topology, String> {
        let mut targets = DevicePool::default();
        for p in &cfg.target_pools {
            for _ in 0..p.count {
                targets.add(Role::Target, p.gpu, p.tp, p.model);
            }
        }
        let mut drafters = DevicePool::default();
        let mut links = Vec::new();
        for p in &cfg.drafter_pools {
            let link = resolve_link(&cfg.network, p.link.as_ref());
            for _ in 0..p.count {
                drafters.add(Role::Drafter, p.gpu, p.tp, p.model);
                links.push(link);
            }
        }
        targets.validate()?;
        drafters.validate()?;
        Ok(Topology {
            targets,
            drafters,
            links,
            default_link: cfg.network,
        })
    }

    /// Target device by id.
    pub fn target(&self, id: usize) -> &DeviceInstance {
        &self.targets.devices[id]
    }

    /// Drafter device by id.
    pub fn drafter(&self, id: usize) -> &DeviceInstance {
        &self.drafters.devices[id]
    }

    /// Resolved link for a drafter id (global defaults when the id is
    /// synthetic, e.g. fused-only runs with an empty edge pool).
    pub fn link(&self, drafter_id: usize) -> &LinkSpec {
        self.links.get(drafter_id).unwrap_or(&self.default_link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn expansion_counts_and_order() {
        let y = "\
cluster:
  targets:
    - count: 2
      gpu: a100
      tp: 4
      model: llama2-70b
    - count: 3
      gpu: h100
      tp: 4
      model: qwen-72b
  drafters:
    - count: 5
      gpu: a40
      model: llama2-7b
";
        let cfg = SimConfig::from_yaml(y).unwrap();
        let topo = Topology::expand(&cfg).unwrap();
        assert_eq!(topo.targets.len(), 5);
        assert_eq!(topo.drafters.len(), 5);
        // Pool slices expand in order; ids are stable.
        assert_eq!(topo.target(0).gpu.name, "A100");
        assert_eq!(topo.target(2).gpu.name, "H100");
        assert_eq!(topo.target(4).id, 4);
    }

    #[test]
    fn memory_violations_caught() {
        // 70B on a single A40 does not fit.
        let y = "\
cluster:
  targets:
    - count: 1
      gpu: a40
      tp: 1
      model: llama2-70b
";
        let cfg = SimConfig::from_yaml(y).unwrap();
        assert!(Topology::expand(&cfg).is_err());
    }

    #[test]
    fn per_pool_links_expand_in_order() {
        let y = "\
cluster:
  targets:
    - count: 1
      gpu: a100
      tp: 4
      model: llama2-70b
  drafters:
    - count: 2
      gpu: a40
      model: llama2-7b
      rtt_ms: 80
      bandwidth_mbps: 20
    - count: 3
      gpu: v100
      model: qwen-7b
network:
  rtt_ms: 10
  jitter_ms: 0.5
";
        let cfg = SimConfig::from_yaml(y).unwrap();
        let topo = Topology::expand(&cfg).unwrap();
        assert_eq!(topo.links.len(), 5);
        // Overridden slice: RTT and bandwidth from the pool, jitter
        // inherited from the global network section.
        assert_eq!(topo.link(0).rtt_ms, 80.0);
        assert_eq!(topo.link(1).bandwidth_mbps, 20.0);
        assert_eq!(topo.link(0).jitter_ms, 0.5);
        // Plain slice inherits everything.
        assert_eq!(topo.link(2).rtt_ms, 10.0);
        assert!(topo.link(4).bandwidth_mbps.is_infinite());
        // Out-of-range id falls back to the global defaults.
        assert_eq!(topo.link(99).rtt_ms, 10.0);
    }

    #[test]
    fn fused_only_zero_drafters_has_default_link() {
        use crate::config::WindowKind;
        let cfg = SimConfig::builder()
            .drafters(0)
            .window(WindowKind::FusedOnly)
            .rtt_ms(25.0)
            .build();
        let topo = Topology::expand(&cfg).unwrap();
        assert!(topo.links.is_empty());
        assert_eq!(topo.link(0).rtt_ms, 25.0);
    }
}
