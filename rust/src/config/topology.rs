//! The `auto_topology` pass (paper §3.1): expand pool slices from the
//! configuration into explicit drafter and target device lists with fully
//! defined network connections.

use super::schema::SimConfig;
use crate::cluster::{DeviceInstance, DevicePool, Role};

/// Fully expanded deployment topology.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Cloud pool (targets), ids 0..n_targets.
    pub targets: DevicePool,
    /// Edge pool (drafters), ids 0..n_drafters.
    pub drafters: DevicePool,
    /// Edge→cloud RTT, ms (all links share the config's RTT/jitter model;
    /// per-link heterogeneity enters through jitter draws at send time).
    pub rtt_ms: f64,
    /// Jitter std-dev, ms.
    pub jitter_ms: f64,
}

impl Topology {
    /// Expand a [`SimConfig`] into explicit device pools.
    pub fn expand(cfg: &SimConfig) -> Result<Topology, String> {
        let mut targets = DevicePool::default();
        for p in &cfg.target_pools {
            for _ in 0..p.count {
                targets.add(Role::Target, p.gpu, p.tp, p.model);
            }
        }
        let mut drafters = DevicePool::default();
        for p in &cfg.drafter_pools {
            for _ in 0..p.count {
                drafters.add(Role::Drafter, p.gpu, p.tp, p.model);
            }
        }
        targets.validate()?;
        drafters.validate()?;
        Ok(Topology {
            targets,
            drafters,
            rtt_ms: cfg.network.rtt_ms,
            jitter_ms: cfg.network.jitter_ms,
        })
    }

    /// Target device by id.
    pub fn target(&self, id: usize) -> &DeviceInstance {
        &self.targets.devices[id]
    }

    /// Drafter device by id.
    pub fn drafter(&self, id: usize) -> &DeviceInstance {
        &self.drafters.devices[id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn expansion_counts_and_order() {
        let y = "\
cluster:
  targets:
    - count: 2
      gpu: a100
      tp: 4
      model: llama2-70b
    - count: 3
      gpu: h100
      tp: 4
      model: qwen-72b
  drafters:
    - count: 5
      gpu: a40
      model: llama2-7b
";
        let cfg = SimConfig::from_yaml(y).unwrap();
        let topo = Topology::expand(&cfg).unwrap();
        assert_eq!(topo.targets.len(), 5);
        assert_eq!(topo.drafters.len(), 5);
        // Pool slices expand in order; ids are stable.
        assert_eq!(topo.target(0).gpu.name, "A100");
        assert_eq!(topo.target(2).gpu.name, "H100");
        assert_eq!(topo.target(4).id, 4);
    }

    #[test]
    fn memory_violations_caught() {
        // 70B on a single A40 does not fit.
        let y = "\
cluster:
  targets:
    - count: 1
      gpu: a40
      tp: 1
      model: llama2-70b
";
        let cfg = SimConfig::from_yaml(y).unwrap();
        assert!(Topology::expand(&cfg).is_err());
    }
}
