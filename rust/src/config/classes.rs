//! Multi-tenant request classes: per-class arrival processes, SLO
//! tiers, and priority-aware serving knobs (the ROADMAP's multi-tenant
//! gateway item; paper framing: "agile serving" of heterogeneous
//! workloads sharing one edge–cloud deployment).
//!
//! A [`ClassesConfig`] attaches to a [`SimConfig`](crate::config::SimConfig)
//! via the `classes:` YAML block:
//!
//! ```yaml
//! classes:
//!   priority_admission: true
//!   defer_batch_threshold: 12
//!   tiers:
//!     - name: interactive
//!       rate_per_s: 20
//!       slo:
//!         ttft_ms: 1000
//!         tpot_ms: 50
//!     - name: batch
//!       arrivals:
//!         kind: spike
//!         base_per_s: 5
//!         peak_per_s: 80
//!         t_start_ms: 20000
//!         t_end_ms: 40000
//! ```
//!
//! Tier declaration order **is** priority order: tier 0 is served first
//! under priority admission, the last tier is deferred under backlog
//! pressure. Each tier carries its own [`ArrivalProcess`] (the global
//! `workload.rate_per_s` is unused when classes are present — every
//! arrival belongs to exactly one tier) and its own [`SloSpec`];
//! `workload.requests` remains the *total* request count, split across
//! tiers by merging their arrival streams in time order.
//!
//! Like the `scenario:` and `autoscale:` blocks, an absent `classes:`
//! block leaves the canonical JSON — and therefore every sweep cache
//! key — byte-identical to the class-free simulator.

use crate::metrics::SloSpec;
use crate::scenario::{ArrivalPlan, ArrivalProcess, Scenario, ScenarioEvent};
use crate::util::json::Json;
use crate::util::yaml;

/// One request class (SLO tier): a name, an arrival process, and the
/// SLO thresholds its traffic is evaluated against.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassSpec {
    /// Tier name (unique within the block; also the label scenario
    /// `class_rate_override` events target).
    pub name: String,
    /// The tier's own arrival process.
    pub arrivals: ArrivalProcess,
    /// SLO thresholds for this tier's attainment counters.
    pub slo: SloSpec,
}

/// The `classes:` block: an ordered list of SLO tiers plus the
/// priority-serving knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassesConfig {
    /// Block name (sweep axis label; defaults to `"classes"`, or the
    /// file stem when loaded via [`ClassesConfig::from_yaml_file`]).
    pub name: String,
    /// SLO tiers in priority order (tier 0 served first).
    pub tiers: Vec<ClassSpec>,
    /// Reorder target queues so higher-priority classes are admitted to
    /// batches first (stable within a class — FIFO order is preserved).
    pub priority_admission: bool,
    /// When set, batch formation skips lowest-tier work whenever the
    /// target's queued top-tier backlog exceeds this many requests (the
    /// deferral never empties an otherwise non-empty batch).
    pub defer_batch_threshold: Option<usize>,
}

const KNOWN: [&str; 4] = ["name", "priority_admission", "defer_batch_threshold", "tiers"];
const TIER_KNOWN: [&str; 4] = ["name", "rate_per_s", "arrivals", "slo"];

impl ClassesConfig {
    /// Parse a classes YAML document (the standalone-file form of the
    /// `classes:` block).
    pub fn from_yaml(text: &str) -> Result<ClassesConfig, String> {
        let doc = yaml::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&doc)
    }

    /// Load from a YAML file; the file stem becomes the name when the
    /// document has no `name:` key, and relative resource paths (a
    /// `kind: trace` tier arrival's timestamp file) resolve against the
    /// file's directory.
    pub fn from_yaml_file(path: &str) -> Result<ClassesConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let mut c = Self::from_yaml(&text)?;
        if c.name == "classes" {
            if let Some(stem) = std::path::Path::new(path)
                .file_stem()
                .and_then(|x| x.to_str())
            {
                c.name = stem.to_string();
            }
        }
        let base = std::path::Path::new(path)
            .parent()
            .unwrap_or(std::path::Path::new("."));
        c.resolve_paths(base)?;
        Ok(c)
    }

    /// Resolve (and load) file-backed tier arrival resources; relative
    /// paths resolve against `base_dir`.
    pub fn resolve_paths(&mut self, base_dir: &std::path::Path) -> Result<(), String> {
        for t in &mut self.tiers {
            t.arrivals.resolve_paths(base_dir)?;
        }
        Ok(())
    }

    /// Parse from a decoded document (the `classes:` block of a
    /// `SimConfig` shares this schema). Strict: unknown keys are
    /// rejected so a typo'd knob cannot silently neutralize a tier
    /// while still labeling and cache-keying the cell.
    pub fn from_json(doc: &Json) -> Result<ClassesConfig, String> {
        if let Json::Obj(pairs) = doc {
            for (k, _) in pairs {
                if !KNOWN.contains(&k.as_str()) {
                    return Err(format!(
                        "classes: unknown key '{k}' (known: {})",
                        KNOWN.join(", ")
                    ));
                }
            }
        } else {
            return Err("classes: expected a mapping".into());
        }
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("classes")
            .to_string();
        let priority_admission = match doc.get("priority_admission") {
            None => true,
            Some(v) => v
                .as_bool()
                .ok_or("classes: priority_admission must be a boolean")?,
        };
        let defer_batch_threshold = match doc.get("defer_batch_threshold") {
            None => None,
            Some(v) => Some(
                v.as_usize()
                    .ok_or("classes: defer_batch_threshold must be a non-negative integer")?,
            ),
        };
        let tier_list = doc
            .get("tiers")
            .ok_or("classes: missing 'tiers' list")?
            .as_arr()
            .ok_or("classes: 'tiers' must be a list")?;
        let mut tiers = Vec::with_capacity(tier_list.len());
        for t in tier_list {
            tiers.push(Self::tier_from_json(t)?);
        }
        let cfg = ClassesConfig { name, tiers, priority_admission, defer_batch_threshold };
        cfg.validate()?;
        Ok(cfg)
    }

    fn tier_from_json(j: &Json) -> Result<ClassSpec, String> {
        if let Json::Obj(pairs) = j {
            for (k, _) in pairs {
                if !TIER_KNOWN.contains(&k.as_str()) {
                    return Err(format!(
                        "classes tier: unknown key '{k}' (known: {})",
                        TIER_KNOWN.join(", ")
                    ));
                }
            }
        } else {
            return Err("classes tier: expected a mapping".into());
        }
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("classes tier: missing 'name'")?
            .to_string();
        let arrivals = match (j.get("rate_per_s"), j.get("arrivals")) {
            (Some(r), None) => ArrivalProcess::Constant {
                rate_per_s: r
                    .as_f64()
                    .ok_or_else(|| format!("classes tier '{name}': rate_per_s must be a number"))?,
            },
            (None, Some(a)) => ArrivalProcess::from_json(a)
                .map_err(|e| format!("classes tier '{name}': {e}"))?,
            (Some(_), Some(_)) => {
                return Err(format!(
                    "classes tier '{name}': give either rate_per_s or arrivals, not both"
                ))
            }
            (None, None) => {
                return Err(format!(
                    "classes tier '{name}': missing arrival process (rate_per_s or arrivals)"
                ))
            }
        };
        let slo = match j.get("slo") {
            None => SloSpec::RELAXED,
            Some(s) => {
                if let Json::Obj(pairs) = s {
                    for (k, _) in pairs {
                        if k != "ttft_ms" && k != "tpot_ms" {
                            return Err(format!(
                                "classes tier '{name}': unknown slo key '{k}' (known: \
                                 ttft_ms, tpot_ms)"
                            ));
                        }
                    }
                } else {
                    return Err(format!("classes tier '{name}': slo must be a mapping"));
                }
                SloSpec {
                    ttft_ms: s
                        .get("ttft_ms")
                        .and_then(Json::as_f64)
                        .unwrap_or(SloSpec::RELAXED.ttft_ms),
                    tpot_ms: s
                        .get("tpot_ms")
                        .and_then(Json::as_f64)
                        .unwrap_or(SloSpec::RELAXED.tpot_ms),
                }
            }
        };
        Ok(ClassSpec { name, arrivals, slo })
    }

    /// Canonical JSON: fixed key order, tiers in priority order. Part
    /// of [`SimConfig::to_canonical_json`](crate::config::SimConfig) —
    /// and therefore of the sweep cell cache key — whenever a classes
    /// block is attached. Class-free configs serialize exactly as
    /// before (no `classes` key at all).
    pub fn to_canonical_json(&self) -> Json {
        let mut j = Json::obj()
            .with("name", self.name.as_str().into())
            .with("priority_admission", self.priority_admission.into());
        if let Some(th) = self.defer_batch_threshold {
            j.set("defer_batch_threshold", th.into());
        }
        j.with(
            "tiers",
            Json::Arr(
                self.tiers
                    .iter()
                    .map(|t| {
                        Json::obj()
                            .with("name", t.name.as_str().into())
                            .with("arrivals", t.arrivals.to_canonical_json())
                            .with(
                                "slo",
                                Json::obj()
                                    .with("ttft_ms", t.slo.ttft_ms.into())
                                    .with("tpot_ms", t.slo.tpot_ms.into()),
                            )
                    })
                    .collect(),
            ),
        )
    }

    /// Number of declared tiers.
    pub fn n_classes(&self) -> usize {
        self.tiers.len()
    }

    /// Index of a tier by name.
    pub fn class_index(&self, name: &str) -> Option<usize> {
        self.tiers.iter().position(|t| t.name == name)
    }

    /// `(name, slo)` list in priority order — the per-class breakdown
    /// configuration both metric sinks consume.
    pub fn slo_list(&self) -> Vec<(String, SloSpec)> {
        self.tiers.iter().map(|t| (t.name.clone(), t.slo)).collect()
    }

    /// Per-tier arrival plans: each tier's process plus every scenario
    /// `class_rate_override` event naming that tier folded into its
    /// envelope (validated against declared names in
    /// [`SimConfig::validate`](crate::config::SimConfig)).
    pub fn plans(&self, scenario: Option<&Scenario>) -> Vec<ArrivalPlan> {
        self.tiers
            .iter()
            .map(|t| {
                let overrides = scenario
                    .map(|s| {
                        s.events
                            .iter()
                            .filter_map(|e| match &e.event {
                                ScenarioEvent::ClassRateOverride { class, rate_per_s }
                                    if *class == t.name =>
                                {
                                    Some((e.at_ms, *rate_per_s))
                                }
                                _ => None,
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                ArrivalPlan { process: t.arrivals.clone(), overrides }
            })
            .collect()
    }

    /// Sanity checks (shape-level; cross-checks against the owning
    /// config — trace workloads, scenario arrivals — live in
    /// [`SimConfig::validate`](crate::config::SimConfig)).
    pub fn validate(&self) -> Result<(), String> {
        if self.tiers.is_empty() {
            return Err("classes: at least one tier required".into());
        }
        for (i, t) in self.tiers.iter().enumerate() {
            if t.name.is_empty() {
                return Err(format!("classes: tier {i} has an empty name"));
            }
            if self.tiers[..i].iter().any(|u| u.name == t.name) {
                return Err(format!("classes: duplicate tier name '{}'", t.name));
            }
            t.arrivals
                .validate()
                .map_err(|e| format!("classes tier '{}': {e}", t.name))?;
            let bad = |x: f64| !x.is_finite() || x <= 0.0;
            if bad(t.slo.ttft_ms) || bad(t.slo.tpot_ms) {
                return Err(format!(
                    "classes tier '{}': slo thresholds must be finite and positive",
                    t.name
                ));
            }
        }
        if self.defer_batch_threshold.is_some() && self.tiers.len() < 2 {
            return Err(
                "classes: defer_batch_threshold requires at least two tiers (it defers \
                 the lowest tier in favor of the highest)"
                    .into(),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FAIR: &str = "\
name: fair
priority_admission: true
defer_batch_threshold: 12
tiers:
  - name: interactive
    rate_per_s: 20
    slo:
      ttft_ms: 1000
      tpot_ms: 50
  - name: batch
    arrivals:
      kind: spike
      base_per_s: 5
      peak_per_s: 80
      t_start_ms: 20000
      t_end_ms: 40000
";

    #[test]
    fn yaml_parses_tiers_in_priority_order() {
        let c = ClassesConfig::from_yaml(FAIR).unwrap();
        assert_eq!(c.name, "fair");
        assert!(c.priority_admission);
        assert_eq!(c.defer_batch_threshold, Some(12));
        assert_eq!(c.n_classes(), 2);
        assert_eq!(c.tiers[0].name, "interactive");
        assert_eq!(
            c.tiers[0].arrivals,
            ArrivalProcess::Constant { rate_per_s: 20.0 }
        );
        assert_eq!(c.tiers[0].slo, SloSpec { ttft_ms: 1_000.0, tpot_ms: 50.0 });
        // Tier without an slo block gets the relaxed default.
        assert_eq!(c.tiers[1].slo, SloSpec::RELAXED);
        assert!(matches!(c.tiers[1].arrivals, ArrivalProcess::Spike { .. }));
        assert_eq!(c.class_index("batch"), Some(1));
        assert_eq!(c.class_index("bulk"), None);
    }

    #[test]
    fn canonical_json_roundtrip_is_stable() {
        let c = ClassesConfig::from_yaml(FAIR).unwrap();
        let j = c.to_canonical_json();
        let back = ClassesConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
        assert_eq!(
            j.to_string_canonical(),
            back.to_canonical_json().to_string_canonical()
        );
        // Threshold-free blocks omit the key entirely.
        let mut bare = c.clone();
        bare.defer_batch_threshold = None;
        assert!(!bare
            .to_canonical_json()
            .to_string_canonical()
            .contains("defer_batch_threshold"));
    }

    #[test]
    fn strict_keys_and_shapes_rejected() {
        assert!(ClassesConfig::from_yaml("tiersz: []\n")
            .unwrap_err()
            .contains("unknown key"));
        assert!(ClassesConfig::from_yaml("name: x\n")
            .unwrap_err()
            .contains("tiers"));
        let typo = FAIR.replace("rate_per_s: 20", "rate_pers: 20");
        assert!(ClassesConfig::from_yaml(&typo).unwrap_err().contains("unknown key"));
        let slo_typo = FAIR.replace("ttft_ms: 1000", "ttft: 1000");
        assert!(ClassesConfig::from_yaml(&slo_typo)
            .unwrap_err()
            .contains("unknown slo key"));
        // Both or neither arrival forms are rejected.
        let both = "\
tiers:
  - name: a
    rate_per_s: 5
    arrivals:
      kind: constant
      rate_per_s: 5
  - name: b
    rate_per_s: 5
";
        assert!(ClassesConfig::from_yaml(both).unwrap_err().contains("not both"));
        let neither = "tiers:\n  - name: a\n";
        assert!(ClassesConfig::from_yaml(neither)
            .unwrap_err()
            .contains("missing arrival process"));
    }

    #[test]
    fn validation_rejects_bad_blocks() {
        let dup = "\
tiers:
  - name: a
    rate_per_s: 5
  - name: a
    rate_per_s: 6
";
        assert!(ClassesConfig::from_yaml(dup).unwrap_err().contains("duplicate"));
        let bad_rate = "tiers:\n  - name: a\n    rate_per_s: -2\n";
        assert!(ClassesConfig::from_yaml(bad_rate).is_err());
        let bad_slo = "\
tiers:
  - name: a
    rate_per_s: 5
    slo:
      ttft_ms: 0
";
        assert!(ClassesConfig::from_yaml(bad_slo)
            .unwrap_err()
            .contains("finite and positive"));
        let single_defer = "\
defer_batch_threshold: 4
tiers:
  - name: a
    rate_per_s: 5
";
        assert!(ClassesConfig::from_yaml(single_defer)
            .unwrap_err()
            .contains("at least two tiers"));
    }

    #[test]
    fn plans_fold_class_rate_overrides_per_tier() {
        use crate::scenario::TimedEvent;
        let c = ClassesConfig::from_yaml(FAIR).unwrap();
        let s = Scenario {
            name: "s".into(),
            arrivals: None,
            events: vec![
                TimedEvent {
                    at_ms: 8_000.0,
                    event: ScenarioEvent::ClassRateOverride {
                        class: "batch".into(),
                        rate_per_s: 2.0,
                    },
                },
                TimedEvent {
                    at_ms: 9_000.0,
                    event: ScenarioEvent::ClassRateOverride {
                        class: "interactive".into(),
                        rate_per_s: 44.0,
                    },
                },
            ],
        };
        let plans = c.plans(Some(&s));
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].overrides, vec![(9_000.0, 44.0)]);
        assert_eq!(plans[1].overrides, vec![(8_000.0, 2.0)]);
        // No scenario → no overrides.
        let bare = c.plans(None);
        assert!(bare.iter().all(|p| p.overrides.is_empty()));
    }

    #[test]
    fn file_stem_names_the_block() {
        let dir = std::env::temp_dir().join(format!("dsd-classes-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("two_tier.yaml");
        std::fs::write(&path, FAIR.replace("name: fair\n", "")).unwrap();
        let c = ClassesConfig::from_yaml_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.name, "two_tier");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
