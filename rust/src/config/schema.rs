//! Typed simulation configuration and its YAML ingestion.
//!
//! Mirrors the paper's configuration parser (§3.1): device types, network
//! links (RTT, jitter), and runtime policies, in a YAML file; the
//! `auto_topology` pass ([`crate::config::topology`]) expands it into
//! explicit device pools.

use crate::autoscale::AutoscaleConfig;
use crate::cluster::{gpu_by_name, model_by_name, GpuSpec, ModelSpec};
use crate::config::classes::ClassesConfig;
use crate::scenario::Scenario;
use crate::specdec::ExecutionMode;
use crate::util::json::Json;
use crate::util::yaml;

/// Routing policy selector (paper §3.4, "Request Routing Policy").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingKind {
    /// Uniform random target choice.
    Random,
    /// Round-robin over targets.
    RoundRobin,
    /// Join-the-Shortest-Queue.
    Jsq,
}

/// Batching policy selector (paper §3.4, "Batching Policy").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchingKind {
    /// First-in-first-out batch formation.
    Fifo,
    /// Length-aware batching: head-of-line request grouped with
    /// similar-length peers (ORCA/Sarathi-style).
    Lab,
}

/// Window-size policy selector (paper §3.4, "Window Size Policy").
#[derive(Clone, Debug, PartialEq)]
pub enum WindowKind {
    /// Fixed γ.
    Static(u32),
    /// Threshold heuristic: γ+1 when recent acceptance > hi, γ−1 when
    /// below lo (paper §5.2 baseline: hi = 0.75, lo = 0.25).
    Dynamic { init: u32, lo: f64, hi: f64 },
    /// Adaptive Window Control — the learned controller (paper §4).
    /// `weights_path = None` uses the embedded pretrained weights.
    Awc { weights_path: Option<String> },
    /// Cloud-only execution (no speculation) — the "fused" baseline of
    /// Fig. 6.
    FusedOnly,
}

/// Per-pool network link override. Drafter pools may sit behind very
/// different access networks (fiber-attached edge racks vs cellular
/// devices); any field left `None` inherits the global [`NetworkConfig`].
/// Overrides on target pools are accepted but unused: targets share the
/// cloud fabric, links are modelled drafter-side.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkOverride {
    /// Round-trip time to the cloud, ms.
    pub rtt_ms: Option<f64>,
    /// Jitter std-dev, ms.
    pub jitter_ms: Option<f64>,
    /// Link bandwidth, Mbit/s (serialization delay of shipped payloads).
    pub bandwidth_mbps: Option<f64>,
}

impl LinkOverride {
    /// Whether every field is unset.
    pub fn is_empty(&self) -> bool {
        self.rtt_ms.is_none() && self.jitter_ms.is_none() && self.bandwidth_mbps.is_none()
    }
}

/// One homogeneous slice of a device pool.
#[derive(Clone, Debug)]
pub struct PoolSpec {
    /// Number of devices in this slice.
    pub count: usize,
    /// GPU SKU.
    pub gpu: &'static GpuSpec,
    /// Tensor-parallel degree per device.
    pub tp: u32,
    /// Hosted model.
    pub model: &'static ModelSpec,
    /// Optional per-pool link override (heterogeneous edge networks).
    pub link: Option<LinkOverride>,
}

/// Edge–cloud network link model: per-direction delay is
/// `rtt/2 + |N(0, jitter)| + payload_bits / bandwidth`.
#[derive(Clone, Copy, Debug)]
pub struct NetworkConfig {
    /// Round-trip time, ms.
    pub rtt_ms: f64,
    /// Jitter std-dev, ms.
    pub jitter_ms: f64,
    /// Link bandwidth, Mbit/s. Non-finite (the default) disables the
    /// serialization-delay term, matching the pre-bandwidth model.
    pub bandwidth_mbps: f64,
}

/// Workload source.
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Benchmark profile name (gsm8k / cnndm / humaneval).
    pub dataset: String,
    /// Number of requests (synthetic mode).
    pub requests: usize,
    /// Global Poisson arrival rate, requests/second (synthetic mode).
    pub rate_per_s: f64,
    /// Optional trace file (trace-driven mode overrides synthetic).
    pub trace_path: Option<String>,
}

/// Batch formation knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchKnobs {
    /// Max sequences per verify batch.
    pub decode_batch: usize,
    /// Max sequences per *fused-mode* decode batch. Smaller than the
    /// verify cap: in fused mode the server co-hosts the draft model
    /// (paper §3.3), so usable KV-cache memory — and with it the decode
    /// batch — is roughly halved relative to a verification-only server.
    pub fused_batch: usize,
    /// Max requests per prefill batch.
    pub prefill_batch: usize,
    /// How long a server waits to accumulate a batch, ms.
    pub window_ms: f64,
}

impl Default for BatchKnobs {
    fn default() -> Self {
        BatchKnobs {
            decode_batch: 32,
            fused_batch: 8,
            prefill_batch: 8,
            window_ms: 2.0,
        }
    }
}

/// Complete simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Root RNG seed; every stochastic element forks from it.
    pub seed: u64,
    /// Cloud pool slices.
    pub target_pools: Vec<PoolSpec>,
    /// Edge pool slices.
    pub drafter_pools: Vec<PoolSpec>,
    /// Edge–cloud link.
    pub network: NetworkConfig,
    /// Routing policy.
    pub routing: RoutingKind,
    /// Batching policy.
    pub batching: BatchingKind,
    /// Window-size policy.
    pub window: WindowKind,
    /// Batch formation knobs.
    pub batch: BatchKnobs,
    /// Workload.
    pub workload: WorkloadConfig,
    /// Hard stop for simulated time, ms (safety net).
    pub max_sim_ms: f64,
    /// Optional scripted dynamics: a time-varying arrival process and a
    /// timeline of link/device/load events (see [`crate::scenario`]).
    /// `None` reproduces the static pre-scenario simulator bit for bit.
    pub scenario: Option<Scenario>,
    /// Optional elastic target pool (see [`crate::autoscale`]):
    /// `cluster.targets` then declares the *physical* fleet and the
    /// autoscale policy chooses how much of it is provisioned over
    /// time. `None` reproduces the fixed-fleet simulator bit for bit.
    pub autoscale: Option<AutoscaleConfig>,
    /// Optional multi-tenant request classes (see
    /// [`crate::config::classes`]): per-class arrival processes and SLO
    /// tiers plus priority-aware serving. `None` reproduces the
    /// single-tenant simulator bit for bit.
    pub classes: Option<ClassesConfig>,
    /// Round execution mode (see [`ExecutionMode`]). `Sequential` — the
    /// default, and what an absent `execution:` key means — reproduces
    /// the pre-execution-mode simulator bit for bit; `Pipelined`
    /// overlaps drafting of window k+1 with verification of window k.
    pub execution: ExecutionMode,
    /// Opt-in: clamp out-of-range trace `class_id`s to the last declared
    /// tier instead of rejecting the trace at load time. Off (the
    /// default, and what an absent key means) a record whose class id
    /// exceeds the declared tier count fails `Simulator::try_new` with a
    /// named error.
    pub clamp_trace_class_ids: bool,
}

impl SimConfig {
    /// Start building a config with sensible defaults.
    pub fn builder() -> SimConfigBuilder {
        SimConfigBuilder::default()
    }

    /// Parse a YAML deployment description (see `configs/*.yaml`).
    pub fn from_yaml(text: &str) -> Result<SimConfig, String> {
        let doc = yaml::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&doc)
    }

    /// Load from a YAML file. Relative resource paths inside the
    /// document — currently the `kind: trace` arrival envelope's
    /// timestamp file — resolve against the config file's directory.
    pub fn from_yaml_file(path: &str) -> Result<SimConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let mut cfg = Self::from_yaml(&text)?;
        let base = std::path::Path::new(path)
            .parent()
            .unwrap_or(std::path::Path::new("."))
            .to_path_buf();
        if let Some(s) = &mut cfg.scenario {
            s.resolve_paths(&base)?;
        }
        if let Some(c) = &mut cfg.classes {
            c.resolve_paths(&base)?;
        }
        Ok(cfg)
    }

    /// Parse from an already-decoded document (the sweep grid embeds a
    /// `base:` section with this schema).
    pub fn from_json(doc: &Json) -> Result<SimConfig, String> {
        let mut b = SimConfig::builder();
        if let Some(seed) = doc.get("seed").and_then(Json::as_u64) {
            b = b.seed(seed);
        }
        if let Some(cluster) = doc.get("cluster") {
            if let Some(ts) = cluster.get("targets").and_then(Json::as_arr) {
                b.cfg.target_pools = ts
                    .iter()
                    .map(|p| parse_pool(p, 4, "llama2-70b", "a100"))
                    .collect::<Result<_, _>>()?;
            }
            if let Some(ds) = cluster.get("drafters").and_then(Json::as_arr) {
                b.cfg.drafter_pools = ds
                    .iter()
                    .map(|p| parse_pool(p, 1, "llama2-7b", "a40"))
                    .collect::<Result<_, _>>()?;
            }
        }
        if let Some(net) = doc.get("network") {
            if let Some(x) = net.get("rtt_ms").and_then(Json::as_f64) {
                b.cfg.network.rtt_ms = x;
            }
            if let Some(x) = net.get("jitter_ms").and_then(Json::as_f64) {
                b.cfg.network.jitter_ms = x;
            }
            if let Some(x) = net.get("bandwidth_mbps").and_then(Json::as_f64) {
                b.cfg.network.bandwidth_mbps = x;
            }
        }
        if let Some(p) = doc.get("policies") {
            if let Some(r) = p.get("routing").and_then(Json::as_str) {
                b.cfg.routing = parse_routing(r)?;
            }
            if let Some(q) = p.get("batching").and_then(Json::as_str) {
                b.cfg.batching = parse_batching(q)?;
            }
            if let Some(w) = p.get("window").and_then(Json::as_str) {
                let gamma = p
                    .get("static_gamma")
                    .and_then(Json::as_u64)
                    .unwrap_or(4) as u32;
                let weights = p
                    .get("awc_weights")
                    .and_then(Json::as_str)
                    .map(String::from);
                b.cfg.window = parse_window(w, gamma, weights)?;
            }
        }
        if let Some(k) = doc.get("batching") {
            if let Some(x) = k.get("decode_batch").and_then(Json::as_usize) {
                b.cfg.batch.decode_batch = x;
            }
            if let Some(x) = k.get("fused_batch").and_then(Json::as_usize) {
                b.cfg.batch.fused_batch = x;
            }
            if let Some(x) = k.get("prefill_batch").and_then(Json::as_usize) {
                b.cfg.batch.prefill_batch = x;
            }
            if let Some(x) = k.get("window_ms").and_then(Json::as_f64) {
                b.cfg.batch.window_ms = x;
            }
        }
        if let Some(w) = doc.get("workload") {
            if let Some(x) = w.get("dataset").and_then(Json::as_str) {
                b.cfg.workload.dataset = x.to_string();
            }
            if let Some(x) = w.get("requests").and_then(Json::as_usize) {
                b.cfg.workload.requests = x;
            }
            if let Some(x) = w.get("rate_per_s").and_then(Json::as_f64) {
                b.cfg.workload.rate_per_s = x;
            }
            if let Some(x) = w.get("trace_path").and_then(Json::as_str) {
                b.cfg.workload.trace_path = Some(x.to_string());
            }
        }
        if let Some(x) = doc.get("max_sim_ms").and_then(Json::as_f64) {
            b.cfg.max_sim_ms = x;
        }
        if let Some(s) = doc.get("scenario") {
            b.cfg.scenario = Some(Scenario::from_json(s)?);
        }
        if let Some(a) = doc.get("autoscale") {
            b.cfg.autoscale = Some(AutoscaleConfig::from_json(a)?);
        }
        if let Some(c) = doc.get("classes") {
            b.cfg.classes = Some(ClassesConfig::from_json(c)?);
        }
        if let Some(e) = doc.get("execution") {
            let s = e
                .as_str()
                .ok_or("config: execution must be a string (sequential | pipelined)")?;
            b.cfg.execution = ExecutionMode::parse(s).map_err(|e| format!("config: {e}"))?;
        }
        if let Some(x) = doc.get("clamp_trace_class_ids").and_then(Json::as_bool) {
            b.cfg.clamp_trace_class_ids = x;
        }
        b.cfg.validate()?;
        Ok(b.cfg)
    }

    /// Canonical JSON of the fully *resolved* configuration: every knob,
    /// including values that came from defaults, in a fixed structure.
    /// Two configs that would drive the simulator identically serialize
    /// to identical bytes (via [`Json::to_string_canonical`]) regardless
    /// of how they were built — YAML key order, builder calls, or sweep
    /// expansion. This is the content-hash basis for sweep cell caching
    /// ([`crate::sweep::cache`]).
    pub fn to_canonical_json(&self) -> Json {
        fn pool_json(p: &PoolSpec) -> Json {
            let mut j = Json::obj()
                .with("count", p.count.into())
                .with("gpu", p.gpu.name.into())
                .with("tp", p.tp.into())
                .with("model", p.model.name.into());
            if let Some(l) = &p.link {
                let mut lj = Json::obj();
                if let Some(x) = l.rtt_ms {
                    lj.set("rtt_ms", x.into());
                }
                if let Some(x) = l.jitter_ms {
                    lj.set("jitter_ms", x.into());
                }
                if let Some(x) = l.bandwidth_mbps {
                    lj.set("bandwidth_mbps", x.into());
                }
                j.set("link", lj);
            }
            j
        }
        fn window_json(w: &WindowKind) -> Json {
            match w {
                WindowKind::Static(g) => {
                    Json::obj().with("kind", "static".into()).with("gamma", (*g).into())
                }
                WindowKind::Dynamic { init, lo, hi } => Json::obj()
                    .with("kind", "dynamic".into())
                    .with("init", (*init).into())
                    .with("lo", (*lo).into())
                    .with("hi", (*hi).into()),
                WindowKind::Awc { weights_path } => {
                    let mut j = Json::obj().with("kind", "awc".into());
                    match weights_path {
                        Some(p) => j.set("weights", p.as_str().into()),
                        None => j.set("weights", Json::Null),
                    };
                    j
                }
                WindowKind::FusedOnly => Json::obj().with("kind", "fused".into()),
            }
        }
        let routing = match self.routing {
            RoutingKind::Random => "random",
            RoutingKind::RoundRobin => "round_robin",
            RoutingKind::Jsq => "jsq",
        };
        let batching = match self.batching {
            BatchingKind::Fifo => "fifo",
            BatchingKind::Lab => "lab",
        };
        let mut workload = Json::obj()
            .with("dataset", self.workload.dataset.as_str().into())
            .with("requests", self.workload.requests.into())
            .with("rate_per_s", self.workload.rate_per_s.into());
        if let Some(p) = &self.workload.trace_path {
            workload.set("trace_path", p.as_str().into());
        }
        // Non-finite bandwidth (the "disabled" default) serializes to
        // null — distinct from every finite setting, which is all the
        // hash needs; NaN never reaches here (validate() rejects it).
        //
        // The seed is emitted as a decimal *string*: JSON numbers here
        // are f64, and distinct u64 seeds ≥ 2^53 (plausible with
        // hash-derived or wrapping-arithmetic seeds) would collide to
        // one f64 — and therefore one cache key — if emitted as Num.
        // The scenario block is appended only when present: scenario-free
        // configs keep their historical canonical bytes, so existing
        // sweep cache keys stay valid.
        let mut j = Json::obj()
            .with("seed", self.seed.to_string().into())
            .with(
                "cluster",
                Json::obj()
                    .with(
                        "targets",
                        Json::Arr(self.target_pools.iter().map(pool_json).collect()),
                    )
                    .with(
                        "drafters",
                        Json::Arr(self.drafter_pools.iter().map(pool_json).collect()),
                    ),
            )
            .with(
                "network",
                Json::obj()
                    .with("rtt_ms", self.network.rtt_ms.into())
                    .with("jitter_ms", self.network.jitter_ms.into())
                    .with("bandwidth_mbps", self.network.bandwidth_mbps.into()),
            )
            .with(
                "policies",
                Json::obj()
                    .with("routing", routing.into())
                    .with("batching", batching.into())
                    .with("window", window_json(&self.window)),
            )
            .with(
                "batch",
                Json::obj()
                    .with("decode_batch", self.batch.decode_batch.into())
                    .with("fused_batch", self.batch.fused_batch.into())
                    .with("prefill_batch", self.batch.prefill_batch.into())
                    .with("window_ms", self.batch.window_ms.into()),
            )
            .with("workload", workload)
            .with("max_sim_ms", self.max_sim_ms.into());
        if let Some(s) = &self.scenario {
            j.set("scenario", s.to_canonical_json());
        }
        // Like the scenario block: appended only when present, so
        // autoscale-free configs keep their historical canonical bytes
        // and existing sweep cache keys stay valid.
        if let Some(a) = &self.autoscale {
            j.set("autoscale", a.to_canonical_json());
        }
        // Same contract for the multi-tenant block: class-free configs
        // keep their historical canonical bytes and cache keys.
        if let Some(c) = &self.classes {
            j.set("classes", c.to_canonical_json());
        }
        // And for execution: the key is emitted only for the non-default
        // pipelined mode, so sequential configs (explicit or implicit)
        // keep their historical canonical bytes and cache keys.
        if self.execution == ExecutionMode::Pipelined {
            j.set("execution", self.execution.label().into());
        }
        // The clamp opt-in follows the same only-when-set contract.
        if self.clamp_trace_class_ids {
            j.set("clamp_trace_class_ids", true.into());
        }
        j
    }

    /// Total target count across pools.
    pub fn n_targets(&self) -> usize {
        self.target_pools.iter().map(|p| p.count).sum()
    }

    /// Total drafter count across pools.
    pub fn n_drafters(&self) -> usize {
        self.drafter_pools.iter().map(|p| p.count).sum()
    }

    /// Sanity checks.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_targets() == 0 {
            return Err("config: at least one target required".into());
        }
        if self.n_drafters() == 0 && !matches!(self.window, WindowKind::FusedOnly) {
            return Err("config: drafters required unless window=fused".into());
        }
        // rtt/jitter feed event times directly, so NaN/∞ must be caught
        // here (NaN also slips through a plain `< 0.0` comparison).
        let bad_delay = |x: f64| !x.is_finite() || x < 0.0;
        if bad_delay(self.network.rtt_ms) || bad_delay(self.network.jitter_ms) {
            return Err("config: rtt_ms/jitter_ms must be finite and non-negative".into());
        }
        if self.network.bandwidth_mbps <= 0.0 || self.network.bandwidth_mbps.is_nan() {
            return Err("config: bandwidth_mbps must be positive".into());
        }
        for p in self.target_pools.iter().chain(&self.drafter_pools) {
            if let Some(l) = &p.link {
                if l.rtt_ms.is_some_and(bad_delay) || l.jitter_ms.is_some_and(bad_delay) {
                    return Err(
                        "config: per-pool link rtt_ms/jitter_ms must be finite and \
                         non-negative"
                            .into(),
                    );
                }
                if l.bandwidth_mbps.is_some_and(|x| x <= 0.0 || x.is_nan()) {
                    return Err("config: per-pool bandwidth_mbps must be positive".into());
                }
            }
        }
        if self.workload.requests == 0 && self.workload.trace_path.is_none() {
            return Err("config: empty workload".into());
        }
        if self.batch.decode_batch == 0 || self.batch.prefill_batch == 0 {
            return Err("config: zero batch size".into());
        }
        if let Some(a) = &self.autoscale {
            a.validate(self.n_targets())?;
        }
        if let Some(c) = &self.classes {
            c.validate()?;
            // Trace-driven workloads carry their own arrival times and
            // class tags would be fabricated; per-class arrivals could
            // not take effect and must not silently pretend to.
            if self.workload.trace_path.is_some() {
                return Err(
                    "config: classes cannot combine with workload.trace_path (the trace \
                     fixes arrival times and carries no tier structure); drop the \
                     classes block or the trace"
                        .into(),
                );
            }
            if let Some(s) = &self.scenario {
                // Each tier owns its arrival process; a scenario-level
                // arrival process or global rate override would fight
                // the per-tier envelopes.
                let has_global_override = s
                    .events
                    .iter()
                    .any(|e| matches!(e.event, crate::scenario::ScenarioEvent::RateOverride { .. }));
                if s.arrivals.is_some() || has_global_override {
                    return Err(
                        "config: scenario arrival processes / global rate_override \
                         events cannot combine with a classes block (each tier declares \
                         its own arrivals); use class_rate_override events instead"
                            .into(),
                    );
                }
            }
        }
        // Class-targeted scenario events must name a declared tier —
        // checked here (Simulator::try_new calls validate) so a typo'd
        // class name fails with a named error, never a silent no-op.
        if let Some(s) = &self.scenario {
            for e in &s.events {
                if let crate::scenario::ScenarioEvent::ClassRateOverride { class, .. } = &e.event {
                    match &self.classes {
                        None => {
                            return Err(format!(
                                "config: scenario event class_rate_override ('{class}') \
                                 requires a classes: block declaring that tier"
                            ))
                        }
                        Some(c) if c.class_index(class).is_none() => {
                            return Err(format!(
                                "config: scenario event class_rate_override targets \
                                 undeclared class '{class}' (declared: {})",
                                c.tiers
                                    .iter()
                                    .map(|t| t.name.as_str())
                                    .collect::<Vec<_>>()
                                    .join(", ")
                            ))
                        }
                        Some(_) => {}
                    }
                }
            }
        }
        if let Some(s) = &self.scenario {
            s.validate(self.drafter_pools.len(), self.n_targets())?;
            // Scripted capacity events drive the autoscale fleet; with
            // no autoscale block they could not take effect and must
            // not silently pretend to.
            let has_pool_events = s.events.iter().any(|e| {
                matches!(
                    e.event,
                    crate::scenario::ScenarioEvent::TargetPoolUp { .. }
                        | crate::scenario::ScenarioEvent::TargetPoolDown { .. }
                )
            });
            if has_pool_events && self.autoscale.is_none() {
                return Err(
                    "config: scenario target_pool_up/target_pool_down events require an \
                     autoscale: block (they drive the elastic target pool; add \
                     `autoscale: {policy: {kind: scheduled}}` for purely scripted \
                     capacity)"
                        .into(),
                );
            }
            // Trace-driven workloads carry their own arrival times; a
            // scenario arrival process (or rate override) could not take
            // effect and must not silently pretend to — the cell would
            // be cache-keyed and labeled by dynamics it never ran.
            if self.workload.trace_path.is_some() {
                let has_overrides = s
                    .events
                    .iter()
                    .any(|e| matches!(e.event, crate::scenario::ScenarioEvent::RateOverride { .. }));
                if s.arrivals.is_some() || has_overrides {
                    return Err(
                        "config: scenario arrival processes / rate overrides cannot \
                         combine with workload.trace_path (the trace fixes arrival \
                         times); drop the arrivals block or the trace"
                            .into(),
                    );
                }
            }
        }
        Ok(())
    }
}

fn parse_pool(
    p: &Json,
    default_tp: u32,
    default_model: &str,
    default_gpu: &str,
) -> Result<PoolSpec, String> {
    let gpu_name = p.get("gpu").and_then(Json::as_str).unwrap_or(default_gpu);
    let model_name = p
        .get("model")
        .and_then(Json::as_str)
        .unwrap_or(default_model);
    let link = LinkOverride {
        rtt_ms: p.get("rtt_ms").and_then(Json::as_f64),
        jitter_ms: p.get("jitter_ms").and_then(Json::as_f64),
        bandwidth_mbps: p.get("bandwidth_mbps").and_then(Json::as_f64),
    };
    Ok(PoolSpec {
        count: p
            .get("count")
            .and_then(Json::as_usize)
            .ok_or("pool: missing count")?,
        gpu: gpu_by_name(gpu_name).ok_or_else(|| format!("unknown gpu '{gpu_name}'"))?,
        tp: p.get("tp").and_then(Json::as_u64).unwrap_or(default_tp as u64) as u32,
        model: model_by_name(model_name)
            .ok_or_else(|| format!("unknown model '{model_name}'"))?,
        link: (!link.is_empty()).then_some(link),
    })
}

/// Parse a routing policy name.
pub fn parse_routing(s: &str) -> Result<RoutingKind, String> {
    match s.to_ascii_lowercase().as_str() {
        "random" => Ok(RoutingKind::Random),
        "rr" | "round_robin" | "round-robin" => Ok(RoutingKind::RoundRobin),
        "jsq" => Ok(RoutingKind::Jsq),
        _ => Err(format!("unknown routing policy '{s}'")),
    }
}

/// Parse a batching policy name.
pub fn parse_batching(s: &str) -> Result<BatchingKind, String> {
    match s.to_ascii_lowercase().as_str() {
        "fifo" => Ok(BatchingKind::Fifo),
        "lab" | "length_aware" => Ok(BatchingKind::Lab),
        _ => Err(format!("unknown batching policy '{s}'")),
    }
}

/// Parse a window policy name.
pub fn parse_window(s: &str, gamma: u32, weights: Option<String>) -> Result<WindowKind, String> {
    match s.to_ascii_lowercase().as_str() {
        "static" => Ok(WindowKind::Static(gamma)),
        "dynamic" => Ok(WindowKind::Dynamic {
            init: gamma,
            lo: 0.25,
            hi: 0.75,
        }),
        "awc" => Ok(WindowKind::Awc {
            weights_path: weights,
        }),
        "fused" | "fused_only" | "cloud_only" => Ok(WindowKind::FusedOnly),
        _ => Err(format!("unknown window policy '{s}'")),
    }
}

/// Fluent builder for homogeneous single-pool configs (the common case in
/// tests and examples); heterogeneous pools come from YAML.
pub struct SimConfigBuilder {
    cfg: SimConfig,
}

impl Default for SimConfigBuilder {
    fn default() -> Self {
        use crate::cluster::gpu::{A100, A40};
        use crate::cluster::model::{LLAMA2_70B, LLAMA2_7B};
        SimConfigBuilder {
            cfg: SimConfig {
                seed: 42,
                target_pools: vec![PoolSpec {
                    count: 4,
                    gpu: &A100,
                    tp: 4,
                    model: &LLAMA2_70B,
                    link: None,
                }],
                drafter_pools: vec![PoolSpec {
                    count: 100,
                    gpu: &A40,
                    tp: 1,
                    model: &LLAMA2_7B,
                    link: None,
                }],
                network: NetworkConfig {
                    rtt_ms: 10.0,
                    jitter_ms: 0.5,
                    bandwidth_mbps: f64::INFINITY,
                },
                routing: RoutingKind::Jsq,
                batching: BatchingKind::Lab,
                window: WindowKind::Static(4),
                batch: BatchKnobs::default(),
                workload: WorkloadConfig {
                    dataset: "gsm8k".into(),
                    requests: 200,
                    rate_per_s: 30.0,
                    trace_path: None,
                },
                max_sim_ms: 3_600_000.0,
                scenario: None,
                autoscale: None,
                classes: None,
                execution: ExecutionMode::Sequential,
                clamp_trace_class_ids: false,
            },
        }
    }
}

impl SimConfigBuilder {
    /// Set the RNG seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }
    /// Set the number of (homogeneous) targets.
    pub fn targets(mut self, n: usize) -> Self {
        self.cfg.target_pools[0].count = n;
        self
    }
    /// Set the number of (homogeneous) drafters.
    pub fn drafters(mut self, n: usize) -> Self {
        self.cfg.drafter_pools[0].count = n;
        self
    }
    /// Set the edge–cloud RTT.
    pub fn rtt_ms(mut self, rtt: f64) -> Self {
        self.cfg.network.rtt_ms = rtt;
        self
    }
    /// Set network jitter.
    pub fn jitter_ms(mut self, j: f64) -> Self {
        self.cfg.network.jitter_ms = j;
        self
    }
    /// Set the edge–cloud link bandwidth (Mbit/s).
    pub fn bandwidth_mbps(mut self, b: f64) -> Self {
        self.cfg.network.bandwidth_mbps = b;
        self
    }
    /// Set the workload dataset profile.
    pub fn dataset(mut self, d: &str) -> Self {
        self.cfg.workload.dataset = d.to_string();
        self
    }
    /// Set the number of synthetic requests.
    pub fn requests(mut self, n: usize) -> Self {
        self.cfg.workload.requests = n;
        self
    }
    /// Set the global arrival rate (requests/second).
    pub fn rate_per_s(mut self, r: f64) -> Self {
        self.cfg.workload.rate_per_s = r;
        self
    }
    /// Set the routing policy.
    pub fn routing(mut self, r: RoutingKind) -> Self {
        self.cfg.routing = r;
        self
    }
    /// Set the batching policy.
    pub fn batching(mut self, b: BatchingKind) -> Self {
        self.cfg.batching = b;
        self
    }
    /// Set the window-size policy.
    pub fn window(mut self, w: WindowKind) -> Self {
        self.cfg.window = w;
        self
    }
    /// Set batch knobs.
    pub fn batch_knobs(mut self, k: BatchKnobs) -> Self {
        self.cfg.batch = k;
        self
    }
    /// Attach a scripted-dynamics scenario.
    pub fn scenario(mut self, s: Scenario) -> Self {
        self.cfg.scenario = Some(s);
        self
    }
    /// Attach an elastic-capacity (autoscale) block.
    pub fn autoscale(mut self, a: AutoscaleConfig) -> Self {
        self.cfg.autoscale = Some(a);
        self
    }
    /// Attach a multi-tenant request-classes block.
    pub fn classes(mut self, c: ClassesConfig) -> Self {
        self.cfg.classes = Some(c);
        self
    }
    /// Set the round execution mode (sequential | pipelined).
    pub fn execution(mut self, e: ExecutionMode) -> Self {
        self.cfg.execution = e;
        self
    }
    /// Finalize (panics on invalid combinations — builder misuse is a bug).
    pub fn build(self) -> SimConfig {
        self.cfg.validate().expect("invalid SimConfig");
        self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_are_valid() {
        let c = SimConfig::builder().build();
        assert_eq!(c.n_targets(), 4);
        assert_eq!(c.n_drafters(), 100);
    }

    #[test]
    fn yaml_full_document() {
        let y = "\
seed: 7
cluster:
  targets:
    - count: 12
      gpu: a100
      tp: 4
      model: llama2-70b
    - count: 4
      gpu: h100
      tp: 4
      model: qwen-72b
  drafters:
    - count: 300
      gpu: a40
      model: llama2-7b
    - count: 300
      gpu: v100
      model: qwen-7b
network:
  rtt_ms: 30
  jitter_ms: 2
policies:
  routing: jsq
  batching: lab
  window: dynamic
  static_gamma: 6
batching:
  decode_batch: 48
  prefill_batch: 4
  window_ms: 1.5
workload:
  dataset: humaneval
  requests: 100
  rate_per_s: 12
";
        let c = SimConfig::from_yaml(y).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.n_targets(), 16);
        assert_eq!(c.n_drafters(), 600);
        assert_eq!(c.target_pools[1].gpu.name, "H100");
        assert_eq!(c.network.rtt_ms, 30.0);
        assert_eq!(c.routing, RoutingKind::Jsq);
        assert_eq!(c.batching, BatchingKind::Lab);
        assert!(matches!(c.window, WindowKind::Dynamic { init: 6, .. }));
        assert_eq!(c.batch.decode_batch, 48);
        assert_eq!(c.workload.dataset, "humaneval");
    }

    #[test]
    fn yaml_partial_uses_defaults() {
        let c = SimConfig::from_yaml("seed: 1\n").unwrap();
        assert_eq!(c.seed, 1);
        assert_eq!(c.routing, RoutingKind::Jsq); // builder default
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(SimConfig::from_yaml("cluster:\n  targets:\n    - count: 0\n").is_err());
        let y = "network:\n  rtt_ms: -5\n";
        assert!(SimConfig::from_yaml(y).is_err());
        assert!(parse_routing("nope").is_err());
        assert!(parse_batching("nope").is_err());
        assert!(parse_window("nope", 4, None).is_err());
    }

    #[test]
    fn non_finite_network_parameters_rejected() {
        // `str::parse::<f64>` accepts "nan"/"inf", so the YAML path can
        // produce them; they would poison event times downstream.
        assert!(SimConfig::from_yaml("network:\n  rtt_ms: nan\n").is_err());
        assert!(SimConfig::from_yaml("network:\n  jitter_ms: inf\n").is_err());
        let y = "\
cluster:
  targets:
    - count: 1
  drafters:
    - count: 1
      rtt_ms: nan
";
        assert!(SimConfig::from_yaml(y).unwrap_err().contains("link"));
    }

    #[test]
    fn unknown_hardware_rejected() {
        let y = "cluster:\n  targets:\n    - count: 1\n      gpu: tpu-v5\n";
        assert!(SimConfig::from_yaml(y).unwrap_err().contains("unknown gpu"));
    }

    #[test]
    fn per_pool_link_overrides_parse() {
        let y = "\
cluster:
  targets:
    - count: 1
      gpu: a100
      tp: 4
      model: llama2-70b
  drafters:
    - count: 2
      gpu: a40
      model: llama2-7b
      rtt_ms: 80
      jitter_ms: 6
      bandwidth_mbps: 20
    - count: 3
      gpu: v100
      model: qwen-7b
network:
  rtt_ms: 10
  jitter_ms: 0.5
  bandwidth_mbps: 1000
";
        let c = SimConfig::from_yaml(y).unwrap();
        assert_eq!(c.network.bandwidth_mbps, 1000.0);
        let l = c.drafter_pools[0].link.expect("override present");
        assert_eq!(l.rtt_ms, Some(80.0));
        assert_eq!(l.jitter_ms, Some(6.0));
        assert_eq!(l.bandwidth_mbps, Some(20.0));
        assert!(c.drafter_pools[1].link.is_none(), "no keys -> no override");
    }

    #[test]
    fn bad_link_overrides_rejected() {
        let y = "\
cluster:
  targets:
    - count: 1
  drafters:
    - count: 1
      rtt_ms: -3
";
        assert!(SimConfig::from_yaml(y).unwrap_err().contains("link"));
        let y2 = "network:\n  bandwidth_mbps: 0\n";
        assert!(SimConfig::from_yaml(y2).unwrap_err().contains("bandwidth"));
    }

    #[test]
    fn canonical_json_is_total_and_stable() {
        let cfg = SimConfig::builder().build();
        let a = cfg.to_canonical_json().to_string_canonical();
        let b = cfg.clone().to_canonical_json().to_string_canonical();
        assert_eq!(a, b);
        // Every section present, including defaulted knobs.
        let j = cfg.to_canonical_json();
        assert!(j.path(&["network", "rtt_ms"]).is_some());
        assert!(j.path(&["policies", "window", "kind"]).is_some());
        assert!(j.path(&["batch", "decode_batch"]).is_some());
        assert_eq!(j.get("seed").unwrap().as_str(), Some("42"));
    }

    #[test]
    fn canonical_json_distinguishes_seeds_beyond_f64_precision() {
        // 2^60 and 2^60 + 1 are the same f64; as canonical strings they
        // must stay distinct or two cells would share a cache key.
        let a = SimConfig::builder().seed(1u64 << 60).build();
        let b = SimConfig::builder().seed((1u64 << 60) + 1).build();
        assert_ne!(
            a.to_canonical_json().to_string_canonical(),
            b.to_canonical_json().to_string_canonical()
        );
    }

    #[test]
    fn canonical_json_distinguishes_every_window_kind() {
        let mut texts = Vec::new();
        for w in [
            WindowKind::Static(4),
            WindowKind::Static(6),
            WindowKind::Dynamic { init: 4, lo: 0.25, hi: 0.75 },
            WindowKind::Awc { weights_path: None },
            WindowKind::Awc { weights_path: Some("w.json".into()) },
            WindowKind::FusedOnly,
        ] {
            let cfg = SimConfig::builder().window(w).build();
            texts.push(cfg.to_canonical_json().to_string_canonical());
        }
        for i in 0..texts.len() {
            for j in (i + 1)..texts.len() {
                assert_ne!(texts[i], texts[j], "windows {i} and {j} collide");
            }
        }
    }

    #[test]
    fn canonical_json_covers_link_overrides() {
        let y = "\
cluster:
  targets:
    - count: 1
  drafters:
    - count: 2
      rtt_ms: 80
";
        let cfg = SimConfig::from_yaml(y).unwrap();
        let j = cfg.to_canonical_json();
        let drafters = j.path(&["cluster", "drafters"]).unwrap().as_arr().unwrap();
        assert_eq!(
            drafters[0].path(&["link", "rtt_ms"]).unwrap().as_f64(),
            Some(80.0)
        );
        // Dropping the override changes the canonical bytes.
        let plain = SimConfig::from_yaml("cluster:\n  targets:\n    - count: 1\n  drafters:\n    - count: 2\n").unwrap();
        assert_ne!(
            cfg.to_canonical_json().to_string_canonical(),
            plain.to_canonical_json().to_string_canonical()
        );
    }

    #[test]
    fn scenario_block_parses_and_validates() {
        let y = "\
seed: 3
cluster:
  targets:
    - count: 2
  drafters:
    - count: 4
    - count: 4
scenario:
  name: flap
  arrivals:
    kind: diurnal
    mean_per_s: 30
    amplitude_per_s: 10
    period_ms: 20000
  events:
    - at_ms: 5000
      kind: link_degrade
      pool: 1
      rtt_mult: 8
    - at_ms: 9000
      kind: link_restore
      pool: 1
";
        let c = SimConfig::from_yaml(y).unwrap();
        let s = c.scenario.as_ref().unwrap();
        assert_eq!(s.name, "flap");
        assert_eq!(s.events.len(), 2);
        // Pool index beyond the deployment is rejected at validate time.
        let bad = y.replace("pool: 1", "pool: 7");
        assert!(SimConfig::from_yaml(&bad).unwrap_err().contains("out of range"));
    }

    #[test]
    fn scenario_arrivals_reject_trace_driven_workloads() {
        use crate::scenario::{ArrivalProcess, Scenario, ScenarioEvent, TimedEvent};
        let mk = |arrivals, events| {
            let mut cfg = SimConfig::builder().build();
            cfg.workload.trace_path = Some("trace.jsonl".into());
            cfg.scenario = Some(Scenario { name: "s".into(), arrivals, events });
            cfg
        };
        // Arrival process + trace: rejected.
        let c = mk(Some(ArrivalProcess::Constant { rate_per_s: 10.0 }), Vec::new());
        assert!(c.validate().unwrap_err().contains("trace_path"));
        // Rate override + trace: rejected.
        let c = mk(
            None,
            vec![TimedEvent {
                at_ms: 5.0,
                event: ScenarioEvent::RateOverride { rate_per_s: 9.0 },
            }],
        );
        assert!(c.validate().unwrap_err().contains("trace_path"));
        // Runtime-only events (no arrival semantics) are fine with traces.
        let c = mk(
            None,
            vec![TimedEvent {
                at_ms: 5.0,
                event: ScenarioEvent::TargetSlowdown { target: None, mult: 2.0 },
            }],
        );
        assert!(c.validate().is_ok());
    }

    #[test]
    fn scenario_free_canonical_json_is_unchanged_and_scenarios_fork_keys() {
        // No "scenario" key for scenario-free configs: historical sweep
        // cache keys must remain valid.
        let plain = SimConfig::builder().build();
        let j = plain.to_canonical_json();
        assert!(j.get("scenario").is_none());
        // Attaching a scenario changes the canonical bytes; different
        // scenarios differ from each other.
        let scn = |name: &str, rtt_mult: f64| {
            crate::scenario::Scenario {
                name: name.into(),
                arrivals: None,
                events: vec![crate::scenario::TimedEvent {
                    at_ms: 100.0,
                    event: crate::scenario::ScenarioEvent::LinkDegrade {
                        pool: None,
                        rtt_mult,
                        jitter_mult: 1.0,
                        bandwidth_mult: 1.0,
                    },
                }],
            }
        };
        let a = SimConfig::builder().scenario(scn("a", 2.0)).build();
        let b = SimConfig::builder().scenario(scn("a", 4.0)).build();
        let pj = plain.to_canonical_json().to_string_canonical();
        let aj = a.to_canonical_json().to_string_canonical();
        let bj = b.to_canonical_json().to_string_canonical();
        assert_ne!(pj, aj);
        assert_ne!(aj, bj);
        assert!(a.to_canonical_json().path(&["scenario", "name"]).is_some());
    }

    #[test]
    fn autoscale_block_parses_validates_and_forks_canonical_bytes() {
        let y = "\
seed: 5
cluster:
  targets:
    - count: 4
  drafters:
    - count: 8
autoscale:
  policy:
    kind: reactive
    up_queue_depth: 4
  min_targets: 1
  max_targets: 4
  initial_targets: 2
";
        let c = SimConfig::from_yaml(y).unwrap();
        let a = c.autoscale.as_ref().unwrap();
        assert_eq!(a.min_targets, 1);
        assert_eq!(a.resolved_initial(c.n_targets()), 2);
        // Bounds beyond the deployment are rejected at validate time.
        let bad = y.replace("max_targets: 4", "max_targets: 9");
        assert!(SimConfig::from_yaml(&bad).unwrap_err().contains("exceeds"));
        // No "autoscale" key for autoscale-free configs: historical
        // sweep cache keys must remain valid.
        let plain = SimConfig::builder().build();
        assert!(plain.to_canonical_json().get("autoscale").is_none());
        // Attaching a block changes the canonical bytes; different
        // blocks differ from each other.
        let pj = plain.to_canonical_json().to_string_canonical();
        let aj = c.to_canonical_json().to_string_canonical();
        let c2 = SimConfig::from_yaml(&y.replace("up_queue_depth: 4", "up_queue_depth: 8"))
            .unwrap();
        let bj = c2.to_canonical_json().to_string_canonical();
        assert_ne!(pj, aj);
        assert_ne!(aj, bj);
        assert!(c.to_canonical_json().path(&["autoscale", "policy", "kind"]).is_some());
    }

    #[test]
    fn classes_block_parses_validates_and_forks_canonical_bytes() {
        let y = "\
seed: 5
cluster:
  targets:
    - count: 2
  drafters:
    - count: 8
classes:
  name: fair
  tiers:
    - name: interactive
      rate_per_s: 20
      slo:
        ttft_ms: 1000
        tpot_ms: 50
    - name: batch
      rate_per_s: 10
";
        let c = SimConfig::from_yaml(y).unwrap();
        let cl = c.classes.as_ref().unwrap();
        assert_eq!(cl.name, "fair");
        assert_eq!(cl.n_classes(), 2);
        assert!(cl.priority_admission, "defaults on");
        // No "classes" key for class-free configs: historical sweep
        // cache keys must remain valid.
        let plain = SimConfig::builder().build();
        assert!(plain.to_canonical_json().get("classes").is_none());
        // Attaching a block changes the canonical bytes; different
        // blocks differ from each other.
        let pj = plain.to_canonical_json().to_string_canonical();
        let aj = c.to_canonical_json().to_string_canonical();
        let c2 = SimConfig::from_yaml(&y.replace("rate_per_s: 20", "rate_per_s: 25")).unwrap();
        let bj = c2.to_canonical_json().to_string_canonical();
        assert_ne!(pj, aj);
        assert_ne!(aj, bj);
        assert!(c.to_canonical_json().path(&["classes", "tiers"]).is_some());
        // Classes reject trace-driven workloads and scenario arrivals.
        let mut traced = c.clone();
        traced.workload.trace_path = Some("t.jsonl".into());
        assert!(traced.validate().unwrap_err().contains("trace_path"));
        let mut with_arrivals = c.clone();
        with_arrivals.scenario = Some(crate::scenario::Scenario {
            name: "s".into(),
            arrivals: Some(crate::scenario::ArrivalProcess::Constant { rate_per_s: 5.0 }),
            events: Vec::new(),
        });
        assert!(with_arrivals
            .validate()
            .unwrap_err()
            .contains("class_rate_override"));
    }

    #[test]
    fn class_rate_override_requires_a_declared_tier() {
        use crate::scenario::{Scenario, ScenarioEvent, TimedEvent};
        let mk_scenario = |class: &str| Scenario {
            name: "s".into(),
            arrivals: None,
            events: vec![TimedEvent {
                at_ms: 5_000.0,
                event: ScenarioEvent::ClassRateOverride {
                    class: class.into(),
                    rate_per_s: 9.0,
                },
            }],
        };
        // Without a classes block the event has nothing to target.
        let mut cfg = SimConfig::builder().build();
        cfg.scenario = Some(mk_scenario("interactive"));
        assert!(cfg.validate().unwrap_err().contains("requires a classes"));
        // With a block, only declared names pass.
        let classes = crate::config::ClassesConfig::from_yaml(
            "tiers:\n  - name: interactive\n    rate_per_s: 20\n  - name: batch\n    rate_per_s: 5\n",
        )
        .unwrap();
        cfg.classes = Some(classes);
        cfg.validate().unwrap();
        cfg.scenario = Some(mk_scenario("bulk"));
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("undeclared class 'bulk'"), "{err}");
        assert!(err.contains("interactive, batch"), "{err}");
    }

    #[test]
    fn scenario_target_pool_events_require_an_autoscale_block() {
        let y = "\
cluster:
  targets:
    - count: 3
  drafters:
    - count: 6
scenario:
  name: scripted
  events:
    - at_ms: 1000
      kind: target_pool_down
      count: 1
";
        let err = SimConfig::from_yaml(y).unwrap_err();
        assert!(err.contains("autoscale"), "{err}");
        let with_block = format!("{y}autoscale:\n  policy:\n    kind: scheduled\n");
        SimConfig::from_yaml(&with_block).unwrap();
    }

    /// ISSUE 8 satellite: the sequential execution mode is the byte-level
    /// identity — an absent `execution:` key, and an explicit
    /// `execution: sequential`, must both keep the historical canonical
    /// bytes (and therefore cache keys); only `pipelined` forks them.
    #[test]
    fn execution_absent_equals_sequential_canonical_json() {
        let plain = SimConfig::builder().build();
        assert_eq!(plain.execution, ExecutionMode::Sequential);
        assert!(plain.to_canonical_json().get("execution").is_none());
        let explicit = SimConfig::from_yaml("execution: sequential\n").unwrap();
        assert_eq!(
            plain.to_canonical_json().to_string_canonical(),
            explicit.to_canonical_json().to_string_canonical()
        );
        let piped = SimConfig::from_yaml("execution: pipelined\n").unwrap();
        assert_eq!(piped.execution, ExecutionMode::Pipelined);
        assert_eq!(
            piped.to_canonical_json().get("execution").and_then(Json::as_str),
            Some("pipelined")
        );
        assert_ne!(
            plain.to_canonical_json().to_string_canonical(),
            piped.to_canonical_json().to_string_canonical()
        );
        // Builder route agrees with the YAML route.
        let built = SimConfig::builder().execution(ExecutionMode::Pipelined).build();
        assert_eq!(
            built.to_canonical_json().to_string_canonical(),
            piped.to_canonical_json().to_string_canonical()
        );
        // Unknown spellings are named errors, not silent defaults.
        let err = SimConfig::from_yaml("execution: overlapped\n").unwrap_err();
        assert!(err.contains("unknown execution mode"), "{err}");
    }

    /// The clamp opt-in follows the same only-when-set byte contract.
    #[test]
    fn clamp_opt_in_is_absent_by_default_and_forks_bytes_when_set() {
        let plain = SimConfig::builder().build();
        assert!(!plain.clamp_trace_class_ids);
        assert!(plain.to_canonical_json().get("clamp_trace_class_ids").is_none());
        let clamped = SimConfig::from_yaml("clamp_trace_class_ids: true\n").unwrap();
        assert!(clamped.clamp_trace_class_ids);
        assert_ne!(
            plain.to_canonical_json().to_string_canonical(),
            clamped.to_canonical_json().to_string_canonical()
        );
        // `false` is the default: identical bytes.
        let off = SimConfig::from_yaml("clamp_trace_class_ids: false\n").unwrap();
        assert_eq!(
            plain.to_canonical_json().to_string_canonical(),
            off.to_canonical_json().to_string_canonical()
        );
    }

    #[test]
    fn window_policy_names() {
        assert!(matches!(parse_window("static", 4, None), Ok(WindowKind::Static(4))));
        assert!(matches!(parse_window("awc", 4, None), Ok(WindowKind::Awc { .. })));
        assert!(matches!(parse_window("fused", 4, None), Ok(WindowKind::FusedOnly)));
    }
}
