//! Deployment configuration (paper §3.1): typed config structs, YAML
//! ingestion, and the `auto_topology` pass that expands a high-level
//! specification into explicit drafter/target device pools.

pub mod classes;
pub mod schema;
pub mod topology;

pub use classes::{ClassSpec, ClassesConfig};
pub use schema::{
    parse_batching, parse_routing, parse_window, BatchKnobs, BatchingKind, LinkOverride,
    NetworkConfig, PoolSpec, RoutingKind, SimConfig, SimConfigBuilder, WindowKind,
    WorkloadConfig,
};
pub use topology::{LinkSpec, Topology};
