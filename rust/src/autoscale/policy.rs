//! Pluggable scaling policies and the tick-driven decision engine.
//!
//! Every policy is evaluated on the fixed autoscale tick against a
//! [`CapacitySnapshot`] of the live system and emits a
//! [`ScaleDecision`]. The [`PolicyEngine`] owns the cross-tick state —
//! cooldown bookkeeping and the recent arrival-rate window the
//! predictive policy extrapolates — so the policies themselves stay
//! pure decision rules, unit-testable without a simulator.

use crate::util::json::Json;

/// The scaling decision rule of an
/// [`AutoscaleConfig`](super::AutoscaleConfig).
#[derive(Clone, Debug, PartialEq)]
pub enum ScalingPolicy {
    /// Threshold rule on the live queue depth and utilization, with a
    /// hysteresis band: scale up when queued work per active target
    /// exceeds `up_queue_depth`; scale down only when it has fallen
    /// below the (strictly smaller) `down_queue_depth` *and* the busy
    /// fraction is at or under `down_utilization`.
    Reactive {
        /// Queued work per active target triggering a scale-up.
        up_queue_depth: f64,
        /// Queued work per active target permitting a scale-down
        /// (hysteresis: must be < `up_queue_depth`).
        down_queue_depth: f64,
        /// Busy-target fraction at or below which scale-down is allowed.
        down_utilization: f64,
    },
    /// No tick-driven decisions: capacity changes come exclusively from
    /// scripted `target_pool_up` / `target_pool_down` scenario events
    /// (and a fixed fleet with no events gets pure cost accounting).
    Scheduled,
    /// Trend extrapolation: the recent arrival-rate slope is projected
    /// one provisioning lead ahead, the backlog is forecast under the
    /// projected rate, and the thresholds act on that *forecast* — so
    /// capacity is requested before the spike arrives rather than after
    /// the queue has already formed.
    Predictive {
        /// Arrival-rate history length, in ticks (slope window; ≥ 2).
        window_ticks: usize,
        /// Forecast backlog per committed target triggering a scale-up.
        up_backlog_per_target: f64,
        /// Forecast backlog per remaining target permitting a
        /// scale-down (hysteresis: must be < `up_backlog_per_target`).
        down_backlog_per_target: f64,
    },
}

impl ScalingPolicy {
    /// The default reactive rule (used when a config block names no
    /// policy).
    pub fn default_reactive() -> ScalingPolicy {
        ScalingPolicy::Reactive {
            up_queue_depth: 6.0,
            down_queue_depth: 1.0,
            down_utilization: 0.35,
        }
    }

    /// Stable kind name (YAML `kind:` values and labels).
    pub fn kind(&self) -> &'static str {
        match self {
            ScalingPolicy::Reactive { .. } => "reactive",
            ScalingPolicy::Scheduled => "scheduled",
            ScalingPolicy::Predictive { .. } => "predictive",
        }
    }

    /// Parse the `policy:` block. Strict: unknown keys are rejected.
    pub fn from_json(j: &Json) -> Result<ScalingPolicy, String> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("autoscale policy: missing 'kind'")?;
        let allowed: &[&str] = match kind {
            "reactive" => &["up_queue_depth", "down_queue_depth", "down_utilization"],
            "scheduled" => &[],
            "predictive" => &[
                "window_ticks",
                "up_backlog_per_target",
                "down_backlog_per_target",
            ],
            _ => &[], // unknown kind: rejected below with the full list
        };
        if let Json::Obj(pairs) = j {
            for (k, _) in pairs {
                if k != "kind" && !allowed.contains(&k.as_str()) {
                    return Err(format!("autoscale policy ({kind}): unknown key '{k}'"));
                }
            }
        }
        let num = |key: &str, default: f64| -> Result<f64, String> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| format!("autoscale policy ({kind}): '{key}' must be a number")),
            }
        };
        let p = match kind {
            "reactive" => ScalingPolicy::Reactive {
                up_queue_depth: num("up_queue_depth", 6.0)?,
                down_queue_depth: num("down_queue_depth", 1.0)?,
                down_utilization: num("down_utilization", 0.35)?,
            },
            "scheduled" => ScalingPolicy::Scheduled,
            "predictive" => ScalingPolicy::Predictive {
                window_ticks: match j.get("window_ticks") {
                    None => 4,
                    Some(v) => v.as_usize().ok_or(
                        "autoscale policy (predictive): 'window_ticks' must be a count",
                    )?,
                },
                up_backlog_per_target: num("up_backlog_per_target", 6.0)?,
                down_backlog_per_target: num("down_backlog_per_target", 1.0)?,
            },
            other => {
                return Err(format!(
                    "autoscale policy: unknown kind '{other}' \
                     (known: reactive, scheduled, predictive)"
                ))
            }
        };
        p.validate()?;
        Ok(p)
    }

    /// Canonical JSON (fixed key order per kind — part of the sweep
    /// cache key for autoscale-bearing configs).
    pub fn to_canonical_json(&self) -> Json {
        let base = Json::obj().with("kind", self.kind().into());
        match *self {
            ScalingPolicy::Reactive {
                up_queue_depth,
                down_queue_depth,
                down_utilization,
            } => base
                .with("up_queue_depth", up_queue_depth.into())
                .with("down_queue_depth", down_queue_depth.into())
                .with("down_utilization", down_utilization.into()),
            ScalingPolicy::Scheduled => base,
            ScalingPolicy::Predictive {
                window_ticks,
                up_backlog_per_target,
                down_backlog_per_target,
            } => base
                .with("window_ticks", window_ticks.into())
                .with("up_backlog_per_target", up_backlog_per_target.into())
                .with("down_backlog_per_target", down_backlog_per_target.into()),
        }
    }

    /// Sanity checks (thresholds finite, hysteresis bands ordered).
    pub fn validate(&self) -> Result<(), String> {
        let band = |up_name: &str, up: f64, down_name: &str, down: f64| -> Result<(), String> {
            if !up.is_finite() || up <= 0.0 {
                return Err(format!(
                    "autoscale policy: {up_name} must be finite and positive"
                ));
            }
            if !down.is_finite() || down < 0.0 {
                return Err(format!(
                    "autoscale policy: {down_name} must be finite and ≥ 0"
                ));
            }
            if down >= up {
                return Err(format!(
                    "autoscale policy: {down_name} must be below {up_name} \
                     (the hysteresis band prevents scale flapping)"
                ));
            }
            Ok(())
        };
        match *self {
            ScalingPolicy::Reactive {
                up_queue_depth,
                down_queue_depth,
                down_utilization,
            } => {
                band(
                    "up_queue_depth",
                    up_queue_depth,
                    "down_queue_depth",
                    down_queue_depth,
                )?;
                if !down_utilization.is_finite() || !(0.0..=1.0).contains(&down_utilization) {
                    return Err(
                        "autoscale policy: down_utilization must be in [0, 1]".into()
                    );
                }
                Ok(())
            }
            ScalingPolicy::Scheduled => Ok(()),
            ScalingPolicy::Predictive {
                window_ticks,
                up_backlog_per_target,
                down_backlog_per_target,
            } => {
                if window_ticks < 2 {
                    return Err(
                        "autoscale policy: window_ticks must be at least 2 (a slope \
                         needs two samples)"
                            .into(),
                    );
                }
                band(
                    "up_backlog_per_target",
                    up_backlog_per_target,
                    "down_backlog_per_target",
                    down_backlog_per_target,
                )
            }
        }
    }
}

/// Live-system observation one autoscale tick evaluates.
#[derive(Clone, Copy, Debug)]
pub struct CapacitySnapshot {
    /// Tick time, ms.
    pub now_ms: f64,
    /// Committed capacity: Active + Provisioning targets.
    pub committed: usize,
    /// Targets currently accepting work.
    pub active: usize,
    /// Active targets currently executing a batch.
    pub busy_active: usize,
    /// Work queued across active targets (prefill + verify + fused
    /// residents).
    pub queued: usize,
    /// Requests arrived but not yet completed, system-wide.
    pub backlog: usize,
    /// Backlog of the highest-priority request class (tier 0 of the
    /// `classes:` block); 0 for single-tenant runs. Lets class-aware
    /// policies scale on interactive pressure specifically rather than
    /// the blended total.
    pub interactive_backlog: usize,
    /// Arrival rate over the last tick, requests/second.
    pub arrival_rate_per_s: f64,
    /// Completion rate over the last tick, requests/second.
    pub completion_rate_per_s: f64,
}

/// What one tick decided.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// No change.
    Hold,
    /// Provision this many additional targets.
    Up(usize),
    /// Drain this many targets.
    Down(usize),
}

/// Tick-driven decision engine: applies the policy rule under the
/// configured cooldown and capacity bounds, and maintains the
/// arrival-rate history the predictive rule extrapolates.
pub struct PolicyEngine {
    policy: ScalingPolicy,
    cooldown_ms: f64,
    eval_interval_ms: f64,
    /// Forecast lead: a decision made now delivers capacity one
    /// provisioning delay (plus one tick of decision latency) later.
    lead_ms: f64,
    min: usize,
    max: usize,
    last_decision_ms: f64,
    /// Recent arrival rates, oldest first (bounded by the predictive
    /// window; unused but cheap for the other policies).
    rates: Vec<f64>,
}

impl PolicyEngine {
    /// Engine for one config with bounds already resolved against the
    /// deployment.
    pub fn new(cfg: &super::AutoscaleConfig, min: usize, max: usize) -> PolicyEngine {
        PolicyEngine {
            policy: cfg.policy.clone(),
            cooldown_ms: cfg.cooldown_ms,
            eval_interval_ms: cfg.eval_interval_ms,
            lead_ms: cfg.provision_delay_ms + cfg.eval_interval_ms,
            min,
            max,
            last_decision_ms: f64::NEG_INFINITY,
            rates: Vec::new(),
        }
    }

    /// Evaluate one tick. Non-`Hold` outcomes stamp the cooldown clock;
    /// a tick inside the cooldown window always holds (the rate history
    /// still advances, so the predictive slope never goes stale).
    pub fn decide(&mut self, snap: &CapacitySnapshot) -> ScaleDecision {
        let window = match self.policy {
            ScalingPolicy::Predictive { window_ticks, .. } => window_ticks,
            _ => 2,
        };
        self.rates.push(snap.arrival_rate_per_s);
        if self.rates.len() > window {
            self.rates.remove(0);
        }
        if snap.now_ms - self.last_decision_ms < self.cooldown_ms {
            return ScaleDecision::Hold;
        }
        let decision = match self.policy {
            ScalingPolicy::Scheduled => ScaleDecision::Hold,
            ScalingPolicy::Reactive {
                up_queue_depth,
                down_queue_depth,
                down_utilization,
            } => {
                let active = snap.active.max(1) as f64;
                let q_per = snap.queued as f64 / active;
                let util = snap.busy_active as f64 / active;
                if q_per > up_queue_depth && snap.committed < self.max {
                    ScaleDecision::Up(1)
                } else if snap.committed > self.min
                    && q_per <= down_queue_depth
                    && util <= down_utilization
                {
                    ScaleDecision::Down(1)
                } else {
                    ScaleDecision::Hold
                }
            }
            ScalingPolicy::Predictive {
                up_backlog_per_target,
                down_backlog_per_target,
                ..
            } => {
                let newest = *self.rates.last().expect("rate pushed above");
                let oldest = self.rates[0];
                let slope_per_ms = if self.rates.len() >= 2 {
                    (newest - oldest) / ((self.rates.len() - 1) as f64 * self.eval_interval_ms)
                } else {
                    0.0
                };
                let forecast_rate = (newest + slope_per_ms * self.lead_ms).max(0.0);
                let drift =
                    (forecast_rate - snap.completion_rate_per_s) * self.lead_ms / 1_000.0;
                let forecast_backlog = (snap.backlog as f64 + drift).max(0.0);
                let committed = snap.committed.max(1) as f64;
                if forecast_backlog / committed > up_backlog_per_target
                    && snap.committed < self.max
                {
                    ScaleDecision::Up(1)
                } else if snap.committed > self.min
                    && forecast_backlog / (committed - 1.0).max(1.0) <= down_backlog_per_target
                {
                    ScaleDecision::Down(1)
                } else {
                    ScaleDecision::Hold
                }
            }
        };
        if decision != ScaleDecision::Hold {
            self.last_decision_ms = snap.now_ms;
        }
        decision
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::AutoscaleConfig;
    use crate::util::prop::{run_prop, Gen};

    fn engine(policy: ScalingPolicy, cooldown_ms: f64, min: usize, max: usize) -> PolicyEngine {
        let cfg = AutoscaleConfig {
            policy,
            cooldown_ms,
            eval_interval_ms: 500.0,
            provision_delay_ms: 1_000.0,
            ..AutoscaleConfig::default()
        };
        PolicyEngine::new(&cfg, min, max)
    }

    fn snap(now_ms: f64, committed: usize, queued: usize, busy: usize) -> CapacitySnapshot {
        CapacitySnapshot {
            now_ms,
            committed,
            active: committed,
            busy_active: busy,
            queued,
            backlog: queued,
            interactive_backlog: 0,
            arrival_rate_per_s: 10.0,
            completion_rate_per_s: 10.0,
        }
    }

    #[test]
    fn reactive_scales_up_on_queue_pressure_and_down_when_idle() {
        let mut e = engine(ScalingPolicy::default_reactive(), 0.0, 1, 4);
        // 2 targets, 20 queued → 10 per target > 6 → up.
        assert_eq!(e.decide(&snap(0.0, 2, 20, 2)), ScaleDecision::Up(1));
        // Mid-band: hold (hysteresis — neither threshold crossed).
        assert_eq!(e.decide(&snap(500.0, 3, 9, 3)), ScaleDecision::Hold);
        // Empty and idle → down.
        assert_eq!(e.decide(&snap(1_000.0, 3, 0, 0)), ScaleDecision::Down(1));
        // At the lower bound: never below min.
        assert_eq!(e.decide(&snap(1_500.0, 1, 0, 0)), ScaleDecision::Hold);
        // At the upper bound: never above max.
        assert_eq!(e.decide(&snap(2_000.0, 4, 99, 4)), ScaleDecision::Hold);
    }

    #[test]
    fn cooldown_suppresses_consecutive_decisions() {
        let mut e = engine(ScalingPolicy::default_reactive(), 2_000.0, 1, 8);
        assert_eq!(e.decide(&snap(0.0, 2, 40, 2)), ScaleDecision::Up(1));
        // Pressure persists but the cooldown window holds the line.
        assert_eq!(e.decide(&snap(500.0, 3, 40, 3)), ScaleDecision::Hold);
        assert_eq!(e.decide(&snap(1_999.0, 3, 40, 3)), ScaleDecision::Hold);
        // Cooldown elapsed → the next decision fires.
        assert_eq!(e.decide(&snap(2_000.0, 3, 40, 3)), ScaleDecision::Up(1));
    }

    #[test]
    fn scheduled_policy_never_decides() {
        let mut e = engine(ScalingPolicy::Scheduled, 0.0, 1, 4);
        assert_eq!(e.decide(&snap(0.0, 2, 500, 2)), ScaleDecision::Hold);
        assert_eq!(e.decide(&snap(500.0, 2, 0, 0)), ScaleDecision::Hold);
    }

    #[test]
    fn predictive_provisions_ahead_of_a_rising_trend() {
        let p = ScalingPolicy::Predictive {
            window_ticks: 3,
            up_backlog_per_target: 6.0,
            down_backlog_per_target: 1.0,
        };
        let mut e = engine(p, 0.0, 1, 4);
        // Arrival rate ramps 10 → 30 → 50 while completions stay at 10
        // and the *current* backlog is still small: the reactive rule
        // would hold, the forecast does not.
        let mut s = snap(0.0, 2, 0, 2);
        s.backlog = 2;
        s.arrival_rate_per_s = 10.0;
        assert_eq!(e.decide(&s), ScaleDecision::Hold);
        s.now_ms = 500.0;
        s.arrival_rate_per_s = 30.0;
        let _ = e.decide(&s);
        s.now_ms = 1_000.0;
        s.arrival_rate_per_s = 50.0;
        // slope = 40/s per 1000ms; lead 1500ms → forecast 110/s;
        // drift (110-10)·1.5 = 150 ≫ 6 per target.
        assert_eq!(e.decide(&s), ScaleDecision::Up(1));
    }

    #[test]
    fn predictive_shrinks_once_the_forecast_backlog_clears() {
        let p = ScalingPolicy::Predictive {
            window_ticks: 3,
            up_backlog_per_target: 6.0,
            down_backlog_per_target: 1.0,
        };
        let mut e = engine(p, 0.0, 1, 4);
        let mut s = snap(0.0, 3, 0, 0);
        s.backlog = 0;
        s.arrival_rate_per_s = 5.0;
        s.completion_rate_per_s = 20.0;
        assert_eq!(e.decide(&s), ScaleDecision::Down(1));
        // But never below min.
        s.now_ms = 500.0;
        s.committed = 1;
        assert_eq!(e.decide(&s), ScaleDecision::Hold);
    }

    #[test]
    fn validation_rejects_inverted_hysteresis_bands() {
        assert!(ScalingPolicy::Reactive {
            up_queue_depth: 2.0,
            down_queue_depth: 3.0,
            down_utilization: 0.5,
        }
        .validate()
        .is_err());
        assert!(ScalingPolicy::Reactive {
            up_queue_depth: 2.0,
            down_queue_depth: 1.0,
            down_utilization: 1.5,
        }
        .validate()
        .is_err());
        assert!(ScalingPolicy::Predictive {
            window_ticks: 1,
            up_backlog_per_target: 4.0,
            down_backlog_per_target: 1.0,
        }
        .validate()
        .is_err());
        assert!(ScalingPolicy::default_reactive().validate().is_ok());
    }

    #[test]
    fn json_roundtrip_all_kinds() {
        for p in [
            ScalingPolicy::default_reactive(),
            ScalingPolicy::Scheduled,
            ScalingPolicy::Predictive {
                window_ticks: 6,
                up_backlog_per_target: 8.0,
                down_backlog_per_target: 2.0,
            },
        ] {
            let j = p.to_canonical_json();
            let back = ScalingPolicy::from_json(&j).unwrap();
            assert_eq!(p, back);
            assert_eq!(
                j.to_string_canonical(),
                back.to_canonical_json().to_string_canonical()
            );
        }
        let typo = Json::obj()
            .with("kind", "reactive".into())
            .with("up_que_depth", 5.0.into());
        assert!(ScalingPolicy::from_json(&typo).unwrap_err().contains("unknown key"));
    }

    /// Property (ISSUE satellite): under arbitrary snapshots the engine
    /// never proposes leaving `[min, max]`, and decisions are never
    /// closer together than the cooldown.
    #[test]
    fn prop_decisions_respect_bounds_and_cooldown() {
        run_prop("policy engine bounds + cooldown", 50, |g: &mut Gen| {
            let min = g.usize_in(1, 3);
            let max = min + g.usize_in(0, 5);
            let cooldown = g.f64_in(0.0, 5_000.0);
            let policy = if g.bool_with(0.5) {
                ScalingPolicy::default_reactive()
            } else {
                ScalingPolicy::Predictive {
                    window_ticks: g.usize_in(2, 6),
                    up_backlog_per_target: g.f64_in(2.0, 10.0),
                    down_backlog_per_target: g.f64_in(0.0, 1.9),
                }
            };
            let mut e = engine(policy, cooldown, min, max);
            let mut committed = g.usize_in(min, max);
            let mut last_decision = f64::NEG_INFINITY;
            for tick in 0..200 {
                let now = tick as f64 * 500.0;
                let mut s = snap(now, committed, g.usize_in(0, 60), g.usize_in(0, committed));
                s.backlog = g.usize_in(0, 80);
                s.arrival_rate_per_s = g.f64_in(0.0, 100.0);
                s.completion_rate_per_s = g.f64_in(0.0, 100.0);
                match e.decide(&s) {
                    ScaleDecision::Up(n) => {
                        assert!(committed + n <= max, "up beyond max");
                        assert!(now - last_decision >= cooldown, "cooldown violated");
                        committed += n;
                        last_decision = now;
                    }
                    ScaleDecision::Down(n) => {
                        assert!(committed - n >= min, "down beyond min");
                        assert!(now - last_decision >= cooldown, "cooldown violated");
                        committed -= n;
                        last_decision = now;
                    }
                    ScaleDecision::Hold => {}
                }
            }
        });
    }
}
