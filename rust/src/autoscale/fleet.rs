//! The target-fleet lifecycle state machine.
//!
//! Each deployed target is in one of four states:
//!
//! ```text
//!          begin_up (provision)            finish_provision
//!   Off ────────────────────────► Provisioning ────────────► Active
//!    ▲                                                          │
//!    │ finish_drain                                   begin_down │
//!    └───────────────────────── Draining ◄──────────────────────┘
//!                                   │  begin_up (cancel drain)
//!                                   └───────────────────────► Active
//! ```
//!
//! [`Fleet`] owns the states, enforces the capacity bounds on every
//! transition (committed capacity — Active + Provisioning — never
//! leaves `[min, max]`; at least one target always stays serving), and
//! accounts cost: the *provisioned* count (everything not Off — you pay
//! for provisioning cold starts and draining tails too) is integrated
//! over time into target-seconds and recorded as a step series both
//! metric sinks fold into the windowed active-target-count series.
//!
//! The simulator drives the transitions and does the queue surgery
//! (re-routing a draining target's work); this module is pure state so
//! the invariants are unit-testable without an event loop.

use super::AutoscaleMetrics;

/// Lifecycle state of one deployed target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TargetState {
    /// Not provisioned: costs nothing, serves nothing.
    Off,
    /// Cold-starting: paid for, not yet accepting work.
    Provisioning,
    /// Serving.
    Active,
    /// Graceful scale-down: finishes in-flight work, accepts nothing
    /// new, still paid for until it turns off.
    Draining,
}

/// How a scale-up was satisfied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpKind {
    /// A draining target was reprieved: it is Active again immediately
    /// (no cold start — the hardware never shut down).
    CancelDrain(usize),
    /// An off target starts provisioning; it becomes Active after the
    /// configured cold-start delay.
    Provision(usize),
}

/// The elastic target fleet: states, bounds, and cost accounting.
pub struct Fleet {
    min: usize,
    max: usize,
    states: Vec<TargetState>,
    /// Provisioned-count step series `(at_ms, count)`; starts with the
    /// t=0 initial value, ends with the finalize marker.
    steps: Vec<(f64, u32)>,
    /// ∫ provisioned dt, in ms·targets.
    paid_target_ms: f64,
    last_ms: f64,
    scale_ups: u64,
    scale_downs: u64,
    peak: u32,
    finalized: bool,
}

impl Fleet {
    /// Fleet over `n_targets` deployed devices, `initial` of them
    /// Active at t=0. Bounds must already be validated
    /// (`min ≤ initial ≤ max ≤ n_targets`).
    pub fn new(n_targets: usize, min: usize, max: usize, initial: usize) -> Fleet {
        debug_assert!(min >= 1 && min <= initial && initial <= max && max <= n_targets);
        let states = (0..n_targets)
            .map(|i| {
                if i < initial {
                    TargetState::Active
                } else {
                    TargetState::Off
                }
            })
            .collect();
        Fleet {
            min,
            max,
            states,
            steps: vec![(0.0, initial as u32)],
            paid_target_ms: 0.0,
            last_ms: 0.0,
            scale_ups: 0,
            scale_downs: 0,
            peak: initial as u32,
            finalized: false,
        }
    }

    /// State of one target (ids beyond the fleet read as Off).
    pub fn state(&self, tid: usize) -> TargetState {
        self.states.get(tid).copied().unwrap_or(TargetState::Off)
    }

    /// Committed capacity: Active + Provisioning.
    pub fn committed(&self) -> usize {
        self.states
            .iter()
            .filter(|s| matches!(s, TargetState::Active | TargetState::Provisioning))
            .count()
    }

    /// Targets currently accepting work.
    pub fn n_active(&self) -> usize {
        self.states
            .iter()
            .filter(|s| matches!(s, TargetState::Active))
            .count()
    }

    /// Provisioned (paid-for) capacity: everything not Off.
    pub fn provisioned(&self) -> usize {
        self.states
            .iter()
            .filter(|s| !matches!(s, TargetState::Off))
            .count()
    }

    /// The provisioned-count step series recorded so far.
    pub fn steps(&self) -> &[(f64, u32)] {
        &self.steps
    }

    /// Advance the cost integral to `now` at the current provisioned
    /// count. Time never runs backwards (same-time events integrate a
    /// zero-length segment).
    fn accrue(&mut self, now: f64) {
        let now = now.max(self.last_ms);
        self.paid_target_ms += self.provisioned() as f64 * (now - self.last_ms);
        self.last_ms = now;
    }

    fn record_step(&mut self, now: f64) {
        let paid = self.provisioned() as u32;
        self.peak = self.peak.max(paid);
        self.steps.push((now, paid));
    }

    /// Begin one scale-up at `now`. Prefers reprieving a draining
    /// target (its hardware never left); otherwise starts provisioning
    /// the lowest-indexed off target. `None` when the committed bound
    /// or the physical fleet is exhausted. The provisioned count only
    /// steps for a fresh provision — a drain cancellation was already
    /// being paid for.
    pub fn begin_up(&mut self, now: f64) -> Option<UpKind> {
        if self.committed() + 1 > self.max {
            return None;
        }
        if let Some(tid) = self
            .states
            .iter()
            .position(|s| matches!(s, TargetState::Draining))
        {
            self.accrue(now);
            self.states[tid] = TargetState::Active;
            self.scale_ups += 1;
            return Some(UpKind::CancelDrain(tid));
        }
        let tid = self
            .states
            .iter()
            .position(|s| matches!(s, TargetState::Off))?;
        self.accrue(now);
        self.states[tid] = TargetState::Provisioning;
        self.scale_ups += 1;
        self.record_step(now);
        Some(UpKind::Provision(tid))
    }

    /// A provisioning target finished its cold start. Returns whether a
    /// transition happened (false if the target was not provisioning —
    /// a stale event).
    pub fn finish_provision(&mut self, now: f64, tid: usize) -> bool {
        if self.state(tid) != TargetState::Provisioning {
            return false;
        }
        self.accrue(now);
        self.states[tid] = TargetState::Active;
        true
    }

    /// Begin one graceful scale-down at `now`: the highest-indexed
    /// active target starts draining (deterministic victim choice).
    /// Refused when it would take committed capacity below `min` or
    /// leave no serving target (provisioning replacements are not yet
    /// accepting work).
    pub fn begin_down(&mut self, now: f64) -> Option<usize> {
        if self.committed() <= self.min || self.n_active() <= 1 {
            return None;
        }
        let tid = self
            .states
            .iter()
            .rposition(|s| matches!(s, TargetState::Active))?;
        self.accrue(now);
        self.states[tid] = TargetState::Draining;
        self.scale_downs += 1;
        // Paid count unchanged: a draining target still costs money
        // until it actually turns off.
        Some(tid)
    }

    /// A draining target emptied out: turn it off (this is when the
    /// meter stops). No-op if the target is not draining (e.g. its
    /// drain was cancelled by a scale-up).
    pub fn finish_drain(&mut self, now: f64, tid: usize) {
        if self.state(tid) != TargetState::Draining {
            return;
        }
        self.accrue(now);
        self.states[tid] = TargetState::Off;
        self.record_step(now);
    }

    /// Close the books at the end of the run: integrate the final
    /// segment and append the end-of-run step marker both metric sinks
    /// need to bound the windowed capacity series. Idempotent.
    pub fn finalize(&mut self, now: f64) {
        if self.finalized {
            return;
        }
        self.finalized = true;
        self.accrue(now);
        self.record_step(now);
    }

    /// Fold the accounting into the end-of-run metrics.
    pub fn metrics(&self, cost_per_target_s: f64, completed_tokens: u64) -> AutoscaleMetrics {
        let target_seconds = self.paid_target_ms / 1_000.0;
        let cost = target_seconds * cost_per_target_s;
        AutoscaleMetrics {
            target_seconds,
            cost,
            cost_per_1k_tokens: if completed_tokens == 0 {
                f64::NAN
            } else {
                cost / (completed_tokens as f64 / 1_000.0)
            },
            scale_up_events: self.scale_ups,
            scale_down_events: self.scale_downs,
            peak_provisioned: self.peak,
            final_provisioned: self.provisioned() as u32,
            steps: self.steps.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, Gen};

    #[test]
    fn initial_fleet_splits_active_and_off() {
        let f = Fleet::new(4, 1, 4, 2);
        assert_eq!(f.state(0), TargetState::Active);
        assert_eq!(f.state(1), TargetState::Active);
        assert_eq!(f.state(2), TargetState::Off);
        assert_eq!(f.state(9), TargetState::Off);
        assert_eq!(f.committed(), 2);
        assert_eq!(f.provisioned(), 2);
        assert_eq!(f.steps(), &[(0.0, 2)]);
    }

    #[test]
    fn up_provisions_then_activates_and_steps_once() {
        let mut f = Fleet::new(4, 1, 4, 2);
        let up = f.begin_up(1_000.0).unwrap();
        assert_eq!(up, UpKind::Provision(2));
        assert_eq!(f.state(2), TargetState::Provisioning);
        assert_eq!(f.committed(), 3);
        assert_eq!(f.provisioned(), 3);
        assert_eq!(f.steps().last(), Some(&(1_000.0, 3)));
        assert!(f.finish_provision(2_000.0, 2));
        assert_eq!(f.state(2), TargetState::Active);
        // No extra step for activation: the paid count did not change.
        assert_eq!(f.steps().len(), 2);
        // Stale event: no-op.
        assert!(!f.finish_provision(2_500.0, 2));
    }

    #[test]
    fn down_drains_highest_index_and_steps_at_shutoff() {
        let mut f = Fleet::new(4, 1, 4, 3);
        let tid = f.begin_down(1_000.0).unwrap();
        assert_eq!(tid, 2, "highest-indexed active target drains first");
        assert_eq!(f.state(2), TargetState::Draining);
        assert_eq!(f.committed(), 2);
        assert_eq!(f.provisioned(), 3, "draining still paid");
        assert_eq!(f.steps().len(), 1, "no step until the meter stops");
        f.finish_drain(3_000.0, 2);
        assert_eq!(f.state(2), TargetState::Off);
        assert_eq!(f.provisioned(), 2);
        assert_eq!(f.steps().last(), Some(&(3_000.0, 2)));
    }

    #[test]
    fn up_cancels_a_drain_before_paying_for_a_cold_start() {
        let mut f = Fleet::new(4, 1, 4, 3);
        let tid = f.begin_down(1_000.0).unwrap();
        let up = f.begin_up(1_500.0).unwrap();
        assert_eq!(up, UpKind::CancelDrain(tid));
        assert_eq!(f.state(tid), TargetState::Active);
        assert_eq!(f.steps().len(), 1, "cancelled drain never changed the paid count");
        // finish_drain after a cancellation is a stale no-op.
        f.finish_drain(2_000.0, tid);
        assert_eq!(f.state(tid), TargetState::Active);
    }

    #[test]
    fn bounds_are_enforced() {
        let mut f = Fleet::new(3, 2, 3, 2);
        assert!(f.begin_up(0.0).is_some());
        assert!(f.begin_up(1.0).is_none(), "max reached");
        assert!(f.begin_down(2.0).is_some());
        assert!(f.begin_down(3.0).is_none(), "min reached");
        // Never drain the last serving target, even above min.
        let mut f = Fleet::new(4, 1, 4, 2);
        assert!(f.begin_up(0.0).is_some()); // 2 active + 1 provisioning
        let first = f.begin_down(1.0);
        assert!(first.is_some());
        assert!(
            f.begin_down(2.0).is_none(),
            "one serving target must remain while the replacement cold-starts"
        );
    }

    #[test]
    fn cost_integrates_the_paid_step_function() {
        let mut f = Fleet::new(4, 1, 4, 2);
        f.begin_up(1_000.0); // 2 targets × 1 s
        f.finish_provision(1_500.0, 2);
        f.begin_down(2_000.0); // 3 targets × 1 s
        f.finish_drain(3_000.0, 2); // 3 targets × 1 s (draining is paid)
        f.finalize(5_000.0); // 2 targets × 2 s
        let m = f.metrics(2.0, 4_000);
        // 2·1 + 3·1 + 3·1 + 2·2 = 12 target-seconds.
        assert!((m.target_seconds - 12.0).abs() < 1e-9, "{}", m.target_seconds);
        assert!((m.cost - 24.0).abs() < 1e-9);
        assert!((m.cost_per_1k_tokens - 6.0).abs() < 1e-9);
        assert_eq!(m.scale_up_events, 1);
        assert_eq!(m.scale_down_events, 1);
        assert_eq!(m.peak_provisioned, 3);
        assert_eq!(m.final_provisioned, 2);
        assert_eq!(m.steps.last(), Some(&(5_000.0, 2)));
        // Finalize is idempotent.
        f.finalize(9_000.0);
        assert!((f.metrics(2.0, 4_000).target_seconds - 12.0).abs() < 1e-9);
    }

    #[test]
    fn zero_tokens_yield_nan_cost_per_1k() {
        let mut f = Fleet::new(2, 1, 2, 1);
        f.finalize(1_000.0);
        assert!(f.metrics(1.0, 0).cost_per_1k_tokens.is_nan());
    }

    /// Property (ISSUE satellite): under arbitrary valid transition
    /// sequences the provisioned count recorded in the step series
    /// never leaves `[min, max]`, committed capacity stays in bounds,
    /// and at least one target keeps serving.
    #[test]
    fn prop_fleet_never_leaves_bounds() {
        run_prop("fleet capacity bounds", 60, |g: &mut Gen| {
            let n = g.usize_in(2, 8);
            let min = g.usize_in(1, n);
            let max = g.usize_in(min, n);
            let initial = g.usize_in(min, max);
            let mut f = Fleet::new(n, min, max, initial);
            let mut pending: Vec<usize> = Vec::new(); // provisioning
            let mut draining: Vec<usize> = Vec::new();
            for tick in 0..120 {
                let now = tick as f64 * 100.0;
                match g.usize_in(0, 3) {
                    0 => match f.begin_up(now) {
                        Some(UpKind::Provision(tid)) => pending.push(tid),
                        Some(UpKind::CancelDrain(tid)) => draining.retain(|&x| x != tid),
                        None => {}
                    },
                    1 => {
                        if let Some(tid) = f.begin_down(now) {
                            draining.push(tid);
                        }
                    }
                    2 => {
                        if let Some(tid) = pending.pop() {
                            assert!(f.finish_provision(now, tid));
                        }
                    }
                    _ => {
                        if let Some(tid) = draining.pop() {
                            f.finish_drain(now, tid);
                        }
                    }
                }
                assert!(f.committed() >= min && f.committed() <= max, "committed bounds");
                assert!(f.n_active() >= 1, "a serving target must always remain");
                assert!(f.provisioned() <= max, "paid capacity above max");
            }
            f.finalize(120.0 * 100.0);
            for &(_, c) in f.steps() {
                assert!(
                    (c as usize) >= min && (c as usize) <= max,
                    "step series left [{min}, {max}]: {c}"
                );
            }
            let m = f.metrics(1.0, 10);
            assert!(m.target_seconds >= 0.0 && m.target_seconds.is_finite());
        });
    }
}
