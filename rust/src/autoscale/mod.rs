//! The elastic-capacity subsystem: autoscaling target pools.
//!
//! The paper's north star is *agile* edge–cloud serving, but a fixed
//! target fleet frozen at t=0 cannot express the provisioning side of
//! agility (DiP-SD and the heterogeneous-edge speculative-decoding line
//! study exactly this interaction). This module makes the cloud pool
//! elastic:
//!
//! * [`AutoscaleConfig`] — the `autoscale:` block of a
//!   [`SimConfig`](crate::config::SimConfig): capacity bounds, the
//!   evaluation tick, cold-start provisioning delay, cooldown, and the
//!   per-target-second cost rate. A config without the block behaves —
//!   byte for byte, including canonical JSON and sweep cache keys —
//!   exactly like the pre-autoscale simulator.
//! * [`ScalingPolicy`] / [`PolicyEngine`] ([`policy`]) — pluggable
//!   scale-up/scale-down decision rules evaluated on a fixed tick:
//!   reactive queue-depth/utilization thresholds with hysteresis and
//!   cooldown, a scheduled policy driven purely by scripted
//!   `target_pool_up` / `target_pool_down` scenario events, and a
//!   predictive policy that extrapolates the windowed arrival-rate
//!   trend one provisioning lead ahead.
//! * [`Fleet`] ([`fleet`]) — the per-target lifecycle state machine
//!   (Off → Provisioning → Active → Draining → Off) with bound-checked
//!   transitions, the provisioned-capacity step series, and
//!   target-second cost accounting folded into [`AutoscaleMetrics`].
//!
//! The simulator applies fleet transitions through
//! [`RuntimeDynamics`](crate::scenario::RuntimeDynamics) (live
//! per-target availability), drains scale-downs gracefully (in-flight
//! batches finish; queued work re-routes through the configured routing
//! policy), and surfaces everything via `dsd simulate --autoscale`, the
//! `autoscale` sweep axis, and the `dsd reproduce elasticity` family.

pub mod fleet;
pub mod policy;

pub use fleet::{Fleet, TargetState, UpKind};
pub use policy::{CapacitySnapshot, PolicyEngine, ScaleDecision, ScalingPolicy};

use crate::util::json::Json;
use crate::util::yaml;

/// The `autoscale:` configuration block: capacity bounds, tick timing,
/// and cost accounting for an elastic target pool.
#[derive(Clone, Debug, PartialEq)]
pub struct AutoscaleConfig {
    /// Name (sweep axis label; defaults to `"autoscale"`, or the file
    /// stem when loaded from a file).
    pub name: String,
    /// The scaling decision rule.
    pub policy: ScalingPolicy,
    /// Lower capacity bound (committed targets never fall below this).
    pub min_targets: usize,
    /// Upper capacity bound; `None` = every deployed target.
    pub max_targets: Option<usize>,
    /// Targets active at t=0; `None` = the resolved maximum.
    pub initial_targets: Option<usize>,
    /// Policy evaluation tick, ms.
    pub eval_interval_ms: f64,
    /// Minimum spacing between policy-initiated scaling decisions, ms
    /// (scripted scenario events bypass it — an operator override).
    pub cooldown_ms: f64,
    /// Cold-start delay between a scale-up decision and the new target
    /// accepting work, ms. Provisioning capacity is already paid for.
    pub provision_delay_ms: f64,
    /// Cost rate, per target-second (folds into
    /// [`AutoscaleMetrics::cost`] and cost-per-1k-tokens).
    pub cost_per_target_s: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            name: "autoscale".into(),
            policy: ScalingPolicy::default_reactive(),
            min_targets: 1,
            max_targets: None,
            initial_targets: None,
            eval_interval_ms: 500.0,
            cooldown_ms: 2_000.0,
            provision_delay_ms: 1_500.0,
            cost_per_target_s: 1.0,
        }
    }
}

impl AutoscaleConfig {
    /// Parse an autoscale YAML document:
    ///
    /// ```yaml
    /// policy:
    ///   kind: reactive
    ///   up_queue_depth: 6
    ///   down_queue_depth: 1
    ///   down_utilization: 0.35
    /// min_targets: 1
    /// max_targets: 4
    /// initial_targets: 2
    /// eval_interval_ms: 500
    /// cooldown_ms: 2000
    /// provision_delay_ms: 1500
    /// cost_per_target_s: 1.0
    /// ```
    pub fn from_yaml(text: &str) -> Result<AutoscaleConfig, String> {
        let doc = yaml::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&doc)
    }

    /// Load from a YAML file; the file stem becomes the name when the
    /// document has no `name:` key.
    pub fn from_yaml_file(path: &str) -> Result<AutoscaleConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let mut a = Self::from_yaml(&text)?;
        if a.name == "autoscale" {
            if let Some(stem) = std::path::Path::new(path)
                .file_stem()
                .and_then(|x| x.to_str())
            {
                a.name = stem.to_string();
            }
        }
        Ok(a)
    }

    /// Parse from a decoded document (the `autoscale:` block of a
    /// `SimConfig` shares this schema). Strict: unknown keys are
    /// rejected — a typo'd bound would otherwise silently fall back to a
    /// default while still labeling and cache-keying the cell.
    pub fn from_json(doc: &Json) -> Result<AutoscaleConfig, String> {
        const KNOWN: &[&str] = &[
            "name",
            "policy",
            "min_targets",
            "max_targets",
            "initial_targets",
            "eval_interval_ms",
            "cooldown_ms",
            "provision_delay_ms",
            "cost_per_target_s",
        ];
        if let Json::Obj(pairs) = doc {
            for (k, _) in pairs {
                if !KNOWN.contains(&k.as_str()) {
                    return Err(format!(
                        "autoscale: unknown key '{k}' (known: {})",
                        KNOWN.join(", ")
                    ));
                }
            }
        } else {
            return Err("autoscale: expected a mapping".into());
        }
        let mut a = AutoscaleConfig::default();
        if let Some(n) = doc.get("name").and_then(Json::as_str) {
            a.name = n.to_string();
        }
        if let Some(p) = doc.get("policy") {
            a.policy = ScalingPolicy::from_json(p)?;
        }
        let num = |key: &str, default: f64| -> Result<f64, String> {
            match doc.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| format!("autoscale: '{key}' must be a number")),
            }
        };
        let opt_usize = |key: &str| -> Result<Option<usize>, String> {
            match doc.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_usize()
                    .map(Some)
                    .ok_or_else(|| format!("autoscale: '{key}' must be a count")),
            }
        };
        if let Some(m) = opt_usize("min_targets")? {
            a.min_targets = m;
        }
        a.max_targets = opt_usize("max_targets")?;
        a.initial_targets = opt_usize("initial_targets")?;
        a.eval_interval_ms = num("eval_interval_ms", a.eval_interval_ms)?;
        a.cooldown_ms = num("cooldown_ms", a.cooldown_ms)?;
        a.provision_delay_ms = num("provision_delay_ms", a.provision_delay_ms)?;
        a.cost_per_target_s = num("cost_per_target_s", a.cost_per_target_s)?;
        a.validate_shape()?;
        Ok(a)
    }

    /// Canonical JSON: fixed key order, optional bounds emitted only
    /// when set. Part of
    /// [`SimConfig::to_canonical_json`](crate::config::SimConfig) — and
    /// therefore of the sweep cell cache key — whenever the block is
    /// attached; autoscale-free configs serialize exactly as before.
    pub fn to_canonical_json(&self) -> Json {
        let mut j = Json::obj()
            .with("name", self.name.as_str().into())
            .with("policy", self.policy.to_canonical_json())
            .with("min_targets", self.min_targets.into());
        if let Some(m) = self.max_targets {
            j.set("max_targets", m.into());
        }
        if let Some(m) = self.initial_targets {
            j.set("initial_targets", m.into());
        }
        j.with("eval_interval_ms", self.eval_interval_ms.into())
            .with("cooldown_ms", self.cooldown_ms.into())
            .with("provision_delay_ms", self.provision_delay_ms.into())
            .with("cost_per_target_s", self.cost_per_target_s.into())
    }

    /// Upper capacity bound resolved against the deployment size.
    pub fn resolved_max(&self, n_targets: usize) -> usize {
        self.max_targets.unwrap_or(n_targets)
    }

    /// Initial active count resolved against the deployment size.
    pub fn resolved_initial(&self, n_targets: usize) -> usize {
        self.initial_targets
            .unwrap_or_else(|| self.resolved_max(n_targets))
    }

    /// Deployment-independent sanity checks (run at parse time).
    fn validate_shape(&self) -> Result<(), String> {
        self.policy.validate()?;
        if self.min_targets == 0 {
            return Err("autoscale: min_targets must be at least 1".into());
        }
        let pos = |name: &str, x: f64| -> Result<(), String> {
            if !x.is_finite() || x <= 0.0 {
                return Err(format!("autoscale: {name} must be finite and positive"));
            }
            Ok(())
        };
        let non_neg = |name: &str, x: f64| -> Result<(), String> {
            if !x.is_finite() || x < 0.0 {
                return Err(format!("autoscale: {name} must be finite and ≥ 0"));
            }
            Ok(())
        };
        pos("eval_interval_ms", self.eval_interval_ms)?;
        non_neg("cooldown_ms", self.cooldown_ms)?;
        non_neg("provision_delay_ms", self.provision_delay_ms)?;
        non_neg("cost_per_target_s", self.cost_per_target_s)
    }

    /// Full validation against the deployment shape (from
    /// [`SimConfig::validate`](crate::config::SimConfig)).
    pub fn validate(&self, n_targets: usize) -> Result<(), String> {
        self.validate_shape()?;
        let max = self.resolved_max(n_targets);
        if max > n_targets {
            return Err(format!(
                "autoscale: max_targets {max} exceeds the {n_targets} deployed targets \
                 (declare more targets in cluster.targets — the pool lists the physical \
                 fleet; autoscale chooses how much of it is provisioned)"
            ));
        }
        if self.min_targets > max {
            return Err(format!(
                "autoscale: min_targets {} exceeds max_targets {max}",
                self.min_targets
            ));
        }
        let initial = self.resolved_initial(n_targets);
        if initial < self.min_targets || initial > max {
            return Err(format!(
                "autoscale: initial_targets {initial} outside [{}, {max}]",
                self.min_targets
            ));
        }
        Ok(())
    }
}

/// End-of-run elastic-capacity accounting, reported (only) for
/// autoscale-bearing runs in both metric sinks'
/// [`SystemMetrics`](crate::metrics::SystemMetrics) and carried by
/// autoscale-bearing sweep cells.
#[derive(Clone, Debug, PartialEq)]
pub struct AutoscaleMetrics {
    /// ∫ provisioned-target count dt over the run, in target-seconds —
    /// provisioning and draining targets are paid for too.
    pub target_seconds: f64,
    /// `target_seconds × cost_per_target_s`.
    pub cost: f64,
    /// Cost per 1 000 generated tokens (NaN when nothing completed).
    pub cost_per_1k_tokens: f64,
    /// Scale-up decisions applied (including drain cancellations and
    /// scripted `target_pool_up` events).
    pub scale_up_events: u64,
    /// Scale-down decisions applied (drain starts).
    pub scale_down_events: u64,
    /// Largest provisioned count observed.
    pub peak_provisioned: u32,
    /// Provisioned count at the end of the run.
    pub final_provisioned: u32,
    /// The provisioned-capacity step series `(at_ms, count)`: one entry
    /// per change plus the t=0 initial value and an end-of-run marker.
    /// Both metric sinks integrate this into the windowed
    /// active-target-count series (parity-locked).
    pub steps: Vec<(f64, u32)>,
}

impl AutoscaleMetrics {
    /// JSON encoding (insertion-ordered keys, deterministic).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("target_seconds", self.target_seconds.into())
            .with("cost", self.cost.into())
            .with("cost_per_1k_tokens", self.cost_per_1k_tokens.into())
            .with("scale_up_events", self.scale_up_events.into())
            .with("scale_down_events", self.scale_down_events.into())
            .with("peak_provisioned", (self.peak_provisioned as u64).into())
            .with("final_provisioned", (self.final_provisioned as u64).into())
            .with(
                "steps",
                Json::Arr(
                    self.steps
                        .iter()
                        .map(|&(t, c)| Json::Arr(vec![t.into(), (c as u64).into()]))
                        .collect(),
                ),
            )
    }

    /// Decode a snapshot previously written by
    /// [`AutoscaleMetrics::to_json`] (the sweep cell-cache load path).
    /// `None` on any missing or mistyped field.
    pub fn from_json(j: &Json) -> Option<AutoscaleMetrics> {
        let steps = j
            .get("steps")?
            .as_arr()?
            .iter()
            .map(|s| {
                let pair = s.as_arr()?;
                if pair.len() != 2 {
                    return None;
                }
                Some((pair[0].as_f64()?, pair[1].as_u64()? as u32))
            })
            .collect::<Option<Vec<_>>>()?;
        Some(AutoscaleMetrics {
            target_seconds: j.get("target_seconds")?.as_f64()?,
            cost: j.get("cost")?.as_f64()?,
            cost_per_1k_tokens: j.get("cost_per_1k_tokens")?.as_f64_or_nan()?,
            scale_up_events: j.get("scale_up_events")?.as_u64()?,
            scale_down_events: j.get("scale_down_events")?.as_u64()?,
            peak_provisioned: j.get("peak_provisioned")?.as_u64()? as u32,
            final_provisioned: j.get("final_provisioned")?.as_u64()? as u32,
            steps,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const REACTIVE: &str = "\
name: burst-pool
policy:
  kind: reactive
  up_queue_depth: 6
  down_queue_depth: 1
  down_utilization: 0.35
min_targets: 1
max_targets: 4
initial_targets: 2
eval_interval_ms: 250
cooldown_ms: 1000
provision_delay_ms: 800
cost_per_target_s: 2.5
";

    #[test]
    fn yaml_parses_and_resolves_bounds() {
        let a = AutoscaleConfig::from_yaml(REACTIVE).unwrap();
        assert_eq!(a.name, "burst-pool");
        assert!(matches!(a.policy, ScalingPolicy::Reactive { .. }));
        assert_eq!(a.min_targets, 1);
        assert_eq!(a.resolved_max(8), 4);
        assert_eq!(a.resolved_initial(8), 2);
        a.validate(4).unwrap();
        // Defaults: bounds resolve to the deployment.
        let d = AutoscaleConfig::from_yaml("policy:\n  kind: scheduled\n").unwrap();
        assert_eq!(d.resolved_max(6), 6);
        assert_eq!(d.resolved_initial(6), 6);
        d.validate(6).unwrap();
    }

    #[test]
    fn unknown_keys_rejected() {
        let err = AutoscaleConfig::from_yaml("min_targts: 1\n").unwrap_err();
        assert!(err.contains("unknown key"), "{err}");
        let err = AutoscaleConfig::from_yaml("policy:\n  kind: nope\n").unwrap_err();
        assert!(err.contains("unknown"), "{err}");
    }

    #[test]
    fn canonical_json_roundtrip_is_stable() {
        for y in [
            REACTIVE,
            "policy:\n  kind: scheduled\n",
            "policy:\n  kind: predictive\n  window_ticks: 5\nmax_targets: 3\n",
        ] {
            let a = AutoscaleConfig::from_yaml(y).unwrap();
            let j = a.to_canonical_json();
            let back = AutoscaleConfig::from_json(&j).unwrap();
            assert_eq!(a, back);
            assert_eq!(
                j.to_string_canonical(),
                back.to_canonical_json().to_string_canonical()
            );
        }
    }

    #[test]
    fn validation_checks_bounds_against_deployment() {
        let a = AutoscaleConfig::from_yaml("max_targets: 6\n").unwrap();
        assert!(a.validate(4).unwrap_err().contains("exceeds"));
        let a = AutoscaleConfig::from_yaml("min_targets: 3\nmax_targets: 2\n").unwrap();
        assert!(a.validate(4).is_err());
        let a = AutoscaleConfig::from_yaml("min_targets: 2\ninitial_targets: 1\n").unwrap();
        assert!(a.validate(4).unwrap_err().contains("initial_targets"));
        assert!(AutoscaleConfig::from_yaml("min_targets: 0\n").is_err());
        assert!(AutoscaleConfig::from_yaml("eval_interval_ms: 0\n").is_err());
        assert!(AutoscaleConfig::from_yaml("cooldown_ms: -1\n").is_err());
    }

    #[test]
    fn metrics_json_roundtrip() {
        let m = AutoscaleMetrics {
            target_seconds: 12.5,
            cost: 25.0,
            cost_per_1k_tokens: 0.8,
            scale_up_events: 3,
            scale_down_events: 2,
            peak_provisioned: 4,
            final_provisioned: 2,
            steps: vec![(0.0, 2), (1_000.0, 3), (5_000.0, 2), (9_000.0, 2)],
        };
        let back = AutoscaleMetrics::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
        // NaN cost-per-token (no tokens) survives via the null convention.
        let empty = AutoscaleMetrics {
            cost_per_1k_tokens: f64::NAN,
            ..m.clone()
        };
        let back = AutoscaleMetrics::from_json(&empty.to_json()).unwrap();
        assert!(back.cost_per_1k_tokens.is_nan());
        assert!(AutoscaleMetrics::from_json(&Json::obj()).is_none());
    }
}
