//! Per-request and system-level metric records and the end-of-run report.

use super::sink::{drafter_pool_of, ClassSummary, GammaSummary, GroupSummary, SloSummary};
use super::timeseries::{
    integrate_capacity_segment, TimeSeriesConfig, TimeSeriesSummary, WindowSummary,
};
use crate::autoscale::AutoscaleMetrics;
use crate::util::json::Json;
use crate::util::stats::{mean, percentile};

/// Everything the analyzer records about one completed request
/// (paper §3.5, "Per-Request Metrics").
#[derive(Clone, Debug)]
pub struct RequestMetrics {
    /// Request id (trace order).
    pub id: usize,
    /// Arrival time, ms.
    pub arrival_ms: f64,
    /// Time-to-first-token, ms.
    pub ttft_ms: f64,
    /// Time-per-output-token (decode phase), ms.
    pub tpot_ms: f64,
    /// End-to-end latency, ms.
    pub e2e_ms: f64,
    /// Final draft-token acceptance ratio (NaN in fused mode).
    pub acceptance: f64,
    /// Routing decision: target server id.
    pub target_id: usize,
    /// Drafter id.
    pub drafter_id: usize,
    /// Output tokens generated.
    pub output_tokens: u32,
    /// Sequence of window-size decisions (γ per verification round).
    pub gamma_decisions: Vec<u32>,
    /// Rounds executed in fused mode.
    pub fused_rounds: u32,
    /// Request-class index (tier position in the `classes:` block; 0 for
    /// single-tenant runs). Serialized only when nonzero, so classless
    /// per-request dumps keep their historical bytes.
    pub class_id: usize,
}

impl RequestMetrics {
    /// Serialize to the analyzer's JSON schema.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("id", self.id.into())
            .with("arrival_ms", self.arrival_ms.into())
            .with("ttft_ms", self.ttft_ms.into())
            .with("tpot_ms", self.tpot_ms.into())
            .with("e2e_ms", self.e2e_ms.into())
            .with("acceptance", self.acceptance.into())
            .with("target_id", self.target_id.into())
            .with("drafter_id", self.drafter_id.into())
            .with("output_tokens", (self.output_tokens as u64).into())
            .with(
                "gamma_decisions",
                Json::Arr(
                    self.gamma_decisions
                        .iter()
                        .map(|&g| Json::Num(g as f64))
                        .collect(),
                ),
            )
            .with("fused_rounds", (self.fused_rounds as u64).into());
        if self.class_id != 0 {
            j.set("class_id", self.class_id.into());
        }
        j
    }
}

/// System-level aggregates (paper §3.5, "System-Level Metrics").
#[derive(Clone, Debug, Default)]
pub struct SystemMetrics {
    /// Steady-state throughput, requests per second: the interquartile
    /// completion rate `0.5·N / (t75 − t25)`. Robust to warm-up and to
    /// straggler tails (a completions-per-total-duration ratio would be
    /// dominated by the longest request).
    ///
    /// **Stationarity caveat:** the interquartile estimator assumes the
    /// completion process is (roughly) stationary between its 25th and
    /// 75th completion percentiles. Under scripted dynamics — flash
    /// crowds, link flaps, pool failures (`scenario:` configs) — that
    /// assumption fails and this single number averages over regimes
    /// that were deliberately made different. Non-stationary analyses
    /// (e.g. the `agility` experiment family) must use the windowed
    /// alternative instead: [`SimReport::time_series`] /
    /// [`TimeSeriesSummary::mean_throughput_between`].
    pub throughput_rps: f64,
    /// Completed requests / total simulated duration (the naive ratio).
    pub total_throughput_rps: f64,
    /// Token throughput, output tokens per second.
    pub token_throughput: f64,
    /// Mean busy fraction across target devices.
    pub target_utilization: f64,
    /// Mean time requests spent queued at targets, ms.
    pub mean_queue_delay_ms: f64,
    /// Mean network delay per verification round-trip, ms.
    pub mean_net_delay_ms: f64,
    /// Total simulated duration, ms.
    pub sim_duration_ms: f64,
    /// Completed requests.
    pub completed: usize,
    /// Events processed by the DES engine (perf accounting).
    pub events_processed: u64,
    /// Wall-clock time the simulation took, ms (perf accounting).
    pub wall_ms: f64,
    /// Mean WC-DNN feature vector observed at window-decision time
    /// `[q_depth_util, α_recent, RTT_recent, TPOT_recent, γ_prev]` —
    /// consumed by the AWC training-dataset generator (paper §4.2).
    pub mean_features: [f64; 5],
    /// Draft tokens thrown away by pipelined execution: speculative
    /// windows invalidated by a rejection (or request completion)
    /// before their verdict arrived. Always 0 under `execution:
    /// sequential`, so sequential reports keep their historical bytes
    /// (serialized only when work was actually wasted).
    pub wasted_draft_tokens: u64,
    /// Uplink transmission time spent shipping those invalidated
    /// windows, ms (draft-only invalidations contribute 0 here).
    pub wasted_uplink_ms: f64,
    /// Elastic-capacity accounting (target-seconds, cost, the
    /// provisioned-count step series) — present only for runs with an
    /// `autoscale:` block, so autoscale-free reports keep their
    /// historical bytes. See [`crate::autoscale`].
    pub autoscale: Option<AutoscaleMetrics>,
}

/// SLO thresholds for goodput-style evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloSpec {
    /// TTFT limit, ms.
    pub ttft_ms: f64,
    /// TPOT limit, ms.
    pub tpot_ms: f64,
}

impl SloSpec {
    /// Interactive-tier default: first token within a second, tokens
    /// faster than reading speed. One of the two thresholds the
    /// streaming sink counts by default.
    pub const INTERACTIVE: SloSpec = SloSpec { ttft_ms: 1_000.0, tpot_ms: 50.0 };
    /// Relaxed batch-ish tier (the second default streaming threshold).
    pub const RELAXED: SloSpec = SloSpec { ttft_ms: 2_500.0, tpot_ms: 100.0 };
}

/// Complete end-of-run report.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    /// Per-request records (completed requests only), trace order.
    pub requests: Vec<RequestMetrics>,
    /// System aggregates.
    pub system: SystemMetrics,
}

impl SimReport {
    /// Mean TTFT, ms.
    pub fn mean_ttft(&self) -> f64 {
        mean(&self.requests.iter().map(|r| r.ttft_ms).collect::<Vec<_>>())
    }

    /// Mean TPOT, ms.
    pub fn mean_tpot(&self) -> f64 {
        mean(&self.requests.iter().map(|r| r.tpot_ms).collect::<Vec<_>>())
    }

    /// Mean end-to-end latency, ms.
    pub fn mean_e2e(&self) -> f64 {
        mean(&self.requests.iter().map(|r| r.e2e_ms).collect::<Vec<_>>())
    }

    /// Percentile of TTFT.
    pub fn p_ttft(&self, q: f64) -> f64 {
        percentile(&self.requests.iter().map(|r| r.ttft_ms).collect::<Vec<_>>(), q)
    }

    /// Percentile of TPOT.
    pub fn p_tpot(&self, q: f64) -> f64 {
        percentile(&self.requests.iter().map(|r| r.tpot_ms).collect::<Vec<_>>(), q)
    }

    /// Mean acceptance over requests that speculated.
    pub fn mean_acceptance(&self) -> f64 {
        let xs: Vec<f64> = self
            .requests
            .iter()
            .map(|r| r.acceptance)
            .filter(|a| a.is_finite())
            .collect();
        mean(&xs)
    }

    /// Mean window size across all decisions.
    pub fn mean_gamma(&self) -> f64 {
        let xs: Vec<f64> = self
            .requests
            .iter()
            .flat_map(|r| r.gamma_decisions.iter().map(|&g| g as f64))
            .collect();
        mean(&xs)
    }

    /// Fraction of requests meeting both SLO limits (goodput basis).
    pub fn slo_attainment(&self, slo: SloSpec) -> f64 {
        if self.requests.is_empty() {
            return 0.0;
        }
        self.slo_attained(slo) as f64 / self.requests.len() as f64
    }

    /// Number of requests meeting both SLO limits — the integer counter
    /// the streaming sink's [`crate::metrics::SloSummary`] must match
    /// exactly.
    pub fn slo_attained(&self, slo: SloSpec) -> u64 {
        self.requests
            .iter()
            .filter(|r| r.ttft_ms <= slo.ttft_ms && r.tpot_ms <= slo.tpot_ms)
            .count() as u64
    }

    /// Window-decision (γ) histogram over the retained per-request
    /// decision vectors, in the exact [`GammaSummary`] shape the
    /// streaming sink folds at decision time. When every request
    /// completes the two are identical (all-integer fields).
    pub fn gamma_summary(&self) -> GammaSummary {
        let mut g = GammaSummary::default();
        for r in &self.requests {
            for &gamma in &r.gamma_decisions {
                g.push(gamma);
            }
        }
        g
    }

    /// Per-target breakdown (routing histogram + per-target latency and
    /// acceptance), computed *independently* of the streaming sink:
    /// arithmetic means over the retained records, grouped by
    /// `target_id`, indexed `0..=max_target_id`. The differential
    /// harness compares this against the streaming sink's Welford-folded
    /// [`GroupSummary`]s: counts exactly, means to floating-point noise.
    pub fn per_target_breakdown(&self) -> Vec<GroupSummary> {
        self.group_breakdown(|r| r.target_id)
    }

    /// Per-drafter-pool breakdown; `pool_ends` are cumulative pool end
    /// indices as in [`drafter_pool_of`].
    pub fn per_pool_breakdown(&self, pool_ends: &[usize]) -> Vec<GroupSummary> {
        self.group_breakdown(|r| drafter_pool_of(r.drafter_id, pool_ends))
    }

    /// Windowed time series over the retained records — the full-sink
    /// side of the streaming sink's folded
    /// [`TimeSeriesSummary`](crate::metrics::StreamingSummary); this is
    /// also the throughput estimator of record for *non-stationary*
    /// runs, where the interquartile `throughput_rps` is invalid (see
    /// [`SystemMetrics::throughput_rps`]).
    ///
    /// Computed *independently* of [`crate::metrics::TimeSeries`]: a
    /// single sum-and-count binning pass in trace order with plain
    /// arithmetic means (the streaming fold runs Welford in completion
    /// order), re-deriving the same grouping rules — completion-window
    /// assignment, active-span overlap, cap-and-overflow. O(requests +
    /// windows), so scenario cells can carry the series at any scale.
    /// The differential harness compares this against the streaming
    /// fold — counts exactly, means to floating-point noise.
    pub fn time_series(&self, cfg: &TimeSeriesConfig) -> TimeSeriesSummary {
        let w = cfg.window_ms;
        let index_of = |t_ms: f64| (t_ms.max(0.0) / w) as usize;
        #[derive(Clone, Default)]
        struct Bin {
            completed: u64,
            output_tokens: u64,
            ttft_sum: f64,
            tpot_sum: f64,
            acc_sum: f64,
            acc_n: u64,
        }
        let mut bins: Vec<Bin> = Vec::new();
        let mut active: Vec<u64> = Vec::new();
        let mut overflow_completed = 0u64;
        for r in &self.requests {
            let wi = index_of(r.arrival_ms + r.e2e_ms);
            if wi >= cfg.max_windows {
                overflow_completed += 1;
            } else {
                if bins.len() <= wi {
                    bins.resize(wi + 1, Bin::default());
                }
                let b = &mut bins[wi];
                b.completed += 1;
                b.output_tokens += r.output_tokens as u64;
                b.ttft_sum += r.ttft_ms;
                b.tpot_sum += r.tpot_ms;
                if r.acceptance.is_finite() {
                    b.acc_sum += r.acceptance;
                    b.acc_n += 1;
                }
            }
            let first = index_of(r.arrival_ms);
            if first < cfg.max_windows {
                let last = wi.min(cfg.max_windows - 1);
                if active.len() <= last {
                    active.resize(last + 1, 0);
                }
                for a in &mut active[first..=last] {
                    *a += 1;
                }
            }
        }
        // Active-target-count series: integrate the autoscale fleet's
        // provisioned-count step function over the window grid — the
        // batch recomputation of the series the streaming sink folds
        // incrementally through `record_capacity`. Both sides process
        // the same segments in time order through the one shared
        // integration routine, so per-window sums are bit-identical by
        // construction (the parity harness checks the plumbing around
        // it: step delivery, presence rules, the per-window divisor).
        let mut cap_ms: Vec<f64> = Vec::new();
        let has_capacity = self.system.autoscale.is_some();
        if let Some(auto) = &self.system.autoscale {
            for pair in auto.steps.windows(2) {
                let (t0, count) = pair[0];
                let (t1, _) = pair[1];
                integrate_capacity_segment(
                    &mut cap_ms,
                    w,
                    cfg.max_windows,
                    t0,
                    t1,
                    count as f64,
                );
            }
        }
        let n = bins.len().max(active.len()).max(cap_ms.len());
        let empty = Bin::default();
        let windows = (0..n)
            .map(|k| {
                let b = bins.get(k).unwrap_or(&empty);
                let mean_of = |sum: f64| {
                    if b.completed == 0 {
                        0.0
                    } else {
                        sum / b.completed as f64
                    }
                };
                WindowSummary {
                    index: k,
                    start_ms: k as f64 * w,
                    completed: b.completed,
                    active: active.get(k).copied().unwrap_or(0),
                    output_tokens: b.output_tokens,
                    throughput_rps: b.completed as f64 / (w / 1_000.0),
                    mean_ttft_ms: mean_of(b.ttft_sum),
                    mean_tpot_ms: mean_of(b.tpot_sum),
                    mean_acceptance: if b.acc_n == 0 {
                        f64::NAN
                    } else {
                        b.acc_sum / b.acc_n as f64
                    },
                    provisioned_targets: if has_capacity {
                        Some(cap_ms.get(k).copied().unwrap_or(0.0) / w)
                    } else {
                        None
                    },
                }
            })
            .collect();
        TimeSeriesSummary {
            window_ms: w,
            overflow_completed,
            windows,
        }
    }

    /// Per-request-class breakdown, computed *independently* of the
    /// streaming sink: one entry per declared tier (`classes` in
    /// declaration order), each with arithmetic-mean group statistics,
    /// attainment against the tier's *own* SLO, and a windowed time
    /// series restricted to the tier's requests. Out-of-range class ids
    /// clamp to the last tier, mirroring both the simulator and the
    /// streaming fold. Tiers with no completions yield 0-count groups
    /// (0.0 means, NaN acceptance) — never a division by zero. The
    /// per-tier series is built from a capacity-free sub-report, so it
    /// carries no `provisioned_targets` — fleet size is global, not
    /// per-tier — matching the streaming side's per-class fold.
    pub fn per_class_breakdown(
        &self,
        classes: &[(String, SloSpec)],
        ts_cfg: &TimeSeriesConfig,
    ) -> Vec<ClassSummary> {
        let n = classes.len();
        classes
            .iter()
            .enumerate()
            .map(|(ci, (name, spec))| {
                let members: Vec<RequestMetrics> = self
                    .requests
                    .iter()
                    .filter(|r| r.class_id.min(n - 1) == ci)
                    .cloned()
                    .collect();
                let vals = |f: &dyn Fn(&RequestMetrics) -> f64| -> Vec<f64> {
                    members.iter().map(|r| f(r)).collect()
                };
                let acc: Vec<f64> = members
                    .iter()
                    .map(|r| r.acceptance)
                    .filter(|a| a.is_finite())
                    .collect();
                let group = GroupSummary {
                    key: ci,
                    completed: members.len() as u64,
                    output_tokens: members.iter().map(|r| r.output_tokens as u64).sum(),
                    fused_rounds: members.iter().map(|r| r.fused_rounds as u64).sum(),
                    mean_ttft_ms: mean(&vals(&|r| r.ttft_ms)),
                    mean_tpot_ms: mean(&vals(&|r| r.tpot_ms)),
                    mean_e2e_ms: mean(&vals(&|r| r.e2e_ms)),
                    mean_acceptance: if acc.is_empty() { f64::NAN } else { mean(&acc) },
                };
                let slo = SloSummary {
                    spec: *spec,
                    attained: members
                        .iter()
                        .filter(|r| r.ttft_ms <= spec.ttft_ms && r.tpot_ms <= spec.tpot_ms)
                        .count() as u64,
                    completed: members.len() as u64,
                };
                let sub = SimReport {
                    requests: members,
                    system: SystemMetrics::default(),
                };
                ClassSummary {
                    name: name.clone(),
                    group,
                    slo,
                    time_series: sub.time_series(ts_cfg),
                }
            })
            .collect()
    }

    fn group_breakdown(&self, key_of: impl Fn(&RequestMetrics) -> usize) -> Vec<GroupSummary> {
        let n_groups = match self.requests.iter().map(&key_of).max() {
            Some(max) => max + 1,
            None => return Vec::new(),
        };
        (0..n_groups)
            .map(|key| {
                let members: Vec<&RequestMetrics> = self
                    .requests
                    .iter()
                    .filter(|r| key_of(r) == key)
                    .collect();
                let vals = |f: &dyn Fn(&RequestMetrics) -> f64| -> Vec<f64> {
                    members.iter().map(|r| f(r)).collect()
                };
                let acc: Vec<f64> = members
                    .iter()
                    .map(|r| r.acceptance)
                    .filter(|a| a.is_finite())
                    .collect();
                GroupSummary {
                    key,
                    completed: members.len() as u64,
                    output_tokens: members.iter().map(|r| r.output_tokens as u64).sum(),
                    fused_rounds: members.iter().map(|r| r.fused_rounds as u64).sum(),
                    mean_ttft_ms: mean(&vals(&|r| r.ttft_ms)),
                    mean_tpot_ms: mean(&vals(&|r| r.tpot_ms)),
                    mean_e2e_ms: mean(&vals(&|r| r.e2e_ms)),
                    mean_acceptance: if acc.is_empty() { f64::NAN } else { mean(&acc) },
                }
            })
            .collect()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "completed={} tput={:.1} req/s ttft={:.0} ms tpot={:.1} ms e2e={:.0} ms acc={:.2} util={:.2}",
            self.system.completed,
            self.system.throughput_rps,
            self.mean_ttft(),
            self.mean_tpot(),
            self.mean_e2e(),
            self.mean_acceptance(),
            self.system.target_utilization,
        )
    }

    /// Full structured JSON (paper §3.5: "emitted in a structured JSON
    /// format" for online adaptation and offline analysis).
    pub fn to_json(&self) -> Json {
        let mut system = Json::obj()
            .with("throughput_rps", self.system.throughput_rps.into())
            .with("token_throughput", self.system.token_throughput.into())
            .with("target_utilization", self.system.target_utilization.into())
            .with("mean_queue_delay_ms", self.system.mean_queue_delay_ms.into())
            .with("mean_net_delay_ms", self.system.mean_net_delay_ms.into())
            .with("sim_duration_ms", self.system.sim_duration_ms.into())
            .with("completed", self.system.completed.into())
            .with("events_processed", self.system.events_processed.into())
            .with("wall_ms", self.system.wall_ms.into());
        // Pipelining-free reports keep their historical bytes: the
        // waste counters appear only when an invalidated speculative
        // window actually burned work (sequential runs never do).
        if self.system.wasted_draft_tokens > 0 || self.system.wasted_uplink_ms != 0.0 {
            system.set(
                "wasted_draft_tokens",
                self.system.wasted_draft_tokens.into(),
            );
            system.set("wasted_uplink_ms", self.system.wasted_uplink_ms.into());
        }
        // Autoscale-free reports keep their historical bytes: the key
        // exists only when an elastic pool actually ran.
        if let Some(a) = &self.system.autoscale {
            system.set("autoscale", a.to_json());
        }
        Json::obj()
            .with("system", system)
            .with(
                "aggregates",
                Json::obj()
                    .with("mean_ttft_ms", self.mean_ttft().into())
                    .with("mean_tpot_ms", self.mean_tpot().into())
                    .with("mean_e2e_ms", self.mean_e2e().into())
                    .with("p99_ttft_ms", self.p_ttft(99.0).into())
                    .with("p99_tpot_ms", self.p_tpot(99.0).into())
                    .with("mean_acceptance", self.mean_acceptance().into())
                    .with("mean_gamma", self.mean_gamma().into()),
            )
            .with(
                "requests",
                Json::Arr(self.requests.iter().map(|r| r.to_json()).collect()),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, ttft: f64, tpot: f64) -> RequestMetrics {
        RequestMetrics {
            id,
            arrival_ms: 0.0,
            ttft_ms: ttft,
            tpot_ms: tpot,
            e2e_ms: ttft + tpot * 100.0,
            acceptance: 0.8,
            target_id: 0,
            drafter_id: 0,
            output_tokens: 100,
            gamma_decisions: vec![4, 4, 5],
            fused_rounds: 0,
            class_id: 0,
        }
    }

    #[test]
    fn aggregates() {
        let rep = SimReport {
            requests: vec![req(0, 100.0, 30.0), req(1, 300.0, 50.0)],
            system: SystemMetrics::default(),
        };
        assert!((rep.mean_ttft() - 200.0).abs() < 1e-9);
        assert!((rep.mean_tpot() - 40.0).abs() < 1e-9);
        assert!((rep.mean_gamma() - 13.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn slo_attainment() {
        let rep = SimReport {
            requests: vec![req(0, 100.0, 30.0), req(1, 300.0, 50.0)],
            system: SystemMetrics::default(),
        };
        let slo = SloSpec { ttft_ms: 200.0, tpot_ms: 40.0 };
        assert!((rep.slo_attainment(slo) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn json_emission_parses() {
        let rep = SimReport {
            requests: vec![req(0, 1.0, 2.0)],
            system: SystemMetrics::default(),
        };
        let j = rep.to_json();
        assert!(j.path(&["aggregates", "mean_ttft_ms"]).is_some());
        assert_eq!(j.get("requests").unwrap().as_arr().unwrap().len(), 1);
        // Round-trips through text.
        let text = j.to_string_pretty();
        assert!(Json::parse(&text).is_ok());
    }

    /// ISSUE 8: the pipelined waste counters must stay entirely off the
    /// wire for sequential runs (historical report bytes unchanged) and
    /// appear, with their exact totals, once any speculative work burns.
    #[test]
    fn wasted_counters_serialized_only_when_nonzero() {
        let mut rep = SimReport {
            requests: vec![req(0, 1.0, 2.0)],
            system: SystemMetrics::default(),
        };
        let clean = rep.to_json();
        let sys = clean.get("system").unwrap();
        assert!(sys.get("wasted_draft_tokens").is_none());
        assert!(sys.get("wasted_uplink_ms").is_none());
        rep.system.wasted_draft_tokens = 9;
        rep.system.wasted_uplink_ms = 3.25;
        let dirty = rep.to_json();
        let sys = dirty.get("system").unwrap();
        assert_eq!(sys.get("wasted_draft_tokens").and_then(Json::as_usize), Some(9));
        assert!(sys.get("wasted_uplink_ms").is_some());
        let text = dirty.to_string_pretty();
        assert!(text.contains("wasted_draft_tokens"));
        assert!(Json::parse(&text).is_ok());
    }

    /// Regression: a single non-finite latency record must degrade the
    /// affected percentiles, never abort the whole end-of-run report —
    /// `percentile`'s old `partial_cmp(..).unwrap()` comparator panicked
    /// on the first NaN it compared.
    #[test]
    fn report_with_nan_latency_does_not_panic() {
        let mut bad = req(0, 100.0, 30.0);
        bad.ttft_ms = f64::NAN;
        bad.tpot_ms = f64::NAN;
        let rep = SimReport {
            requests: vec![bad, req(1, 300.0, 50.0), req(2, 200.0, 40.0)],
            system: SystemMetrics::default(),
        };
        // NaN sorts past +inf under total order: low/mid percentiles
        // stay finite, only the extreme upper tail reaches the NaN.
        assert!(rep.p_ttft(50.0).is_finite());
        assert!(rep.p_tpot(50.0).is_finite());
        assert!(rep.p_ttft(100.0).is_nan());
        // The rest of the report machinery must also survive emission.
        assert!(Json::parse(&rep.to_json().to_string_pretty()).is_ok());
        assert!(rep.summary().contains("completed="));
    }

    #[test]
    fn acceptance_ignores_fused_nan() {
        let mut a = req(0, 1.0, 2.0);
        a.acceptance = f64::NAN;
        let rep = SimReport {
            requests: vec![a, req(1, 1.0, 2.0)],
            system: SystemMetrics::default(),
        };
        assert!((rep.mean_acceptance() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn gamma_summary_counts_all_decisions() {
        let rep = SimReport {
            requests: vec![req(0, 1.0, 2.0), req(1, 1.0, 2.0)],
            system: SystemMetrics::default(),
        };
        // Each req carries decisions [4, 4, 5].
        let g = rep.gamma_summary();
        assert_eq!(g.decisions, 6);
        assert_eq!(g.total, 26);
        assert_eq!(g.hist[4], 4);
        assert_eq!(g.hist[5], 2);
        assert_eq!(g.overflow, 0);
        assert!((g.mean() - rep.mean_gamma()).abs() < 1e-12);
    }

    #[test]
    fn per_target_breakdown_partitions() {
        let mut a = req(0, 100.0, 30.0);
        a.target_id = 1;
        let mut b = req(1, 300.0, 50.0);
        b.target_id = 1;
        let mut c = req(2, 200.0, 40.0);
        c.target_id = 0;
        c.acceptance = f64::NAN;
        let rep = SimReport {
            requests: vec![a, b, c],
            system: SystemMetrics::default(),
        };
        let groups = rep.per_target_breakdown();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].completed, 1);
        assert_eq!(groups[1].completed, 2);
        assert!((groups[1].mean_ttft_ms - 200.0).abs() < 1e-9);
        assert!(groups[0].mean_acceptance.is_nan());
        assert!((groups[1].mean_acceptance - 0.8).abs() < 1e-12);
        let total: u64 = groups.iter().map(|g| g.completed).sum();
        assert_eq!(total as usize, rep.requests.len());
        // Pool breakdown groups by drafter id through the pool map.
        let pools = rep.per_pool_breakdown(&[1, 2]);
        assert_eq!(pools.len(), 1); // all drafter_id 0 → pool 0
        assert_eq!(pools[0].completed, 3);
    }

    #[test]
    fn time_series_groups_by_completion_window() {
        let mut a = req(0, 100.0, 1.0); // e2e = 100 + 1*100 = 200 → window 0
        a.arrival_ms = 0.0;
        let mut b = req(1, 100.0, 10.0); // e2e = 1100; arrival 500 → completes 1600 → window 1
        b.arrival_ms = 500.0;
        b.e2e_ms = 1_100.0;
        let rep = SimReport {
            requests: vec![a, b],
            system: SystemMetrics::default(),
        };
        let ts = rep.time_series(&TimeSeriesConfig { window_ms: 1_000.0, max_windows: 64 });
        assert_eq!(ts.windows.len(), 2);
        assert_eq!(ts.windows[0].completed, 1);
        assert_eq!(ts.windows[1].completed, 1);
        // b is active in both windows, a only in the first.
        assert_eq!(ts.windows[0].active, 2);
        assert_eq!(ts.windows[1].active, 1);
        assert_eq!(ts.overflow_completed, 0);
        assert!((ts.windows[0].throughput_rps - 1.0).abs() < 1e-12);
        assert!((ts.windows[0].mean_ttft_ms - 100.0).abs() < 1e-12);
        // A cap of 1 window overflows b's completion but keeps it active
        // in the surviving window.
        let capped = rep.time_series(&TimeSeriesConfig { window_ms: 1_000.0, max_windows: 1 });
        assert_eq!(capped.windows.len(), 1);
        assert_eq!(capped.overflow_completed, 1);
        assert_eq!(capped.windows[0].completed, 1);
        assert_eq!(capped.windows[0].active, 2);
    }

    #[test]
    fn class_id_serialized_only_when_nonzero() {
        let classless = req(0, 1.0, 2.0).to_json();
        assert!(classless.get("class_id").is_none(), "classless bytes unchanged");
        let mut r = req(1, 1.0, 2.0);
        r.class_id = 2;
        assert_eq!(r.to_json().get("class_id").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn per_class_breakdown_partitions_with_tier_slos() {
        let classes = vec![
            ("interactive".to_string(), SloSpec { ttft_ms: 150.0, tpot_ms: 40.0 }),
            ("batch".to_string(), SloSpec { ttft_ms: 1_000.0, tpot_ms: 100.0 }),
        ];
        let a = req(0, 100.0, 30.0); // tier 0, attained
        let mut b = req(1, 300.0, 50.0); // tier 0, breach
        b.class_id = 0;
        let mut c = req(2, 400.0, 60.0); // tier 1, attained
        c.class_id = 1;
        let mut stray = req(3, 2_000.0, 60.0); // clamps to tier 1, breach
        stray.class_id = 7;
        let rep = SimReport {
            requests: vec![a, b, c, stray],
            system: SystemMetrics::default(),
        };
        let ts_cfg = TimeSeriesConfig { window_ms: 1_000.0, max_windows: 64 };
        let per = rep.per_class_breakdown(&classes, &ts_cfg);
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].name, "interactive");
        assert_eq!(per[0].group.completed, 2);
        assert_eq!(per[0].slo.attained, 1);
        assert!((per[0].group.mean_ttft_ms - 200.0).abs() < 1e-9);
        assert_eq!(per[1].group.completed, 2);
        assert_eq!(per[1].slo.attained, 1);
        // Class counts partition the report.
        let total: u64 = per.iter().map(|c| c.group.completed).sum();
        assert_eq!(total as usize, rep.requests.len());
        // Per-tier series are capacity-free sub-reports.
        for c in &per {
            assert!(c.time_series.windows.iter().all(|w| w.provisioned_targets.is_none()));
        }
    }

    /// ISSUE satellite: a declared tier with zero completions must
    /// report 0 counts and 0.0 means, never NaN from a 0/0.
    #[test]
    fn per_class_breakdown_empty_tier_is_zero_not_nan() {
        let classes = vec![
            ("interactive".to_string(), SloSpec::INTERACTIVE),
            ("batch".to_string(), SloSpec::RELAXED),
        ];
        let rep = SimReport {
            requests: vec![req(0, 100.0, 30.0)], // tier 0 only
            system: SystemMetrics::default(),
        };
        let ts_cfg = TimeSeriesConfig { window_ms: 1_000.0, max_windows: 64 };
        let per = rep.per_class_breakdown(&classes, &ts_cfg);
        let empty = &per[1];
        assert_eq!(empty.group.completed, 0);
        assert_eq!(empty.group.mean_ttft_ms, 0.0);
        assert_eq!(empty.group.mean_e2e_ms, 0.0);
        assert!(empty.group.mean_acceptance.is_nan());
        assert!((empty.slo.attainment() - 0.0).abs() < 1e-12);
        assert!(empty.time_series.windows.is_empty());
        // No declared classes → empty breakdown, even with requests.
        assert!(rep.per_class_breakdown(&[], &ts_cfg).is_empty());
    }

    #[test]
    fn slo_attained_count_matches_fraction() {
        let rep = SimReport {
            requests: vec![req(0, 100.0, 30.0), req(1, 300.0, 50.0)],
            system: SystemMetrics::default(),
        };
        let slo = SloSpec { ttft_ms: 200.0, tpot_ms: 40.0 };
        assert_eq!(rep.slo_attained(slo), 1);
        assert!((rep.slo_attainment(slo) - 0.5).abs() < 1e-9);
        assert_eq!(rep.slo_attained(SloSpec::INTERACTIVE), 2);
    }
}
