//! Windowed time-series metrics: fixed-width time windows folding
//! throughput, latency means, acceptance, and active-request counts —
//! the instrumentation that makes scripted dynamics *observable*.
//!
//! Both metric sinks produce a [`TimeSeriesSummary`]:
//!
//! * [`StreamingSink`](super::StreamingSink) folds each completed
//!   request into a [`TimeSeries`] (Welford accumulators per window,
//!   O(windows) memory), preserving bounded-memory mode's feature
//!   parity;
//! * [`SimReport::time_series`](super::SimReport::time_series)
//!   recomputes the same summary *independently* from the retained
//!   per-request records with plain arithmetic means — the differential
//!   harness (`tests/streaming_parity.rs`) compares the two exactly on
//!   counts and to 1e-9 on means.
//!
//! A request is assigned to the window containing its **completion**
//! time (`arrival_ms + e2e_ms`); it counts as *active* in every window
//! its `[arrival, completion]` span overlaps. Windows are `[k·w,
//! (k+1)·w)`; completions beyond `max_windows` fold into an overflow
//! counter and active spans clamp to the last window.

use super::report::RequestMetrics;
use crate::util::json::Json;
use crate::util::stats::Accumulator;

/// Window geometry for time-series folding.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TimeSeriesConfig {
    /// Window width, ms.
    pub window_ms: f64,
    /// Hard cap on the number of windows (memory bound; completions
    /// beyond it land in the overflow counter).
    pub max_windows: usize,
}

impl Default for TimeSeriesConfig {
    fn default() -> Self {
        // One-second windows: fine enough to see a flash crowd or link
        // flap, coarse enough that an hour of simulated time stays at
        // 3.6k windows. 4096 windows ≈ 68 min at the default width.
        TimeSeriesConfig { window_ms: 1_000.0, max_windows: 4_096 }
    }
}

/// Per-window streaming accumulators.
#[derive(Clone, Debug, Default)]
struct WindowAcc {
    completed: u64,
    output_tokens: u64,
    ttft: Accumulator,
    tpot: Accumulator,
    /// Finite (speculating) acceptance ratios only.
    acceptance: Accumulator,
}

/// Bounded-memory time-series folder (the streaming-sink side).
#[derive(Clone, Debug)]
pub struct TimeSeries {
    cfg: TimeSeriesConfig,
    /// Indexed by window; grown on sight.
    windows: Vec<WindowAcc>,
    /// Active-request counts, indexed by window; grown on sight.
    active: Vec<u64>,
    /// Completions beyond the window cap.
    overflow_completed: u64,
    /// Per-window ∫(provisioned target count)dt in ms·targets — the
    /// elastic-capacity series, folded incrementally from
    /// [`TimeSeries::fold_capacity`] steps. Empty (and the summary
    /// field absent) when no capacity steps were recorded.
    cap_ms: Vec<f64>,
    /// Last capacity step seen: `(time, count)`.
    cap_last: Option<(f64, f64)>,
}

impl TimeSeries {
    /// Empty series with the given geometry.
    pub fn new(cfg: TimeSeriesConfig) -> TimeSeries {
        TimeSeries {
            cfg,
            windows: Vec::new(),
            active: Vec::new(),
            overflow_completed: 0,
            cap_ms: Vec::new(),
            cap_last: None,
        }
    }

    /// Window index of a timestamp (unclamped).
    fn index_of(&self, t_ms: f64) -> usize {
        (t_ms.max(0.0) / self.cfg.window_ms) as usize
    }

    /// Fold one completed request.
    pub fn fold(&mut self, m: &RequestMetrics) {
        let end_ms = m.arrival_ms + m.e2e_ms;
        let wi = self.index_of(end_ms);
        if wi >= self.cfg.max_windows {
            self.overflow_completed += 1;
        } else {
            if self.windows.len() <= wi {
                self.windows.resize_with(wi + 1, WindowAcc::default);
            }
            let w = &mut self.windows[wi];
            w.completed += 1;
            w.output_tokens += m.output_tokens as u64;
            w.ttft.push(m.ttft_ms);
            w.tpot.push(m.tpot_ms);
            if m.acceptance.is_finite() {
                w.acceptance.push(m.acceptance);
            }
        }
        // Active span: every window the [arrival, completion] interval
        // overlaps, clamped to the window cap.
        let first = self.index_of(m.arrival_ms);
        if first < self.cfg.max_windows {
            let last = wi.min(self.cfg.max_windows - 1);
            if self.active.len() <= last {
                self.active.resize(last + 1, 0);
            }
            for a in &mut self.active[first..=last] {
                *a += 1;
            }
        }
    }

    /// Fold one provisioned-capacity step `(t_ms, count)`: the segment
    /// since the previous step is integrated at the previous count into
    /// the per-window capacity series. The simulator emits the t=0
    /// initial count, one step per change, and an end-of-run marker, so
    /// the series covers the whole run. Windows beyond the cap are
    /// skipped (matching the completion fold's overflow behavior).
    pub fn fold_capacity(&mut self, t_ms: f64, provisioned: u32) {
        let t = t_ms.max(0.0);
        if let Some((t0, count)) = self.cap_last {
            integrate_capacity_segment(
                &mut self.cap_ms,
                self.cfg.window_ms,
                self.cfg.max_windows,
                t0,
                t.max(t0),
                count,
            );
        }
        self.cap_last = Some((t.max(self.cap_last.map_or(0.0, |(t0, _)| t0)), provisioned as f64));
    }

    /// Snapshot the folded series.
    pub fn summary(&self) -> TimeSeriesSummary {
        let n = self
            .windows
            .len()
            .max(self.active.len())
            .max(self.cap_ms.len());
        let empty = WindowAcc::default();
        let windows = (0..n)
            .map(|k| {
                let w = self.windows.get(k).unwrap_or(&empty);
                WindowSummary {
                    index: k,
                    start_ms: k as f64 * self.cfg.window_ms,
                    completed: w.completed,
                    active: self.active.get(k).copied().unwrap_or(0),
                    output_tokens: w.output_tokens,
                    throughput_rps: w.completed as f64 / (self.cfg.window_ms / 1_000.0),
                    mean_ttft_ms: w.ttft.mean(),
                    mean_tpot_ms: w.tpot.mean(),
                    mean_acceptance: if w.acceptance.count() == 0 {
                        f64::NAN
                    } else {
                        w.acceptance.mean()
                    },
                    provisioned_targets: if self.cap_last.is_some() {
                        Some(self.cap_ms.get(k).copied().unwrap_or(0.0) / self.cfg.window_ms)
                    } else {
                        None
                    },
                }
            })
            .collect();
        TimeSeriesSummary {
            window_ms: self.cfg.window_ms,
            overflow_completed: self.overflow_completed,
            windows,
        }
    }
}

/// Integrate one constant-count capacity segment `[a, b)` (ms, count in
/// targets) into a per-window `ms·targets` accumulator, clamped to
/// `max_windows`. This is the **single** implementation behind both the
/// streaming sink's incremental fold ([`TimeSeries::fold_capacity`])
/// and the report's batch recomputation
/// ([`SimReport::time_series`](super::SimReport)): the windowed
/// capacity series agrees between the two sides *by construction* —
/// both feed the same step segments, in time order, through this exact
/// arithmetic. The parity harness still checks the surrounding
/// plumbing (step delivery, presence rules, the per-window divisor).
pub(crate) fn integrate_capacity_segment(
    cap_ms: &mut Vec<f64>,
    window_ms: f64,
    max_windows: usize,
    a: f64,
    b: f64,
    count: f64,
) {
    let a = a.max(0.0);
    let b = b.max(a);
    if b <= a {
        return;
    }
    let mut k = (a / window_ms) as usize;
    while k < max_windows {
        let ws = k as f64 * window_ms;
        let we = ws + window_ms;
        let lo = a.max(ws);
        let hi = b.min(we);
        if hi > lo {
            if cap_ms.len() <= k {
                cap_ms.resize(k + 1, 0.0);
            }
            cap_ms[k] += count * (hi - lo);
        }
        if we >= b {
            break;
        }
        k += 1;
    }
}

/// Folded statistics of one time window.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowSummary {
    /// Window index `k` (covers `[k·window_ms, (k+1)·window_ms)`).
    pub index: usize,
    /// Window start, ms.
    pub start_ms: f64,
    /// Requests completing in the window.
    pub completed: u64,
    /// Requests active (arrived, not yet completed) during any part of
    /// the window.
    pub active: u64,
    /// Output tokens of the window's completions.
    pub output_tokens: u64,
    /// Completion throughput, requests/second (`completed / window`).
    pub throughput_rps: f64,
    /// Mean TTFT of the window's completions, ms (0 when empty).
    pub mean_ttft_ms: f64,
    /// Mean TPOT of the window's completions, ms.
    pub mean_tpot_ms: f64,
    /// Mean acceptance over the window's speculating completions — the
    /// accepted fraction of drafted tokens (NaN when none speculated).
    pub mean_acceptance: f64,
    /// Time-weighted mean provisioned-target count over the window —
    /// the elastic-capacity series. `None` (and the JSON key absent,
    /// keeping autoscale-free series byte-identical) when the run had
    /// no autoscale block. The final, partial window integrates only up
    /// to the end of the run, mirroring its partial completion counts.
    pub provisioned_targets: Option<f64>,
}

impl WindowSummary {
    /// JSON encoding (insertion-ordered keys, deterministic).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("index", self.index.into())
            .with("start_ms", self.start_ms.into())
            .with("completed", self.completed.into())
            .with("active", self.active.into())
            .with("output_tokens", self.output_tokens.into())
            .with("throughput_rps", self.throughput_rps.into())
            .with("mean_ttft_ms", self.mean_ttft_ms.into())
            .with("mean_tpot_ms", self.mean_tpot_ms.into())
            .with("mean_acceptance", self.mean_acceptance.into());
        if let Some(p) = self.provisioned_targets {
            j.set("provisioned_targets", p.into());
        }
        j
    }

    fn from_json(j: &Json) -> Option<WindowSummary> {
        Some(WindowSummary {
            index: j.get("index")?.as_usize()?,
            start_ms: j.get("start_ms")?.as_f64()?,
            completed: j.get("completed")?.as_u64()?,
            active: j.get("active")?.as_u64()?,
            output_tokens: j.get("output_tokens")?.as_u64()?,
            throughput_rps: j.get("throughput_rps")?.as_f64_or_nan()?,
            mean_ttft_ms: j.get("mean_ttft_ms")?.as_f64_or_nan()?,
            mean_tpot_ms: j.get("mean_tpot_ms")?.as_f64_or_nan()?,
            mean_acceptance: j.get("mean_acceptance")?.as_f64_or_nan()?,
            // Optional: absent on autoscale-free series (and on entries
            // written before the elastic-capacity subsystem).
            provisioned_targets: match j.get("provisioned_targets") {
                None => None,
                Some(v) => Some(v.as_f64_or_nan()?),
            },
        })
    }
}

/// The complete windowed time series of one run.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeSeriesSummary {
    /// Window width, ms.
    pub window_ms: f64,
    /// Completions beyond the window cap (not represented in `windows`).
    pub overflow_completed: u64,
    /// Per-window summaries, index order, no gaps (quiet windows appear
    /// with zero counts).
    pub windows: Vec<WindowSummary>,
}

impl TimeSeriesSummary {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("window_ms", self.window_ms.into())
            .with("overflow_completed", self.overflow_completed.into())
            .with(
                "windows",
                Json::Arr(self.windows.iter().map(|w| w.to_json()).collect()),
            )
    }

    /// Decode a summary previously written by
    /// [`TimeSeriesSummary::to_json`] (the sweep cell-cache load path).
    /// `None` on any missing or mistyped field.
    pub fn from_json(j: &Json) -> Option<TimeSeriesSummary> {
        let windows = j
            .get("windows")?
            .as_arr()?
            .iter()
            .map(WindowSummary::from_json)
            .collect::<Option<Vec<_>>>()?;
        Some(TimeSeriesSummary {
            window_ms: j.get("window_ms")?.as_f64()?,
            overflow_completed: j.get("overflow_completed")?.as_u64()?,
            windows,
        })
    }

    /// Mean completion throughput (req/s) over the full windows whose
    /// start lies in `[t0_ms, t1_ms)`; `None` when the range covers no
    /// window — including empty (`t1 ≤ t0`) and non-finite ranges, so a
    /// degenerate query can never produce a NaN that propagates into
    /// downstream means (ISSUE satellite).
    pub fn mean_throughput_between(&self, t0_ms: f64, t1_ms: f64) -> Option<f64> {
        Self::range_ok(t0_ms, t1_ms)?;
        let xs: Vec<f64> = self
            .windows
            .iter()
            .filter(|w| (t0_ms..t1_ms).contains(&w.start_ms))
            .map(|w| w.throughput_rps)
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(xs.iter().sum::<f64>() / xs.len() as f64)
        }
    }

    /// Guard shared by the range/scan helpers: degenerate inputs
    /// (non-finite bounds, empty ranges) yield `None` rather than a
    /// silently-wrong scan.
    fn range_ok(t0_ms: f64, t1_ms: f64) -> Option<()> {
        (t0_ms.is_finite() && t1_ms.is_finite() && t1_ms > t0_ms).then_some(())
    }

    /// Time from `event_ms` until throughput first sustains
    /// `target_rps`: scans windows **starting at or after** `event_ms`
    /// (a window straddling the event still contains pre-event
    /// completions and must not register a spurious instant recovery;
    /// the final, partial window is excluded too) for the first with
    /// `throughput_rps >= target_rps` and returns the distance from
    /// `event_ms` to that window's end. `None` when throughput never
    /// recovers within the series — the agility experiment's
    /// time-to-recover metric.
    pub fn recovery_ms_after(&self, event_ms: f64, target_rps: f64) -> Option<f64> {
        // A NaN target (e.g. a recovery fraction of a NaN baseline)
        // would vacuously never match; a non-finite event time would
        // scan from the wrong place. Both are caller bugs — fail to
        // `None` instead of fabricating a recovery time.
        if !event_ms.is_finite() || !target_rps.is_finite() {
            return None;
        }
        self.first_window_matching(event_ms, |w| w.throughput_rps >= target_rps)
    }

    /// Time from `event_ms` until the active-request count first falls
    /// to `target_active` or below — the backlog-drain analogue of
    /// [`TimeSeriesSummary::recovery_ms_after`], with the same window
    /// eligibility rules (post-event full windows only).
    pub fn drain_ms_after(&self, event_ms: f64, target_active: f64) -> Option<f64> {
        if !event_ms.is_finite() || !target_active.is_finite() {
            return None;
        }
        self.first_window_matching(event_ms, |w| (w.active as f64) <= target_active)
    }

    fn first_window_matching(
        &self,
        event_ms: f64,
        pred: impl Fn(&WindowSummary) -> bool,
    ) -> Option<f64> {
        let n = self.windows.len();
        // The last window is truncated by the end of the run; its
        // counts undershoot and must not fake a (non-)recovery.
        for w in self.windows.iter().take(n.saturating_sub(1)) {
            if w.start_ms < event_ms {
                continue;
            }
            if pred(w) {
                return Some((w.start_ms + self.window_ms - event_ms).max(0.0));
            }
        }
        None
    }

    /// Mean active-request count over the full windows whose start lies
    /// in `[t0_ms, t1_ms)`; `None` when the range covers no window (or
    /// is degenerate — see [`TimeSeriesSummary::mean_throughput_between`]).
    pub fn mean_active_between(&self, t0_ms: f64, t1_ms: f64) -> Option<f64> {
        Self::range_ok(t0_ms, t1_ms)?;
        let xs: Vec<f64> = self
            .windows
            .iter()
            .filter(|w| (t0_ms..t1_ms).contains(&w.start_ms))
            .map(|w| w.active as f64)
            .collect();
        if xs.is_empty() {
            None
        } else {
            Some(xs.iter().sum::<f64>() / xs.len() as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, arrival: f64, e2e: f64, acc: f64) -> RequestMetrics {
        RequestMetrics {
            id,
            arrival_ms: arrival,
            ttft_ms: e2e * 0.2,
            tpot_ms: 2.0,
            e2e_ms: e2e,
            acceptance: acc,
            target_id: 0,
            drafter_id: 0,
            output_tokens: 10,
            gamma_decisions: Vec::new(),
            fused_rounds: 0,
            class_id: 0,
        }
    }

    #[test]
    fn folds_by_completion_window_and_tracks_active_spans() {
        let mut ts = TimeSeries::new(TimeSeriesConfig { window_ms: 1_000.0, max_windows: 16 });
        ts.fold(&req(0, 100.0, 400.0, 0.8)); // completes at 500 → window 0
        ts.fold(&req(1, 900.0, 1_200.0, 0.6)); // completes at 2100 → window 2
        ts.fold(&req(2, 1_500.0, 100.0, f64::NAN)); // completes at 1600 → window 1
        let s = ts.summary();
        assert_eq!(s.windows.len(), 3);
        assert_eq!(
            s.windows.iter().map(|w| w.completed).collect::<Vec<_>>(),
            vec![1, 1, 1]
        );
        // Active: r0 spans window 0; r1 spans 0..=2; r2 spans window 1.
        assert_eq!(
            s.windows.iter().map(|w| w.active).collect::<Vec<_>>(),
            vec![2, 2, 1]
        );
        assert_eq!(s.windows[0].output_tokens, 10);
        assert!((s.windows[0].throughput_rps - 1.0).abs() < 1e-12);
        assert!((s.windows[0].mean_ttft_ms - 80.0).abs() < 1e-12);
        assert!((s.windows[0].mean_acceptance - 0.8).abs() < 1e-12);
        assert!(s.windows[1].mean_acceptance.is_nan(), "fused-only window");
        assert_eq!(s.overflow_completed, 0);
    }

    #[test]
    fn quiet_windows_appear_with_zero_counts() {
        let mut ts = TimeSeries::new(TimeSeriesConfig { window_ms: 100.0, max_windows: 64 });
        ts.fold(&req(0, 10.0, 20.0, 0.5)); // window 0
        ts.fold(&req(1, 510.0, 20.0, 0.5)); // window 5
        let s = ts.summary();
        assert_eq!(s.windows.len(), 6);
        assert_eq!(s.windows[3].completed, 0);
        assert_eq!(s.windows[3].active, 0);
        assert_eq!(s.windows[3].mean_ttft_ms, 0.0);
        assert!(s.windows[3].mean_acceptance.is_nan());
    }

    #[test]
    fn window_cap_overflows_and_clamps_active() {
        let mut ts = TimeSeries::new(TimeSeriesConfig { window_ms: 100.0, max_windows: 3 });
        ts.fold(&req(0, 50.0, 800.0, 0.9)); // completes at 850 → beyond cap
        ts.fold(&req(1, 950.0, 10.0, 0.9)); // arrival already beyond cap
        let s = ts.summary();
        assert_eq!(s.overflow_completed, 2);
        assert_eq!(s.windows.len(), 3);
        // r0's active span clamps to the capped windows; r1's span lies
        // entirely beyond the cap and is skipped.
        assert_eq!(
            s.windows.iter().map(|w| w.active).collect::<Vec<_>>(),
            vec![1, 1, 1]
        );
        assert_eq!(s.windows.iter().map(|w| w.completed).sum::<u64>(), 0);
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let mut ts = TimeSeries::new(TimeSeriesConfig::default());
        ts.fold(&req(0, 100.0, 500.0, 0.7));
        ts.fold(&req(1, 2_100.0, 900.0, f64::NAN));
        let s = ts.summary();
        let back = TimeSeriesSummary::from_json(&s.to_json()).expect("roundtrip");
        assert_eq!(
            back.to_json().to_string_pretty(),
            s.to_json().to_string_pretty(),
            "reloaded series must re-serialize byte-identically"
        );
        assert!(TimeSeriesSummary::from_json(&Json::obj()).is_none());
    }

    #[test]
    fn recovery_and_range_helpers() {
        let mk = |tputs: &[f64]| TimeSeriesSummary {
            window_ms: 1_000.0,
            overflow_completed: 0,
            windows: tputs
                .iter()
                .enumerate()
                .map(|(k, &t)| WindowSummary {
                    index: k,
                    start_ms: k as f64 * 1_000.0,
                    completed: t as u64,
                    active: 0,
                    output_tokens: 0,
                    throughput_rps: t,
                    mean_ttft_ms: 0.0,
                    mean_tpot_ms: 0.0,
                    mean_acceptance: f64::NAN,
                    provisioned_targets: None,
                })
                .collect(),
        };
        // Baseline 10/s, dip at window 2, recovery in window 5 (final
        // window 7 is excluded as partial).
        let s = mk(&[10.0, 10.0, 2.0, 3.0, 4.0, 9.5, 10.0, 1.0]);
        assert!((s.mean_throughput_between(0.0, 2_000.0).unwrap() - 10.0).abs() < 1e-12);
        // Event at 2000 ms; target 9.0: window 5 ends at 6000 → 4000 ms.
        assert_eq!(s.recovery_ms_after(2_000.0, 9.0), Some(4_000.0));
        // Never recovers to 11/s.
        assert_eq!(s.recovery_ms_after(2_000.0, 11.0), None);
        // Empty range.
        assert!(s.mean_throughput_between(50_000.0, 60_000.0).is_none());
        // A mid-window event must not let the straddling window — which
        // still holds pre-event completions — register recovery: event
        // at 1500 ms skips window 1 (starts at 1000, throughput 10)
        // and the scan starts at window 2.
        assert_eq!(s.recovery_ms_after(1_500.0, 9.0), Some(4_500.0));
    }

    #[test]
    fn degenerate_ranges_return_none_not_nan() {
        // ISSUE satellite: empty, inverted, and non-finite query ranges
        // must fail to None — a NaN mean would silently poison every
        // downstream seed average.
        let mut ts = TimeSeries::new(TimeSeriesConfig { window_ms: 1_000.0, max_windows: 16 });
        ts.fold(&req(0, 100.0, 400.0, 0.8));
        ts.fold(&req(1, 1_200.0, 300.0, 0.8));
        let s = ts.summary();
        assert!(s.mean_throughput_between(1_000.0, 1_000.0).is_none(), "empty range");
        assert!(s.mean_throughput_between(2_000.0, 1_000.0).is_none(), "inverted range");
        assert!(s.mean_throughput_between(f64::NAN, 1_000.0).is_none());
        assert!(s.mean_throughput_between(0.0, f64::NAN).is_none());
        assert!(s.mean_throughput_between(f64::NEG_INFINITY, f64::INFINITY).is_none());
        assert!(s.mean_active_between(500.0, 500.0).is_none());
        assert!(s.mean_active_between(f64::NAN, f64::NAN).is_none());
        assert!(s.recovery_ms_after(f64::NAN, 1.0).is_none());
        assert!(s.recovery_ms_after(0.0, f64::NAN).is_none());
        assert!(s.drain_ms_after(f64::INFINITY, 1.0).is_none());
        assert!(s.drain_ms_after(0.0, f64::NAN).is_none());
        // Well-formed queries still work.
        assert!(s.mean_throughput_between(0.0, 2_000.0).is_some());
    }

    #[test]
    fn capacity_steps_fold_into_windowed_means() {
        let mut ts = TimeSeries::new(TimeSeriesConfig { window_ms: 1_000.0, max_windows: 8 });
        // 2 targets for 1.5 windows, 3 targets for half a window, then
        // back to 2 until the end-of-run marker at 3 s.
        ts.fold_capacity(0.0, 2);
        ts.fold_capacity(1_500.0, 3);
        ts.fold_capacity(2_000.0, 2);
        ts.fold_capacity(3_000.0, 2); // end marker
        ts.fold(&req(0, 100.0, 300.0, 0.8));
        let s = ts.summary();
        assert_eq!(s.windows.len(), 3, "capacity extends the series past completions");
        assert_eq!(s.windows[0].provisioned_targets, Some(2.0));
        // Window 1: 2 targets for 500 ms + 3 targets for 500 ms = 2.5.
        assert!((s.windows[1].provisioned_targets.unwrap() - 2.5).abs() < 1e-12);
        assert_eq!(s.windows[2].provisioned_targets, Some(2.0));
        // JSON round-trip keeps the capacity series (string compare:
        // empty windows hold NaN acceptance, and NaN != NaN).
        let back = TimeSeriesSummary::from_json(&s.to_json()).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), s.to_json().to_string_pretty());
        assert_eq!(back.windows[1].provisioned_targets, s.windows[1].provisioned_targets);
        // No capacity steps → no field, and bytes match the historical
        // layout (no "provisioned_targets" key anywhere).
        let mut plain = TimeSeries::new(TimeSeriesConfig::default());
        plain.fold(&req(0, 100.0, 300.0, 0.8));
        let pj = plain.summary().to_json().to_string_pretty();
        assert!(!pj.contains("provisioned_targets"));
        assert!(plain.summary().windows[0].provisioned_targets.is_none());
    }

    #[test]
    fn capacity_integration_respects_the_window_cap() {
        let mut ts = TimeSeries::new(TimeSeriesConfig { window_ms: 100.0, max_windows: 2 });
        ts.fold_capacity(0.0, 4);
        ts.fold_capacity(1_000.0, 4); // far beyond the cap
        let s = ts.summary();
        assert_eq!(s.windows.len(), 2);
        assert_eq!(s.windows[0].provisioned_targets, Some(4.0));
        assert_eq!(s.windows[1].provisioned_targets, Some(4.0));
    }

    #[test]
    fn active_drain_helpers() {
        let mk_active = |actives: &[u64]| TimeSeriesSummary {
            window_ms: 1_000.0,
            overflow_completed: 0,
            windows: actives
                .iter()
                .enumerate()
                .map(|(k, &a)| WindowSummary {
                    index: k,
                    start_ms: k as f64 * 1_000.0,
                    completed: 0,
                    active: a,
                    output_tokens: 0,
                    throughput_rps: 0.0,
                    mean_ttft_ms: 0.0,
                    mean_tpot_ms: 0.0,
                    mean_acceptance: f64::NAN,
                    provisioned_targets: None,
                })
                .collect(),
        };
        // Baseline ~4 active, burst backlog peaks at 40, drains by
        // window 6 (last window 8 is partial and excluded).
        let s = mk_active(&[4, 4, 30, 40, 25, 12, 5, 4, 1]);
        assert!((s.mean_active_between(0.0, 2_000.0).unwrap() - 4.0).abs() < 1e-12);
        // Event at 4000 ms, drain target 5: window 6 ends at 7000.
        assert_eq!(s.drain_ms_after(4_000.0, 5.0), Some(3_000.0));
        // Never drains to 0 within the full windows.
        assert_eq!(s.drain_ms_after(4_000.0, 0.0), None);
    }
}
