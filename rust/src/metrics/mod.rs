//! Performance analyzer (paper §3.5): per-request metrics (TTFT, TPOT,
//! end-to-end latency, acceptance, γ decisions, routing), system-level
//! metrics (throughput, target utilization, queueing delay), SLO
//! evaluation, and structured JSON emission.

pub mod report;
pub mod sink;
pub mod timeseries;

pub use report::{RequestMetrics, SimReport, SloSpec, SystemMetrics};
pub use sink::{
    drafter_pool_of, ClassSummary, FullSink, GammaSummary, GroupSummary, MetricSummary,
    MetricsSink, SloSummary, StreamingConfig, StreamingReport, StreamingSink,
    StreamingSummary, GAMMA_HIST_BUCKETS,
};
pub use timeseries::{TimeSeries, TimeSeriesConfig, TimeSeriesSummary, WindowSummary};
