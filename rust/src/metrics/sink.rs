//! Metric sinks — where completed-request records flow during a run.
//!
//! The simulator pushes one [`RequestMetrics`] per completed request into
//! a [`MetricsSink`]. Two implementations exist:
//!
//! * [`FullSink`] (the default behind [`crate::sim::Simulator::run`])
//!   retains every record, giving the classic [`super::SimReport`] with
//!   exact percentiles and the per-request JSON dump.
//! * [`StreamingSink`] folds each record into Welford [`Accumulator`]s
//!   and fixed-bucket [`Histogram`]s at completion time and drops it.
//!   Memory is O(buckets + targets + pools), independent of request
//!   count, so a single cell can simulate millions of requests;
//!   percentiles are accurate to one histogram bucket width.
//!
//! Since the streaming-parity work the streaming sink is at feature
//! parity with the full sink: bounded-memory routing/γ decision
//! histograms ([`GammaSummary`]), per-target and per-drafter-pool
//! latency/acceptance breakdowns ([`GroupSummary`]), SLO-attainment
//! counters ([`SloSummary`]), and the windowed time series
//! ([`TimeSeriesSummary`] — scenario-dynamics observability, see
//! [`super::timeseries`]). γ decisions fold at *decision time*
//! through [`MetricsSink::record_gamma`] (the streaming sink keeps no
//! per-request γ vectors); everything else folds at completion time.
//! When every request completes — the differential grid in
//! `tests/streaming_parity.rs` guarantees it — the decision-time fold
//! counts exactly the decisions a full-sink report retains.

use super::report::{RequestMetrics, SloSpec, SystemMetrics};
use super::timeseries::{TimeSeries, TimeSeriesConfig, TimeSeriesSummary};
use crate::config::SimConfig;
use crate::util::json::Json;
use crate::util::stats::{Accumulator, Histogram};

/// γ values 0..GAMMA_HIST_BUCKETS-1 are counted exactly; anything larger
/// lands in the overflow counter (still part of the decision count and
/// the exact mean).
pub const GAMMA_HIST_BUCKETS: usize = 64;

/// Destination for completed-request records.
pub trait MetricsSink: Send {
    /// Record one completed request.
    fn record(&mut self, m: &RequestMetrics);

    /// Fold one window-size decision the moment the window policy makes
    /// it (distributed rounds only — fused rounds have no γ). The full
    /// sink ignores this: its report derives γ statistics from the
    /// retained per-request decision vectors. The streaming sink counts
    /// here so it never has to retain those vectors.
    fn record_gamma(&mut self, _gamma: u32) {}

    /// Whether the simulator should retain per-request γ-decision
    /// vectors. The full sink reports them; the streaming sink returns
    /// `false` so live-request state stays bounded too.
    fn keep_gamma_history(&self) -> bool {
        true
    }

    /// Fold one elastic-capacity step — the provisioned-target count
    /// changed to `provisioned` at `at_ms` (see [`crate::autoscale`]).
    /// Called only for autoscale-bearing runs: the t=0 initial count,
    /// one step per fleet change, and an end-of-run marker. The
    /// streaming sink integrates these into the windowed
    /// active-target-count series; the full sink ignores them — its
    /// report recomputes the same series from the step list retained in
    /// [`SystemMetrics`](super::SystemMetrics) (`O(scale events)`, so
    /// bounded either way), and the differential harness compares the
    /// two.
    fn record_capacity(&mut self, _at_ms: f64, _provisioned: u32) {}

    /// Fold the cost of one invalidated speculative window — `execution:
    /// pipelined` only (sequential runs never call this). `draft_tokens`
    /// is the window's drafted-but-discarded token count; `uplink_ms`
    /// the uplink delay it paid before dying (0 if it never shipped).
    /// The full sink ignores this: the simulator's own counters reach
    /// its report through [`SystemMetrics`](super::SystemMetrics). The
    /// streaming sink accumulates here so both sides expose the same
    /// totals (parity-locked in `tests/streaming_parity.rs`).
    fn record_wasted(&mut self, _draft_tokens: u32, _uplink_ms: f64) {}
}

/// Retains every per-request record (exact statistics, O(requests) memory).
#[derive(Default)]
pub struct FullSink {
    requests: Vec<RequestMetrics>,
}

impl FullSink {
    /// Empty sink.
    pub fn new() -> Self {
        FullSink::default()
    }

    /// Consume the sink, yielding records in completion order.
    pub fn into_requests(self) -> Vec<RequestMetrics> {
        self.requests
    }
}

impl MetricsSink for FullSink {
    fn record(&mut self, m: &RequestMetrics) {
        self.requests.push(m.clone());
    }
}

/// Map a drafter id to its pool index given cumulative pool end indices
/// (e.g. pool counts `[10, 10]` ⇒ `pool_ends = [10, 20]`). Ids at or
/// beyond the last end — synthetic drafters in fused-only runs — map to
/// the last pool; an empty `pool_ends` means a single implicit pool 0.
pub fn drafter_pool_of(drafter_id: usize, pool_ends: &[usize]) -> usize {
    for (i, &end) in pool_ends.iter().enumerate() {
        if drafter_id < end {
            return i;
        }
    }
    pool_ends.len().saturating_sub(1)
}

/// Histogram geometry + breakdown configuration for the streaming sink.
#[derive(Clone, Debug)]
pub struct StreamingConfig {
    /// Upper edge of the TTFT histogram, ms.
    pub ttft_hi_ms: f64,
    /// Upper edge of the TPOT histogram, ms.
    pub tpot_hi_ms: f64,
    /// Upper edge of the end-to-end latency histogram, ms.
    pub e2e_hi_ms: f64,
    /// Buckets per histogram (resolution = hi / buckets).
    pub buckets: usize,
    /// SLO thresholds to count attainment against (goodput counters).
    pub slos: Vec<SloSpec>,
    /// Cumulative drafter-pool end indices for the per-pool breakdown
    /// (see [`drafter_pool_of`]); empty = one implicit pool.
    pub drafter_pool_ends: Vec<usize>,
    /// Window geometry for the folded time series (scenario-dynamics
    /// observability).
    pub time_series: TimeSeriesConfig,
    /// Request classes, tier order: `(name, slo)` per tier from the
    /// config's `classes:` block. Empty = single-tenant (no per-class
    /// breakdown is kept or emitted, preserving historical bytes).
    pub classes: Vec<(String, SloSpec)>,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        // Generous edges: latencies beyond these land in the overflow
        // counter (reported, and clamped by the percentile estimator).
        StreamingConfig {
            ttft_hi_ms: 120_000.0,
            tpot_hi_ms: 2_000.0,
            e2e_hi_ms: 1_200_000.0,
            buckets: 8192,
            slos: vec![SloSpec::INTERACTIVE, SloSpec::RELAXED],
            drafter_pool_ends: Vec::new(),
            time_series: TimeSeriesConfig::default(),
            classes: Vec::new(),
        }
    }
}

impl StreamingConfig {
    /// Default geometry specialized to one simulation config: the
    /// per-pool breakdown boundaries come from the config's drafter pool
    /// slices. This is what [`crate::sim::Simulator::run_streaming`]
    /// constructs.
    pub fn for_sim(cfg: &SimConfig) -> StreamingConfig {
        let mut ends = Vec::with_capacity(cfg.drafter_pools.len());
        let mut total = 0usize;
        for p in &cfg.drafter_pools {
            total += p.count;
            ends.push(total);
        }
        StreamingConfig {
            drafter_pool_ends: ends,
            classes: cfg
                .classes
                .as_ref()
                .map(|c| c.slo_list())
                .unwrap_or_default(),
            ..StreamingConfig::default()
        }
    }
}

/// Streaming accumulators for one request group (a target server or a
/// drafter pool). O(1) memory per group.
#[derive(Clone, Debug, Default)]
struct GroupStats {
    completed: u64,
    output_tokens: u64,
    fused_rounds: u64,
    ttft: Accumulator,
    tpot: Accumulator,
    e2e: Accumulator,
    /// Finite (speculating) acceptance ratios only; fused NaNs skipped.
    acceptance: Accumulator,
}

impl GroupStats {
    fn push(&mut self, m: &RequestMetrics) {
        self.completed += 1;
        self.output_tokens += m.output_tokens as u64;
        self.fused_rounds += m.fused_rounds as u64;
        self.ttft.push(m.ttft_ms);
        self.tpot.push(m.tpot_ms);
        self.e2e.push(m.e2e_ms);
        if m.acceptance.is_finite() {
            self.acceptance.push(m.acceptance);
        }
    }

    fn summary(&self, key: usize) -> GroupSummary {
        GroupSummary {
            key,
            completed: self.completed,
            output_tokens: self.output_tokens,
            fused_rounds: self.fused_rounds,
            mean_ttft_ms: self.ttft.mean(),
            mean_tpot_ms: self.tpot.mean(),
            mean_e2e_ms: self.e2e.mean(),
            mean_acceptance: if self.acceptance.count() == 0 {
                f64::NAN
            } else {
                self.acceptance.mean()
            },
        }
    }
}

/// Folded breakdown of one request group (target server or drafter
/// pool): counts are exact; means are Welford-exact in streaming mode
/// and arithmetic in [`super::SimReport`]'s independent computation
/// (identical to floating-point noise).
#[derive(Clone, Debug)]
pub struct GroupSummary {
    /// Group key: target id, or drafter-pool index.
    pub key: usize,
    /// Completed requests in the group.
    pub completed: u64,
    /// Output tokens across the group's completed requests.
    pub output_tokens: u64,
    /// Fused rounds executed by the group's completed requests.
    pub fused_rounds: u64,
    /// Mean TTFT, ms (0 for an empty group).
    pub mean_ttft_ms: f64,
    /// Mean TPOT, ms.
    pub mean_tpot_ms: f64,
    /// Mean end-to-end latency, ms.
    pub mean_e2e_ms: f64,
    /// Mean acceptance over speculating requests (NaN if none).
    pub mean_acceptance: f64,
}

impl GroupSummary {
    /// JSON encoding (insertion-ordered keys, deterministic).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("key", self.key.into())
            .with("completed", self.completed.into())
            .with("output_tokens", self.output_tokens.into())
            .with("fused_rounds", self.fused_rounds.into())
            .with("mean_ttft_ms", self.mean_ttft_ms.into())
            .with("mean_tpot_ms", self.mean_tpot_ms.into())
            .with("mean_e2e_ms", self.mean_e2e_ms.into())
            .with("mean_acceptance", self.mean_acceptance.into())
    }
}

/// Streaming per-class (tier) state: group accumulators, the tier's own
/// SLO counter, and a windowed time series restricted to the tier's
/// completions. O(1) memory per declared class.
struct ClassStats {
    name: String,
    spec: SloSpec,
    group: GroupStats,
    attained: u64,
    ts: TimeSeries,
}

/// Per-request-class breakdown: one entry per tier declared in the
/// config's `classes:` block, in declaration (priority) order. Counts
/// are exact; means match the full sink's independent computation to
/// floating-point noise (locked in `tests/streaming_parity.rs`).
#[derive(Clone, Debug)]
pub struct ClassSummary {
    /// Tier name as declared (e.g. `"interactive"`).
    pub name: String,
    /// Latency/acceptance breakdown over the tier's completed requests
    /// (`key` is the tier index).
    pub group: GroupSummary,
    /// Attainment against the tier's *own* SLO — `completed` here is the
    /// tier's completion count, not the global one.
    pub slo: SloSummary,
    /// Windowed time series restricted to the tier's completions. Never
    /// carries capacity (`provisioned_targets`): fleet size is global,
    /// not per-tier.
    pub time_series: TimeSeriesSummary,
}

impl ClassSummary {
    /// JSON encoding (insertion-ordered keys, deterministic).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("name", self.name.as_str().into())
            .with("group", self.group.to_json())
            .with("slo", self.slo.to_json())
            .with("time_series", self.time_series.to_json())
    }
}

/// Bounded-memory window-decision (γ) histogram. All fields are integer
/// counts, so streaming and full modes agree *exactly* whenever every
/// request completes.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GammaSummary {
    /// Window decisions folded (distributed rounds).
    pub decisions: u64,
    /// Sum of all decided γ values (exact).
    pub total: u64,
    /// `hist[g]` = decisions with window size `g`; trailing zeros
    /// trimmed, capped at [`GAMMA_HIST_BUCKETS`].
    pub hist: Vec<u64>,
    /// Decisions with γ ≥ [`GAMMA_HIST_BUCKETS`] (counted in
    /// `decisions`/`total`, not in `hist`).
    pub overflow: u64,
}

impl GammaSummary {
    /// Fold one decision.
    pub fn push(&mut self, gamma: u32) {
        self.decisions += 1;
        self.total += gamma as u64;
        let g = gamma as usize;
        if g < GAMMA_HIST_BUCKETS {
            if self.hist.len() <= g {
                self.hist.resize(g + 1, 0);
            }
            self.hist[g] += 1;
        } else {
            self.overflow += 1;
        }
    }

    /// Mean window size (NaN when no decisions were folded).
    pub fn mean(&self) -> f64 {
        if self.decisions == 0 {
            f64::NAN
        } else {
            self.total as f64 / self.decisions as f64
        }
    }

    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("decisions", self.decisions.into())
            .with("total", self.total.into())
            .with("mean", self.mean().into())
            .with("overflow", self.overflow.into())
            .with(
                "hist",
                Json::Arr(self.hist.iter().map(|&c| Json::Num(c as f64)).collect()),
            )
    }
}

/// SLO-attainment counter for one threshold pair.
#[derive(Clone, Copy, Debug)]
pub struct SloSummary {
    /// The thresholds counted against.
    pub spec: SloSpec,
    /// Completed requests meeting both limits.
    pub attained: u64,
    /// Completed requests evaluated.
    pub completed: u64,
}

impl SloSummary {
    /// Attained fraction (0 when nothing completed — matching
    /// [`super::SimReport::slo_attainment`]).
    pub fn attainment(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.attained as f64 / self.completed as f64
        }
    }

    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("ttft_ms", self.spec.ttft_ms.into())
            .with("tpot_ms", self.spec.tpot_ms.into())
            .with("attained", self.attained.into())
            .with("completed", self.completed.into())
            .with("attainment", self.attainment().into())
    }
}

/// Constant-memory sink: moment accumulators + histogram percentiles +
/// per-target / per-pool / γ / SLO breakdowns.
pub struct StreamingSink {
    ttft: Accumulator,
    tpot: Accumulator,
    e2e: Accumulator,
    /// Finite (speculating) acceptance ratios only; fused NaNs skipped.
    acceptance: Accumulator,
    ttft_hist: Histogram,
    tpot_hist: Histogram,
    e2e_hist: Histogram,
    output_tokens: u64,
    completed: u64,
    fused_rounds: u64,
    /// Indexed by target id; grown on first sight (routing histogram +
    /// per-target latency/acceptance breakdown).
    per_target: Vec<GroupStats>,
    /// Indexed by drafter-pool index (see `pool_ends`).
    per_pool: Vec<GroupStats>,
    pool_ends: Vec<usize>,
    gamma: GammaSummary,
    slos: Vec<SloSpec>,
    slo_attained: Vec<u64>,
    ts: TimeSeries,
    /// One entry per declared request class; empty when single-tenant.
    per_class: Vec<ClassStats>,
    /// Draft tokens burned by invalidated speculative windows
    /// (pipelined execution; 0 — and unreported — otherwise).
    wasted_draft_tokens: u64,
    /// Uplink milliseconds burned by invalidated speculative windows.
    wasted_uplink_ms: f64,
}

impl Default for StreamingSink {
    fn default() -> Self {
        Self::new(StreamingConfig::default())
    }
}

impl StreamingSink {
    /// Sink with the given histogram geometry and breakdown config.
    pub fn new(cfg: StreamingConfig) -> Self {
        let n_slos = cfg.slos.len();
        let per_class = cfg
            .classes
            .iter()
            .map(|(name, spec)| ClassStats {
                name: name.clone(),
                spec: *spec,
                group: GroupStats::default(),
                attained: 0,
                ts: TimeSeries::new(cfg.time_series.clone()),
            })
            .collect();
        StreamingSink {
            ttft: Accumulator::new(),
            tpot: Accumulator::new(),
            e2e: Accumulator::new(),
            acceptance: Accumulator::new(),
            ttft_hist: Histogram::new(0.0, cfg.ttft_hi_ms, cfg.buckets),
            tpot_hist: Histogram::new(0.0, cfg.tpot_hi_ms, cfg.buckets),
            e2e_hist: Histogram::new(0.0, cfg.e2e_hi_ms, cfg.buckets),
            output_tokens: 0,
            completed: 0,
            fused_rounds: 0,
            per_target: Vec::new(),
            per_pool: Vec::new(),
            pool_ends: cfg.drafter_pool_ends,
            gamma: GammaSummary::default(),
            slos: cfg.slos,
            slo_attained: vec![0; n_slos],
            ts: TimeSeries::new(cfg.time_series),
            per_class,
            wasted_draft_tokens: 0,
            wasted_uplink_ms: 0.0,
        }
    }

    /// Snapshot the folded statistics.
    pub fn summary(&self) -> StreamingSummary {
        StreamingSummary {
            completed: self.completed,
            output_tokens: self.output_tokens,
            fused_rounds: self.fused_rounds,
            ttft_ms: MetricSummary::from_parts(&self.ttft, &self.ttft_hist),
            tpot_ms: MetricSummary::from_parts(&self.tpot, &self.tpot_hist),
            e2e_ms: MetricSummary::from_parts(&self.e2e, &self.e2e_hist),
            mean_acceptance: if self.acceptance.count() == 0 {
                f64::NAN
            } else {
                self.acceptance.mean()
            },
            per_target: self
                .per_target
                .iter()
                .enumerate()
                .map(|(id, g)| g.summary(id))
                .collect(),
            per_pool: self
                .per_pool
                .iter()
                .enumerate()
                .map(|(id, g)| g.summary(id))
                .collect(),
            gamma: self.gamma.clone(),
            slo: self
                .slos
                .iter()
                .zip(&self.slo_attained)
                .map(|(&spec, &attained)| SloSummary {
                    spec,
                    attained,
                    completed: self.completed,
                })
                .collect(),
            time_series: self.ts.summary(),
            per_class: self
                .per_class
                .iter()
                .enumerate()
                .map(|(ci, c)| ClassSummary {
                    name: c.name.clone(),
                    group: c.group.summary(ci),
                    slo: SloSummary {
                        spec: c.spec,
                        attained: c.attained,
                        completed: c.group.completed,
                    },
                    time_series: c.ts.summary(),
                })
                .collect(),
            wasted_draft_tokens: self.wasted_draft_tokens,
            wasted_uplink_ms: self.wasted_uplink_ms,
        }
    }
}

fn grow_and_push(groups: &mut Vec<GroupStats>, idx: usize, m: &RequestMetrics) {
    if groups.len() <= idx {
        groups.resize_with(idx + 1, GroupStats::default);
    }
    groups[idx].push(m);
}

impl MetricsSink for StreamingSink {
    fn record(&mut self, m: &RequestMetrics) {
        self.ttft.push(m.ttft_ms);
        self.tpot.push(m.tpot_ms);
        self.e2e.push(m.e2e_ms);
        self.ttft_hist.push(m.ttft_ms);
        self.tpot_hist.push(m.tpot_ms);
        self.e2e_hist.push(m.e2e_ms);
        if m.acceptance.is_finite() {
            self.acceptance.push(m.acceptance);
        }
        self.output_tokens += m.output_tokens as u64;
        self.completed += 1;
        self.fused_rounds += m.fused_rounds as u64;
        grow_and_push(&mut self.per_target, m.target_id, m);
        let pool = drafter_pool_of(m.drafter_id, &self.pool_ends);
        grow_and_push(&mut self.per_pool, pool, m);
        for (i, s) in self.slos.iter().enumerate() {
            if m.ttft_ms <= s.ttft_ms && m.tpot_ms <= s.tpot_ms {
                self.slo_attained[i] += 1;
            }
        }
        if !self.per_class.is_empty() {
            // Out-of-range ids clamp to the last (lowest-priority) tier,
            // mirroring the simulator's request-class clamping.
            let ci = m.class_id.min(self.per_class.len() - 1);
            let c = &mut self.per_class[ci];
            c.group.push(m);
            if m.ttft_ms <= c.spec.ttft_ms && m.tpot_ms <= c.spec.tpot_ms {
                c.attained += 1;
            }
            c.ts.fold(m);
        }
        self.ts.fold(m);
    }

    fn record_gamma(&mut self, gamma: u32) {
        self.gamma.push(gamma);
    }

    fn keep_gamma_history(&self) -> bool {
        false
    }

    fn record_capacity(&mut self, at_ms: f64, provisioned: u32) {
        self.ts.fold_capacity(at_ms, provisioned);
    }

    fn record_wasted(&mut self, draft_tokens: u32, uplink_ms: f64) {
        self.wasted_draft_tokens += draft_tokens as u64;
        self.wasted_uplink_ms += uplink_ms;
    }
}

/// Folded distribution of one latency metric.
#[derive(Clone, Copy, Debug)]
pub struct MetricSummary {
    /// Sample mean, ms (exact — Welford, not histogram-derived).
    pub mean: f64,
    /// Population standard deviation, ms.
    pub std: f64,
    /// Smallest observation, ms.
    pub min: f64,
    /// Largest observation, ms.
    pub max: f64,
    /// Median estimate, ms (histogram, ± one bucket).
    pub p50: f64,
    /// 90th percentile estimate, ms.
    pub p90: f64,
    /// 99th percentile estimate, ms.
    pub p99: f64,
    /// Bucket width backing the percentile estimates, ms.
    pub resolution: f64,
    /// Observations beyond the histogram's upper edge.
    pub overflow: u64,
}

impl MetricSummary {
    fn from_parts(acc: &Accumulator, hist: &Histogram) -> MetricSummary {
        MetricSummary {
            mean: acc.mean(),
            std: acc.std(),
            min: acc.min(),
            max: acc.max(),
            p50: hist.percentile(50.0),
            p90: hist.percentile(90.0),
            p99: hist.percentile(99.0),
            resolution: hist.bucket_width(),
            overflow: hist.overflow(),
        }
    }

    /// JSON encoding (insertion-ordered keys, deterministic).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("mean", self.mean.into())
            .with("std", self.std.into())
            .with("min", self.min.into())
            .with("max", self.max.into())
            .with("p50", self.p50.into())
            .with("p90", self.p90.into())
            .with("p99", self.p99.into())
            .with("resolution", self.resolution.into())
            .with("overflow", self.overflow.into())
    }
}

/// End-of-run snapshot from a [`StreamingSink`].
#[derive(Clone, Debug)]
pub struct StreamingSummary {
    /// Completed requests.
    pub completed: u64,
    /// Output tokens across completed requests.
    pub output_tokens: u64,
    /// Fused rounds executed across completed requests.
    pub fused_rounds: u64,
    /// Time-to-first-token distribution.
    pub ttft_ms: MetricSummary,
    /// Time-per-output-token distribution.
    pub tpot_ms: MetricSummary,
    /// End-to-end latency distribution.
    pub e2e_ms: MetricSummary,
    /// Mean acceptance over speculating requests (NaN if none).
    pub mean_acceptance: f64,
    /// Per-target-server breakdown, indexed by target id (the routing
    /// histogram: `per_target[t].completed` counts completions routed to
    /// target `t`).
    pub per_target: Vec<GroupSummary>,
    /// Per-drafter-pool breakdown, indexed by pool.
    pub per_pool: Vec<GroupSummary>,
    /// Window-decision (γ) histogram.
    pub gamma: GammaSummary,
    /// SLO-attainment counters, parallel to the configured SLO list.
    pub slo: Vec<SloSummary>,
    /// Fixed-width windowed time series (throughput, latency means,
    /// acceptance, active-request counts per window).
    pub time_series: TimeSeriesSummary,
    /// Per-request-class breakdown, in tier order. Empty for
    /// single-tenant runs — the `per_class` JSON key is then omitted so
    /// classless summaries keep their historical bytes.
    pub per_class: Vec<ClassSummary>,
    /// Draft tokens burned by invalidated speculative windows
    /// (pipelined execution). The JSON keys are omitted when no waste
    /// was folded, so sequential summaries keep their historical bytes.
    pub wasted_draft_tokens: u64,
    /// Uplink milliseconds burned by invalidated speculative windows.
    pub wasted_uplink_ms: f64,
}

impl StreamingSummary {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("completed", self.completed.into())
            .with("output_tokens", self.output_tokens.into())
            .with("fused_rounds", self.fused_rounds.into())
            .with("ttft_ms", self.ttft_ms.to_json())
            .with("tpot_ms", self.tpot_ms.to_json())
            .with("e2e_ms", self.e2e_ms.to_json())
            .with("mean_acceptance", self.mean_acceptance.into())
            .with(
                "per_target",
                Json::Arr(self.per_target.iter().map(|g| g.to_json()).collect()),
            )
            .with(
                "per_pool",
                Json::Arr(self.per_pool.iter().map(|g| g.to_json()).collect()),
            )
            .with("gamma", self.gamma.to_json())
            .with(
                "slo",
                Json::Arr(self.slo.iter().map(|s| s.to_json()).collect()),
            )
            .with("time_series", self.time_series.to_json());
        // Key present only for class-bearing runs (byte-stable
        // summaries otherwise — same pattern as `autoscale`).
        if !self.per_class.is_empty() {
            j.set(
                "per_class",
                Json::Arr(self.per_class.iter().map(|c| c.to_json()).collect()),
            );
        }
        // Keys present only when waste was folded (pipelined runs with
        // at least one invalidated window) — same pattern as per_class.
        if self.wasted_draft_tokens > 0 || self.wasted_uplink_ms != 0.0 {
            j.set("wasted_draft_tokens", self.wasted_draft_tokens.into());
            j.set("wasted_uplink_ms", self.wasted_uplink_ms.into());
        }
        j
    }
}

/// Complete result of a streaming-mode run: folded per-request stats plus
/// the usual system aggregates (which were always O(1) memory).
#[derive(Clone, Debug)]
pub struct StreamingReport {
    /// Folded per-request statistics.
    pub stream: StreamingSummary,
    /// System-level aggregates. `throughput_rps` equals the naive
    /// completions/duration ratio here: the interquartile steady-state
    /// estimator needs the full completion-time sample, which a
    /// streaming run does not retain.
    pub system: SystemMetrics,
}

impl StreamingReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "completed={} tput={:.1} req/s ttft={:.0} ms (p99 {:.0}) tpot={:.1} ms (p99 {:.1}) acc={:.2}",
            self.stream.completed,
            self.system.throughput_rps,
            self.stream.ttft_ms.mean,
            self.stream.ttft_ms.p99,
            self.stream.tpot_ms.mean,
            self.stream.tpot_ms.p99,
            self.stream.mean_acceptance,
        )
    }

    /// Full structured JSON (wall-clock excluded so output is
    /// bit-reproducible across runs).
    pub fn to_json(&self) -> Json {
        let mut system = Json::obj()
            .with("throughput_rps", self.system.throughput_rps.into())
            .with("token_throughput", self.system.token_throughput.into())
            .with("target_utilization", self.system.target_utilization.into())
            .with("mean_queue_delay_ms", self.system.mean_queue_delay_ms.into())
            .with("mean_net_delay_ms", self.system.mean_net_delay_ms.into())
            .with("sim_duration_ms", self.system.sim_duration_ms.into())
            .with("completed", self.system.completed.into())
            .with("events_processed", self.system.events_processed.into());
        // Key present only for autoscale-bearing runs (byte-stable
        // reports otherwise).
        if let Some(a) = &self.system.autoscale {
            system.set("autoscale", a.to_json());
        }
        // Wasted-speculation counters appear only when nonzero
        // (pipelined runs), mirroring the full report's emitter.
        if self.system.wasted_draft_tokens > 0 || self.system.wasted_uplink_ms != 0.0 {
            system.set("wasted_draft_tokens", self.system.wasted_draft_tokens.into());
            system.set("wasted_uplink_ms", self.system.wasted_uplink_ms.into());
        }
        Json::obj()
            .with("system", system)
            .with("stream", self.stream.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, Gen};

    fn req(id: usize, ttft: f64, tpot: f64, acc: f64) -> RequestMetrics {
        RequestMetrics {
            id,
            arrival_ms: 0.0,
            ttft_ms: ttft,
            tpot_ms: tpot,
            e2e_ms: ttft + tpot * 10.0,
            acceptance: acc,
            target_id: 0,
            drafter_id: 0,
            output_tokens: 11,
            gamma_decisions: Vec::new(),
            fused_rounds: 0,
            class_id: 0,
        }
    }

    #[test]
    fn full_sink_retains_records() {
        let mut s = FullSink::new();
        s.record(&req(0, 10.0, 1.0, 0.8));
        s.record(&req(1, 20.0, 2.0, 0.8));
        assert!(s.keep_gamma_history());
        let rs = s.into_requests();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[1].id, 1);
    }

    #[test]
    fn streaming_sink_folds_means_exactly() {
        let mut s = StreamingSink::default();
        for i in 0..100 {
            s.record(&req(i, 100.0 + i as f64, 10.0, 0.8));
        }
        assert!(!s.keep_gamma_history());
        let sum = s.summary();
        assert_eq!(sum.completed, 100);
        assert_eq!(sum.output_tokens, 1100);
        assert!((sum.ttft_ms.mean - 149.5).abs() < 1e-9);
        assert!((sum.tpot_ms.mean - 10.0).abs() < 1e-12);
        assert!((sum.mean_acceptance - 0.8).abs() < 1e-12);
        assert_eq!(sum.ttft_ms.min, 100.0);
        assert_eq!(sum.ttft_ms.max, 199.0);
        // p50 within one bucket of the exact median 149.5.
        assert!((sum.ttft_ms.p50 - 149.5).abs() <= sum.ttft_ms.resolution + 1e-9);
    }

    #[test]
    fn streaming_sink_skips_fused_nan_acceptance() {
        let mut s = StreamingSink::default();
        s.record(&req(0, 10.0, 1.0, f64::NAN));
        s.record(&req(1, 10.0, 1.0, 0.6));
        assert!((s.summary().mean_acceptance - 0.6).abs() < 1e-12);
        let empty = StreamingSink::default();
        assert!(empty.summary().mean_acceptance.is_nan());
    }

    #[test]
    fn streaming_json_is_deterministic() {
        let mut s = StreamingSink::default();
        s.record(&req(0, 10.0, 1.0, 0.5));
        s.record_gamma(4);
        let a = s.summary().to_json().to_string_compact();
        let b = s.summary().to_json().to_string_compact();
        assert_eq!(a, b);
        assert!(a.contains("\"p99\""));
        assert!(a.contains("\"per_target\""));
        assert!(a.contains("\"gamma\""));
        assert!(a.contains("\"slo\""));
        assert!(a.contains("\"time_series\""));
    }

    #[test]
    fn capacity_steps_reach_the_streaming_time_series() {
        let mut s = StreamingSink::default();
        s.record_capacity(0.0, 2);
        s.record_capacity(500.0, 3);
        s.record_capacity(1_000.0, 3); // end marker
        s.record(&req(0, 100.0, 10.0, 0.8)); // completes at 200 ms → window 0
        let sum = s.summary();
        // 2 targets for 500 ms + 3 targets for 500 ms over a 1 s window.
        assert!((sum.time_series.windows[0].provisioned_targets.unwrap() - 2.5).abs() < 1e-12);
        // Without capacity steps the field never appears.
        let mut plain = StreamingSink::default();
        plain.record(&req(0, 100.0, 10.0, 0.8));
        assert!(plain.summary().time_series.windows[0].provisioned_targets.is_none());
    }

    #[test]
    fn time_series_folds_with_the_other_breakdowns() {
        let mut s = StreamingSink::default();
        // Completes at 100 + 10·10 = 200 ms → window 0; a second request
        // arriving at 1.5 s completing at 1.6 s → window 1.
        s.record(&req(0, 100.0, 10.0, 0.8));
        let mut late = req(1, 50.0, 5.0, 0.6);
        late.arrival_ms = 1_500.0;
        s.record(&late);
        let sum = s.summary();
        assert_eq!(sum.time_series.windows.len(), 2);
        assert_eq!(sum.time_series.windows[0].completed, 1);
        assert_eq!(sum.time_series.windows[1].completed, 1);
        assert_eq!(
            sum.time_series.windows.iter().map(|w| w.completed).sum::<u64>(),
            sum.completed
        );
    }

    #[test]
    fn per_target_and_pool_breakdowns_fold() {
        let cfg = StreamingConfig {
            drafter_pool_ends: vec![2, 4], // drafters 0-1 → pool 0, 2-3 → pool 1
            ..StreamingConfig::default()
        };
        let mut s = StreamingSink::new(cfg);
        let mut a = req(0, 10.0, 1.0, 0.8);
        a.target_id = 1;
        a.drafter_id = 0;
        let mut b = req(1, 30.0, 3.0, 0.6);
        b.target_id = 1;
        b.drafter_id = 3;
        let mut c = req(2, 20.0, 2.0, f64::NAN);
        c.target_id = 0;
        c.drafter_id = 2;
        c.fused_rounds = 7;
        for m in [&a, &b, &c] {
            s.record(m);
        }
        let sum = s.summary();
        assert_eq!(sum.per_target.len(), 2);
        assert_eq!(sum.per_target[0].completed, 1);
        assert_eq!(sum.per_target[1].completed, 2);
        assert_eq!(sum.per_target[0].fused_rounds, 7);
        assert!((sum.per_target[1].mean_ttft_ms - 20.0).abs() < 1e-12);
        assert!(sum.per_target[0].mean_acceptance.is_nan());
        assert_eq!(sum.per_pool.len(), 2);
        assert_eq!(sum.per_pool[0].completed, 1);
        assert_eq!(sum.per_pool[1].completed, 2);
        assert!((sum.per_pool[0].mean_acceptance - 0.8).abs() < 1e-12);
        assert_eq!(sum.fused_rounds, 7);
    }

    #[test]
    fn gamma_histogram_counts_and_overflow() {
        let mut g = GammaSummary::default();
        for x in [4u32, 4, 6, 2, 100] {
            g.push(x);
        }
        assert_eq!(g.decisions, 5);
        assert_eq!(g.total, 116);
        assert_eq!(g.overflow, 1);
        assert_eq!(g.hist.len(), 7);
        assert_eq!(g.hist[4], 2);
        assert_eq!(g.hist[6], 1);
        assert_eq!(g.hist[2], 1);
        assert!((g.mean() - 23.2).abs() < 1e-12);
        assert!(GammaSummary::default().mean().is_nan());
    }

    #[test]
    fn slo_counters_match_thresholds() {
        let cfg = StreamingConfig {
            slos: vec![SloSpec { ttft_ms: 15.0, tpot_ms: 2.0 }],
            ..StreamingConfig::default()
        };
        let mut s = StreamingSink::new(cfg);
        s.record(&req(0, 10.0, 1.0, 0.8)); // attained
        s.record(&req(1, 10.0, 3.0, 0.8)); // tpot breach
        s.record(&req(2, 20.0, 1.0, 0.8)); // ttft breach
        let sum = s.summary();
        assert_eq!(sum.slo.len(), 1);
        assert_eq!(sum.slo[0].attained, 1);
        assert_eq!(sum.slo[0].completed, 3);
        assert!((sum.slo[0].attainment() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn per_class_breakdown_folds_with_tier_slos() {
        let cfg = StreamingConfig {
            classes: vec![
                ("interactive".into(), SloSpec { ttft_ms: 15.0, tpot_ms: 2.0 }),
                ("batch".into(), SloSpec { ttft_ms: 100.0, tpot_ms: 10.0 }),
            ],
            ..StreamingConfig::default()
        };
        let mut s = StreamingSink::new(cfg);
        s.record(&req(0, 10.0, 1.0, 0.8)); // interactive, attained
        let mut slow = req(1, 40.0, 1.0, 0.6); // interactive, ttft breach
        slow.class_id = 0;
        s.record(&slow);
        let mut b = req(2, 40.0, 3.0, 0.5); // batch, attained vs relaxed slo
        b.class_id = 1;
        s.record(&b);
        // Out-of-range ids clamp to the last tier.
        let mut stray = req(3, 500.0, 50.0, 0.4);
        stray.class_id = 9;
        s.record(&stray);
        let sum = s.summary();
        assert_eq!(sum.per_class.len(), 2);
        assert_eq!(sum.per_class[0].name, "interactive");
        assert_eq!(sum.per_class[0].group.completed, 2);
        assert_eq!(sum.per_class[0].slo.attained, 1);
        assert_eq!(sum.per_class[0].slo.completed, 2);
        assert_eq!(sum.per_class[1].group.completed, 2);
        assert_eq!(sum.per_class[1].slo.attained, 1); // stray breaches
        assert!((sum.per_class[0].group.mean_ttft_ms - 25.0).abs() < 1e-12);
        // Per-class windows partition the global completion count.
        let class_windows: u64 = sum
            .per_class
            .iter()
            .flat_map(|c| c.time_series.windows.iter().map(|w| w.completed))
            .sum();
        assert_eq!(class_windows, sum.completed);
        // Per-class series never carry capacity.
        for c in &sum.per_class {
            assert!(c.time_series.windows.iter().all(|w| w.provisioned_targets.is_none()));
        }
        let j = sum.to_json().to_string_compact();
        assert!(j.contains("\"per_class\""));
        assert!(j.contains("\"interactive\""));
    }

    #[test]
    fn classless_summary_has_no_per_class_key() {
        let mut s = StreamingSink::default();
        let mut m = req(0, 10.0, 1.0, 0.8);
        m.class_id = 3; // ignored without declared classes
        s.record(&m);
        let sum = s.summary();
        assert!(sum.per_class.is_empty());
        assert!(!sum.to_json().to_string_compact().contains("per_class"));
    }

    #[test]
    fn wasted_speculation_folds_and_keys_stay_off_sequential_bytes() {
        // Sequential runs never call record_wasted: the counters stay 0
        // and the JSON keys never appear (historical bytes preserved).
        let mut plain = StreamingSink::default();
        plain.record(&req(0, 10.0, 1.0, 0.8));
        let sum = plain.summary();
        assert_eq!(sum.wasted_draft_tokens, 0);
        assert_eq!(sum.wasted_uplink_ms, 0.0);
        let j = sum.to_json().to_string_compact();
        assert!(!j.contains("wasted_draft_tokens"));
        assert!(!j.contains("wasted_uplink_ms"));
        // Pipelined invalidations accumulate exactly and surface both
        // keys together.
        let mut s = StreamingSink::default();
        s.record(&req(0, 10.0, 1.0, 0.8));
        s.record_wasted(4, 12.5);
        s.record_wasted(3, 0.0); // invalidated before it shipped
        let sum = s.summary();
        assert_eq!(sum.wasted_draft_tokens, 7);
        assert!((sum.wasted_uplink_ms - 12.5).abs() < 1e-12);
        let j = sum.to_json().to_string_compact();
        assert!(j.contains("\"wasted_draft_tokens\":7"));
        assert!(j.contains("\"wasted_uplink_ms\""));
    }

    #[test]
    fn empty_class_tier_reports_zero_counts_not_nan() {
        // ISSUE satellite: tiers with no arrivals must yield 0-count
        // groups and 0.0 attainment, never NaN/divide-by-zero latencies.
        let cfg = StreamingConfig {
            classes: vec![
                ("interactive".into(), SloSpec::INTERACTIVE),
                ("batch".into(), SloSpec::RELAXED),
            ],
            ..StreamingConfig::default()
        };
        let mut s = StreamingSink::new(cfg);
        s.record(&req(0, 10.0, 1.0, 0.8)); // class 0 only
        let sum = s.summary();
        let empty = &sum.per_class[1];
        assert_eq!(empty.group.completed, 0);
        assert_eq!(empty.slo.completed, 0);
        assert!((empty.slo.attainment() - 0.0).abs() < 1e-12);
        assert_eq!(empty.group.mean_ttft_ms, 0.0);
        assert!(empty.group.mean_acceptance.is_nan());
        assert!(empty.time_series.windows.is_empty());
    }

    #[test]
    fn drafter_pool_mapping() {
        assert_eq!(drafter_pool_of(0, &[]), 0);
        assert_eq!(drafter_pool_of(99, &[]), 0);
        let ends = [10, 20, 26];
        assert_eq!(drafter_pool_of(0, &ends), 0);
        assert_eq!(drafter_pool_of(9, &ends), 0);
        assert_eq!(drafter_pool_of(10, &ends), 1);
        assert_eq!(drafter_pool_of(25, &ends), 2);
        // Synthetic ids beyond the last end map to the last pool.
        assert_eq!(drafter_pool_of(40, &ends), 2);
    }

    /// Property (ISSUE 3 satellite): per-target and per-pool breakdowns
    /// *partition* the global accumulators under generated request
    /// streams — counts sum exactly, token/fused-round totals sum
    /// exactly, and group means recombine into the global mean via the
    /// count-weighted average.
    #[test]
    fn prop_breakdowns_partition_global_accumulators() {
        run_prop("streaming breakdown partition", 60, |g: &mut Gen| {
            let n_targets = g.usize_in(1, 5);
            let n_pools = g.usize_in(1, 4);
            let pool_size = g.usize_in(1, 6);
            let ends: Vec<usize> = (1..=n_pools).map(|i| i * pool_size).collect();
            let n = g.usize_in(1, 120);
            let cfg = StreamingConfig {
                drafter_pool_ends: ends.clone(),
                slos: vec![SloSpec { ttft_ms: 50.0, tpot_ms: 5.0 }],
                ..StreamingConfig::default()
            };
            let mut sink = StreamingSink::new(cfg);
            let mut ms = Vec::with_capacity(n);
            for id in 0..n {
                let mut m = req(
                    id,
                    g.f64_in(1.0, 100.0),
                    g.f64_in(0.1, 10.0),
                    if g.bool_with(0.2) { f64::NAN } else { g.f64_in(0.0, 1.0) },
                );
                m.target_id = g.usize_in(0, n_targets - 1);
                m.drafter_id = g.usize_in(0, n_pools * pool_size - 1);
                m.output_tokens = g.usize_in(1, 300) as u32;
                m.fused_rounds = g.usize_in(0, 9) as u32;
                sink.record(&m);
                for _ in 0..g.usize_in(0, 4) {
                    sink.record_gamma(g.usize_in(0, 80) as u32);
                }
                ms.push(m);
            }
            let sum = sink.summary();
            let by_group = |groups: &[GroupSummary]| {
                let completed: u64 = groups.iter().map(|t| t.completed).sum();
                let tokens: u64 = groups.iter().map(|t| t.output_tokens).sum();
                let fused: u64 = groups.iter().map(|t| t.fused_rounds).sum();
                (completed, tokens, fused)
            };
            for groups in [&sum.per_target, &sum.per_pool] {
                let (completed, tokens, fused) = by_group(groups);
                assert_eq!(completed, sum.completed, "group counts must partition");
                assert_eq!(tokens, sum.output_tokens, "token counts must partition");
                assert_eq!(fused, sum.fused_rounds, "fused rounds must partition");
                // Count-weighted group means recombine into the global mean.
                for (pick, global) in [
                    (0usize, sum.ttft_ms.mean),
                    (1, sum.tpot_ms.mean),
                    (2, sum.e2e_ms.mean),
                ] {
                    let weighted: f64 = groups
                        .iter()
                        .map(|t| {
                            let mean = match pick {
                                0 => t.mean_ttft_ms,
                                1 => t.mean_tpot_ms,
                                _ => t.mean_e2e_ms,
                            };
                            mean * t.completed as f64
                        })
                        .sum();
                    let recombined = weighted / sum.completed as f64;
                    assert!(
                        (recombined - global).abs() <= global.abs().max(1.0) * 1e-9,
                        "weighted group means must recombine: {recombined} vs {global}"
                    );
                }
            }
            // Per-pool assignment respects the pool boundaries exactly.
            for (pool_idx, group) in sum.per_pool.iter().enumerate() {
                let expect = ms
                    .iter()
                    .filter(|m| drafter_pool_of(m.drafter_id, &ends) == pool_idx)
                    .count() as u64;
                assert_eq!(group.completed, expect);
            }
            // γ histogram totals reconcile.
            let hist_total: u64 = sum.gamma.hist.iter().sum();
            assert_eq!(hist_total + sum.gamma.overflow, sum.gamma.decisions);
            // SLO counters bounded by completions and consistent with a
            // direct recount.
            let direct = ms
                .iter()
                .filter(|m| m.ttft_ms <= 50.0 && m.tpot_ms <= 5.0)
                .count() as u64;
            assert_eq!(sum.slo[0].attained, direct);
            assert!(sum.slo[0].attained <= sum.completed);
        });
    }

    /// Property: acceptance means also recombine, weighted by the count
    /// of *speculating* (finite-acceptance) requests per group.
    #[test]
    fn prop_acceptance_recombines_over_speculating_requests() {
        run_prop("streaming acceptance recombination", 40, |g: &mut Gen| {
            let n_targets = g.usize_in(1, 4);
            let n = g.usize_in(1, 80);
            let mut sink = StreamingSink::default();
            let mut ms = Vec::with_capacity(n);
            for id in 0..n {
                let mut m = req(
                    id,
                    g.f64_in(1.0, 50.0),
                    g.f64_in(0.1, 5.0),
                    if g.bool_with(0.3) { f64::NAN } else { g.f64_in(0.0, 1.0) },
                );
                m.target_id = g.usize_in(0, n_targets - 1);
                sink.record(&m);
                ms.push(m);
            }
            let sum = sink.summary();
            let spec_count = |t: usize| {
                ms.iter()
                    .filter(|m| m.target_id == t && m.acceptance.is_finite())
                    .count()
            };
            let total_spec: usize = (0..n_targets).map(spec_count).sum();
            if total_spec == 0 {
                assert!(sum.mean_acceptance.is_nan());
                return;
            }
            let weighted: f64 = sum
                .per_target
                .iter()
                .enumerate()
                .filter(|(t, _)| spec_count(*t) > 0)
                .map(|(t, grp)| grp.mean_acceptance * spec_count(t) as f64)
                .sum();
            let recombined = weighted / total_spec as f64;
            assert!(
                (recombined - sum.mean_acceptance).abs() < 1e-9,
                "acceptance recombination: {recombined} vs {}",
                sum.mean_acceptance
            );
        });
    }
}
