//! Metric sinks — where completed-request records flow during a run.
//!
//! The simulator pushes one [`RequestMetrics`] per completed request into
//! a [`MetricsSink`]. Two implementations exist:
//!
//! * [`FullSink`] (the default behind [`crate::sim::Simulator::run`])
//!   retains every record, giving the classic [`super::SimReport`] with
//!   exact percentiles and the per-request JSON dump.
//! * [`StreamingSink`] folds each record into Welford [`Accumulator`]s
//!   and fixed-bucket [`Histogram`]s at completion time and drops it.
//!   Memory is O(buckets), independent of request count, so a single
//!   cell can simulate millions of requests; percentiles are accurate to
//!   one histogram bucket width.

use super::report::{RequestMetrics, SystemMetrics};
use crate::util::json::Json;
use crate::util::stats::{Accumulator, Histogram};

/// Destination for completed-request records.
pub trait MetricsSink: Send {
    /// Record one completed request.
    fn record(&mut self, m: &RequestMetrics);

    /// Whether the simulator should retain per-request γ-decision
    /// vectors. The full sink reports them; the streaming sink returns
    /// `false` so live-request state stays bounded too.
    fn keep_gamma_history(&self) -> bool {
        true
    }
}

/// Retains every per-request record (exact statistics, O(requests) memory).
#[derive(Default)]
pub struct FullSink {
    requests: Vec<RequestMetrics>,
}

impl FullSink {
    /// Empty sink.
    pub fn new() -> Self {
        FullSink::default()
    }

    /// Consume the sink, yielding records in completion order.
    pub fn into_requests(self) -> Vec<RequestMetrics> {
        self.requests
    }
}

impl MetricsSink for FullSink {
    fn record(&mut self, m: &RequestMetrics) {
        self.requests.push(m.clone());
    }
}

/// Histogram geometry for the streaming sink.
#[derive(Clone, Copy, Debug)]
pub struct StreamingConfig {
    /// Upper edge of the TTFT histogram, ms.
    pub ttft_hi_ms: f64,
    /// Upper edge of the TPOT histogram, ms.
    pub tpot_hi_ms: f64,
    /// Upper edge of the end-to-end latency histogram, ms.
    pub e2e_hi_ms: f64,
    /// Buckets per histogram (resolution = hi / buckets).
    pub buckets: usize,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        // Generous edges: latencies beyond these land in the overflow
        // counter (reported, and clamped by the percentile estimator).
        StreamingConfig {
            ttft_hi_ms: 120_000.0,
            tpot_hi_ms: 2_000.0,
            e2e_hi_ms: 1_200_000.0,
            buckets: 8192,
        }
    }
}

/// Constant-memory sink: moment accumulators + histogram percentiles.
pub struct StreamingSink {
    ttft: Accumulator,
    tpot: Accumulator,
    e2e: Accumulator,
    /// Finite (speculating) acceptance ratios only; fused NaNs skipped.
    acceptance: Accumulator,
    ttft_hist: Histogram,
    tpot_hist: Histogram,
    e2e_hist: Histogram,
    output_tokens: u64,
    completed: u64,
}

impl Default for StreamingSink {
    fn default() -> Self {
        Self::new(StreamingConfig::default())
    }
}

impl StreamingSink {
    /// Sink with the given histogram geometry.
    pub fn new(cfg: StreamingConfig) -> Self {
        StreamingSink {
            ttft: Accumulator::new(),
            tpot: Accumulator::new(),
            e2e: Accumulator::new(),
            acceptance: Accumulator::new(),
            ttft_hist: Histogram::new(0.0, cfg.ttft_hi_ms, cfg.buckets),
            tpot_hist: Histogram::new(0.0, cfg.tpot_hi_ms, cfg.buckets),
            e2e_hist: Histogram::new(0.0, cfg.e2e_hi_ms, cfg.buckets),
            output_tokens: 0,
            completed: 0,
        }
    }

    /// Snapshot the folded statistics.
    pub fn summary(&self) -> StreamingSummary {
        StreamingSummary {
            completed: self.completed,
            output_tokens: self.output_tokens,
            ttft_ms: MetricSummary::from_parts(&self.ttft, &self.ttft_hist),
            tpot_ms: MetricSummary::from_parts(&self.tpot, &self.tpot_hist),
            e2e_ms: MetricSummary::from_parts(&self.e2e, &self.e2e_hist),
            mean_acceptance: if self.acceptance.count() == 0 {
                f64::NAN
            } else {
                self.acceptance.mean()
            },
        }
    }
}

impl MetricsSink for StreamingSink {
    fn record(&mut self, m: &RequestMetrics) {
        self.ttft.push(m.ttft_ms);
        self.tpot.push(m.tpot_ms);
        self.e2e.push(m.e2e_ms);
        self.ttft_hist.push(m.ttft_ms);
        self.tpot_hist.push(m.tpot_ms);
        self.e2e_hist.push(m.e2e_ms);
        if m.acceptance.is_finite() {
            self.acceptance.push(m.acceptance);
        }
        self.output_tokens += m.output_tokens as u64;
        self.completed += 1;
    }

    fn keep_gamma_history(&self) -> bool {
        false
    }
}

/// Folded distribution of one latency metric.
#[derive(Clone, Copy, Debug)]
pub struct MetricSummary {
    /// Sample mean, ms (exact — Welford, not histogram-derived).
    pub mean: f64,
    /// Population standard deviation, ms.
    pub std: f64,
    /// Smallest observation, ms.
    pub min: f64,
    /// Largest observation, ms.
    pub max: f64,
    /// Median estimate, ms (histogram, ± one bucket).
    pub p50: f64,
    /// 90th percentile estimate, ms.
    pub p90: f64,
    /// 99th percentile estimate, ms.
    pub p99: f64,
    /// Bucket width backing the percentile estimates, ms.
    pub resolution: f64,
    /// Observations beyond the histogram's upper edge.
    pub overflow: u64,
}

impl MetricSummary {
    fn from_parts(acc: &Accumulator, hist: &Histogram) -> MetricSummary {
        MetricSummary {
            mean: acc.mean(),
            std: acc.std(),
            min: acc.min(),
            max: acc.max(),
            p50: hist.percentile(50.0),
            p90: hist.percentile(90.0),
            p99: hist.percentile(99.0),
            resolution: hist.bucket_width(),
            overflow: hist.overflow(),
        }
    }

    /// JSON encoding (insertion-ordered keys, deterministic).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("mean", self.mean.into())
            .with("std", self.std.into())
            .with("min", self.min.into())
            .with("max", self.max.into())
            .with("p50", self.p50.into())
            .with("p90", self.p90.into())
            .with("p99", self.p99.into())
            .with("resolution", self.resolution.into())
            .with("overflow", self.overflow.into())
    }
}

/// End-of-run snapshot from a [`StreamingSink`].
#[derive(Clone, Copy, Debug)]
pub struct StreamingSummary {
    /// Completed requests.
    pub completed: u64,
    /// Output tokens across completed requests.
    pub output_tokens: u64,
    /// Time-to-first-token distribution.
    pub ttft_ms: MetricSummary,
    /// Time-per-output-token distribution.
    pub tpot_ms: MetricSummary,
    /// End-to-end latency distribution.
    pub e2e_ms: MetricSummary,
    /// Mean acceptance over speculating requests (NaN if none).
    pub mean_acceptance: f64,
}

impl StreamingSummary {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("completed", self.completed.into())
            .with("output_tokens", self.output_tokens.into())
            .with("ttft_ms", self.ttft_ms.to_json())
            .with("tpot_ms", self.tpot_ms.to_json())
            .with("e2e_ms", self.e2e_ms.to_json())
            .with("mean_acceptance", self.mean_acceptance.into())
    }
}

/// Complete result of a streaming-mode run: folded per-request stats plus
/// the usual system aggregates (which were always O(1) memory).
#[derive(Clone, Debug)]
pub struct StreamingReport {
    /// Folded per-request statistics.
    pub stream: StreamingSummary,
    /// System-level aggregates. `throughput_rps` equals the naive
    /// completions/duration ratio here: the interquartile steady-state
    /// estimator needs the full completion-time sample, which a
    /// streaming run does not retain.
    pub system: SystemMetrics,
}

impl StreamingReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "completed={} tput={:.1} req/s ttft={:.0} ms (p99 {:.0}) tpot={:.1} ms (p99 {:.1}) acc={:.2}",
            self.stream.completed,
            self.system.throughput_rps,
            self.stream.ttft_ms.mean,
            self.stream.ttft_ms.p99,
            self.stream.tpot_ms.mean,
            self.stream.tpot_ms.p99,
            self.stream.mean_acceptance,
        )
    }

    /// Full structured JSON (wall-clock excluded so output is
    /// bit-reproducible across runs).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with(
                "system",
                Json::obj()
                    .with("throughput_rps", self.system.throughput_rps.into())
                    .with("token_throughput", self.system.token_throughput.into())
                    .with("target_utilization", self.system.target_utilization.into())
                    .with("mean_queue_delay_ms", self.system.mean_queue_delay_ms.into())
                    .with("mean_net_delay_ms", self.system.mean_net_delay_ms.into())
                    .with("sim_duration_ms", self.system.sim_duration_ms.into())
                    .with("completed", self.system.completed.into())
                    .with("events_processed", self.system.events_processed.into()),
            )
            .with("stream", self.stream.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, ttft: f64, tpot: f64, acc: f64) -> RequestMetrics {
        RequestMetrics {
            id,
            arrival_ms: 0.0,
            ttft_ms: ttft,
            tpot_ms: tpot,
            e2e_ms: ttft + tpot * 10.0,
            acceptance: acc,
            target_id: 0,
            drafter_id: 0,
            output_tokens: 11,
            gamma_decisions: Vec::new(),
            fused_rounds: 0,
        }
    }

    #[test]
    fn full_sink_retains_records() {
        let mut s = FullSink::new();
        s.record(&req(0, 10.0, 1.0, 0.8));
        s.record(&req(1, 20.0, 2.0, 0.8));
        assert!(s.keep_gamma_history());
        let rs = s.into_requests();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[1].id, 1);
    }

    #[test]
    fn streaming_sink_folds_means_exactly() {
        let mut s = StreamingSink::default();
        for i in 0..100 {
            s.record(&req(i, 100.0 + i as f64, 10.0, 0.8));
        }
        assert!(!s.keep_gamma_history());
        let sum = s.summary();
        assert_eq!(sum.completed, 100);
        assert_eq!(sum.output_tokens, 1100);
        assert!((sum.ttft_ms.mean - 149.5).abs() < 1e-9);
        assert!((sum.tpot_ms.mean - 10.0).abs() < 1e-12);
        assert!((sum.mean_acceptance - 0.8).abs() < 1e-12);
        assert_eq!(sum.ttft_ms.min, 100.0);
        assert_eq!(sum.ttft_ms.max, 199.0);
        // p50 within one bucket of the exact median 149.5.
        assert!((sum.ttft_ms.p50 - 149.5).abs() <= sum.ttft_ms.resolution + 1e-9);
    }

    #[test]
    fn streaming_sink_skips_fused_nan_acceptance() {
        let mut s = StreamingSink::default();
        s.record(&req(0, 10.0, 1.0, f64::NAN));
        s.record(&req(1, 10.0, 1.0, 0.6));
        assert!((s.summary().mean_acceptance - 0.6).abs() < 1e-12);
        let empty = StreamingSink::default();
        assert!(empty.summary().mean_acceptance.is_nan());
    }

    #[test]
    fn streaming_json_is_deterministic() {
        let mut s = StreamingSink::default();
        s.record(&req(0, 10.0, 1.0, 0.5));
        let a = s.summary().to_json().to_string_compact();
        let b = s.summary().to_json().to_string_compact();
        assert_eq!(a, b);
        assert!(a.contains("\"p99\""));
    }
}
