//! PJRT client wrapper and compiled-executable handles.
//!
//! [`Runtime`] owns one PJRT CPU client and a cache of compiled
//! executables keyed by artifact name; [`Executable`] gives a typed call
//! interface (f32/i32 tensors in, f32/i32 tensors out) with manifest
//! shape validation.

use super::artifacts::{ArtifactSpec, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// A tensor value crossing the runtime boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    /// f32 data with shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 data with shape.
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    /// Scalar i32.
    pub fn scalar_i32(x: i32) -> Tensor {
        Tensor::I32(vec![x], vec![])
    }

    /// Scalar f32.
    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32(vec![x], vec![])
    }

    /// 1-D i32.
    pub fn vec_i32(xs: Vec<i32>) -> Tensor {
        let n = xs.len();
        Tensor::I32(xs, vec![n])
    }

    /// 1-D f32.
    pub fn vec_f32(xs: Vec<f32>) -> Tensor {
        let n = xs.len();
        Tensor::F32(xs, vec![n])
    }

    /// Shape accessor.
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    /// Element count.
    pub fn elements(&self) -> usize {
        self.shape().iter().product::<usize>().max(
            // scalars have empty shape but one element
            if self.shape().is_empty() { 1 } else { 0 },
        )
    }

    /// Borrow f32 data (None for i32 tensors).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32(d, _) => Some(d),
            _ => None,
        }
    }

    /// Borrow i32 data (None for f32 tensors).
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32(d, _) => Some(d),
            _ => None,
        }
    }

    fn dtype_str(&self) -> &'static str {
        match self {
            Tensor::F32(..) => "f32",
            Tensor::I32(..) => "s32",
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32(data, shape) => {
                let l = xla::Literal::vec1(data);
                if shape.is_empty() {
                    l.reshape(&[])?
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            }
            Tensor::I32(data, shape) => {
                let l = xla::Literal::vec1(data);
                if shape.is_empty() {
                    l.reshape(&[])?
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    l.reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec_dtype: &str, shape: Vec<usize>) -> Result<Tensor> {
        match spec_dtype {
            "f32" => Ok(Tensor::F32(lit.to_vec::<f32>()?, shape)),
            "s32" => Ok(Tensor::I32(lit.to_vec::<i32>()?, shape)),
            other => Err(anyhow!("unsupported dtype {other}")),
        }
    }
}

/// One compiled artifact.
pub struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Operand/result declarations.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with manifest-validated operands; returns result tensors
    /// in manifest order.
    pub fn call(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        if args.len() != self.spec.operands.len() {
            return Err(anyhow!(
                "{}: want {} operands, got {}",
                self.spec.key,
                self.spec.operands.len(),
                args.len()
            ));
        }
        for (arg, want) in args.iter().zip(&self.spec.operands) {
            if arg.shape() != want.shape.as_slice() || arg.dtype_str() != want.dtype {
                return Err(anyhow!(
                    "{}: operand '{}' wants {:?}/{}, got {:?}/{}",
                    self.spec.key,
                    want.name,
                    want.shape,
                    want.dtype,
                    arg.shape(),
                    arg.dtype_str()
                ));
            }
        }
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.results.len() {
            return Err(anyhow!(
                "{}: want {} results, got {}",
                self.spec.key,
                self.spec.results.len(),
                parts.len()
            ));
        }
        parts
            .iter()
            .zip(&self.spec.results)
            .map(|(lit, want)| Tensor::from_literal(lit, &want.dtype, want.shape.clone()))
            .collect()
    }
}

/// PJRT client + compiled-executable cache. `Sync` via an internal mutex
/// on the cache; PJRT execution itself is invoked from worker threads.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) an executable by artifact key.
    pub fn executable(&self, key: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(key).map_err(|e| anyhow!(e))?.clone();
        let path = spec
            .path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = std::sync::Arc::new(Executable { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(key.to_string(), executable.clone());
        Ok(executable)
    }

    /// Compile every artifact up front (serving warm-up).
    pub fn warmup(&self) -> Result<()> {
        let keys: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        for k in keys {
            self.executable(&k)?;
        }
        Ok(())
    }

    /// Compile only the artifacts whose key starts with `prefix` —
    /// drafter workers warm `draft_*`, verifiers `target_*`, so each
    /// role pays only its own parse+compile cost.
    pub fn warmup_prefix(&self, prefix: &str) -> Result<()> {
        let keys: Vec<String> = self
            .manifest
            .artifacts
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        for k in keys {
            self.executable(&k)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_accessors() {
        let t = Tensor::vec_f32(vec![1.0, 2.0]);
        assert_eq!(t.shape(), &[2]);
        assert_eq!(t.elements(), 2);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(t.as_i32().is_none());
        let s = Tensor::scalar_i32(7);
        assert_eq!(s.elements(), 1);
        assert!(s.shape().is_empty());
    }

    #[test]
    fn dtype_strings_match_manifest_vocabulary() {
        assert_eq!(Tensor::scalar_f32(0.0).dtype_str(), "f32");
        assert_eq!(Tensor::scalar_i32(0).dtype_str(), "s32");
    }
}
