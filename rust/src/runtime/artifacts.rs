//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. `manifest.json` names every HLO artifact and its
//! operand/result shapes; the runtime validates against it at load time
//! so shape drift fails fast instead of crashing inside PJRT.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One operand or result declaration.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Logical name (e.g. `"tokens"`).
    pub name: String,
    /// Dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Dtype string: `"f32"` or `"s32"`.
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec, String> {
        Ok(TensorSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or("tensor: missing name")?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or("tensor: missing shape")?
                .iter()
                .map(|x| x.as_usize().ok_or("tensor: bad dim"))
                .collect::<Result<_, _>>()?,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or("tensor: missing dtype")?
                .to_string(),
        })
    }
}

/// One artifact entry (an HLO module on disk).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact key, e.g. `"draft_decode"`.
    pub key: String,
    /// File path (relative to the artifacts dir).
    pub path: PathBuf,
    /// Operand declarations in call order.
    pub operands: Vec<TensorSpec>,
    /// Result declarations in tuple order.
    pub results: Vec<TensorSpec>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory containing the artifacts.
    pub dir: PathBuf,
    /// Vocabulary size of the LM pair.
    pub vocab: usize,
    /// Padded prompt length of the prefill artifacts.
    pub prompt_pad: usize,
    /// Window sizes with a pre-lowered verify artifact.
    pub verify_gammas: Vec<u32>,
    /// Draft model max sequence length.
    pub draft_max_len: usize,
    /// Target model max sequence length.
    pub target_max_len: usize,
    /// All artifacts by key.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {path:?}: {e} (run `make artifacts` first)"))?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        let model_field = |model: &str, field: &str| -> Result<usize, String> {
            j.path(&[model, field])
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("manifest: missing {model}.{field}"))
        };
        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .ok_or("manifest: missing artifacts")?;
        let Json::Obj(pairs) = arts else {
            return Err("manifest: artifacts must be an object".into());
        };
        for (key, spec) in pairs {
            let operands = spec
                .get("operands")
                .and_then(Json::as_arr)
                .ok_or("artifact: missing operands")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?;
            let results = spec
                .get("results")
                .and_then(Json::as_arr)
                .ok_or("artifact: missing results")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?;
            artifacts.insert(
                key.clone(),
                ArtifactSpec {
                    key: key.clone(),
                    path: dir.join(
                        spec.get("path")
                            .and_then(Json::as_str)
                            .ok_or("artifact: missing path")?,
                    ),
                    operands,
                    results,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            vocab: j
                .get("vocab")
                .and_then(Json::as_usize)
                .ok_or("manifest: missing vocab")?,
            prompt_pad: j
                .get("prompt_pad")
                .and_then(Json::as_usize)
                .ok_or("manifest: missing prompt_pad")?,
            verify_gammas: j
                .get("verify_gammas")
                .and_then(Json::as_arr)
                .ok_or("manifest: missing verify_gammas")?
                .iter()
                .map(|x| x.as_u64().map(|v| v as u32).ok_or("bad gamma"))
                .collect::<Result<_, _>>()?,
            draft_max_len: model_field("draft", "max_len")?,
            target_max_len: model_field("target", "max_len")?,
            artifacts,
        })
    }

    /// Artifact by key.
    pub fn get(&self, key: &str) -> Result<&ArtifactSpec, String> {
        self.artifacts
            .get(key)
            .ok_or_else(|| format!("manifest: no artifact '{key}'"))
    }

    /// The largest available verify γ that is ≤ `wanted` (the real-path
    /// clamp for AWC decisions).
    pub fn nearest_verify_gamma(&self, wanted: u32) -> u32 {
        self.verify_gammas
            .iter()
            .copied()
            .filter(|&g| g <= wanted.max(1))
            .max()
            .unwrap_or_else(|| *self.verify_gammas.first().unwrap_or(&1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn nearest_gamma_clamps() {
        let m = Manifest {
            dir: PathBuf::new(),
            vocab: 256,
            prompt_pad: 128,
            verify_gammas: vec![1, 2, 3, 4, 6, 8],
            draft_max_len: 384,
            target_max_len: 384,
            artifacts: BTreeMap::new(),
        };
        assert_eq!(m.nearest_verify_gamma(5), 4);
        assert_eq!(m.nearest_verify_gamma(12), 8);
        assert_eq!(m.nearest_verify_gamma(1), 1);
        assert_eq!(m.nearest_verify_gamma(0), 1);
    }

    #[test]
    fn loads_real_manifest_when_present() {
        // Runs against the artifacts produced by `make artifacts`;
        // silently skipped when they have not been built.
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.vocab, 256);
        for key in ["draft_prefill", "draft_decode", "target_prefill"] {
            let a = m.get(key).unwrap();
            assert!(a.path.exists(), "{:?} missing", a.path);
            assert!(!a.operands.is_empty());
        }
        for g in &m.verify_gammas {
            assert!(m.get(&format!("target_verify_g{g}")).is_ok());
        }
    }
}
