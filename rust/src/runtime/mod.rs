//! PJRT runtime bridge: load the AOT-compiled HLO-text artifacts and
//! execute them from the L3 hot path (no python anywhere).
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6, PJRT C API, CPU plugin):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. See /opt/xla-example/load_hlo for the
//! reference wiring and the HLO-text-vs-proto gotcha.

pub mod artifacts;
pub mod exec;

pub use artifacts::{ArtifactSpec, Manifest};
pub use exec::{Executable, Runtime};
