//! Hardware performance modeling engine (paper §3.1).
//!
//! [`predictor`] is the VIDUR-role analytical latency model behind the
//! `predict(op, shape, hardware)` API; [`oracle`] is the synthetic
//! "real hardware" testbed used by the Fig-4 calibration experiment.

pub mod oracle;
pub mod predictor;

pub use oracle::{HardwareOracle, OracleOverheads};
pub use predictor::{Efficiency, Hardware, Op, Predictor};
