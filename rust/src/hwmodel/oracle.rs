//! The "real hardware" oracle used for the Fig-4 calibration study.
//!
//! The paper validates VIDUR's predictions against measurements on real
//! A40/A100/H100 machines and reports: prefill MAE ≈ 7.4%, decode MAE
//! ≈ 5.2%, with the simulator *systematically under-predicting* because
//! VIDUR models only MLP/Attention kernel time and omits NCCL collectives
//! and non-kernel work (§5.1).
//!
//! We have no GPUs in this environment, so the oracle plays the role of
//! the testbed: it is the same roofline surface *plus* the terms VIDUR
//! omits — an NCCL communication overhead for multi-GPU models, a
//! non-kernel (scheduler/python/framework) time slice, and run-to-run
//! measurement noise. The calibration experiment then measures exactly
//! what the paper measures: how far the predictor lands from the oracle.

use super::predictor::{Hardware, Op, Predictor};
use crate::cluster::ModelSpec;
use crate::util::rng::Pcg64;

/// Overheads the predictor knowingly omits (present only on "hardware").
#[derive(Clone, Debug)]
pub struct OracleOverheads {
    /// Extra fraction of kernel time spent in NCCL collectives per
    /// tensor-parallel degree beyond 1 (e.g. 0.025 ⇒ +7.5% at TP=4).
    pub nccl_frac_per_tp: f64,
    /// Non-kernel time as a fraction of kernel time (CPU-side scheduling,
    /// tokenization, framework glue).
    pub nonkernel_frac: f64,
    /// Fixed per-invocation host overhead, ms.
    pub host_ms: f64,
    /// Std-dev of multiplicative measurement noise.
    pub noise_std: f64,
}

impl Default for OracleOverheads {
    fn default() -> Self {
        OracleOverheads {
            nccl_frac_per_tp: 0.018,
            nonkernel_frac: 0.035,
            host_ms: 0.35,
            noise_std: 0.025,
        }
    }
}

/// Synthetic testbed: predictor surface + omitted overheads + noise.
pub struct HardwareOracle {
    predictor: Predictor,
    over: OracleOverheads,
    rng: Pcg64,
}

impl HardwareOracle {
    /// Oracle with default overheads, seeded for reproducible "runs".
    pub fn new(seed: u64) -> Self {
        HardwareOracle {
            predictor: Predictor::new(),
            over: OracleOverheads::default(),
            rng: Pcg64::new(seed),
        }
    }

    /// Oracle with explicit overheads.
    pub fn with_overheads(seed: u64, over: OracleOverheads) -> Self {
        HardwareOracle {
            predictor: Predictor::new(),
            over,
            rng: Pcg64::new(seed),
        }
    }

    /// One "measured" execution of `op` on the synthetic testbed (ms).
    pub fn measure(&mut self, op: Op, model: &ModelSpec, hw: Hardware) -> f64 {
        let kernel_ms = self.predictor.predict(op, model, hw);
        let nccl = self.over.nccl_frac_per_tp * (hw.tp.saturating_sub(1)) as f64;
        let systematic = kernel_ms * (1.0 + nccl + self.over.nonkernel_frac) + self.over.host_ms;
        let noise = 1.0 + self.over.noise_std * self.rng.normal();
        systematic * noise.max(0.5)
    }

    /// Mean and std of `n` measurements (the error bars in Fig. 4).
    pub fn measure_stats(
        &mut self,
        op: Op,
        model: &ModelSpec,
        hw: Hardware,
        n: usize,
    ) -> (f64, f64) {
        let xs: Vec<f64> = (0..n).map(|_| self.measure(op, model, hw)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        (mean, var.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::{A100, A40};
    use crate::cluster::model::{LLAMA2_70B, LLAMA2_7B};

    #[test]
    fn oracle_exceeds_prediction_systematically() {
        // The paper's key calibration observation: VIDUR's predictions are
        // consistently *below* hardware measurements.
        let p = Predictor::new();
        let mut o = HardwareOracle::new(1);
        let hw = Hardware { gpu: &A100, tp: 4 };
        let op = Op::Decode { batch: 8, avg_ctx: 512 };
        let predicted = p.predict(op, &LLAMA2_70B, hw);
        let (measured, _) = o.measure_stats(op, &LLAMA2_70B, hw, 100);
        assert!(measured > predicted, "measured={measured} predicted={predicted}");
        // And within a plausible calibration band (paper: 5-8% MAE).
        let err = (measured - predicted) / measured;
        assert!(err > 0.01 && err < 0.20, "err={err}");
    }

    #[test]
    fn single_gpu_has_no_nccl_term() {
        let mut o1 = HardwareOracle::new(2);
        let mut o2 = HardwareOracle::new(2);
        let op = Op::Decode { batch: 1, avg_ctx: 128 };
        let hw1 = Hardware { gpu: &A40, tp: 1 };
        // Same seed, same op: only deterministic path differences matter.
        let a = o1.measure(op, &LLAMA2_7B, hw1);
        let b = o2.measure(op, &LLAMA2_7B, hw1);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn stats_have_small_spread() {
        let mut o = HardwareOracle::new(3);
        let hw = Hardware { gpu: &A40, tp: 1 };
        let (mean, std) = o.measure_stats(Op::Prefill { tokens: 512, batch: 4 }, &LLAMA2_7B, hw, 100);
        assert!(std / mean < 0.05, "noise should be a few percent");
    }
}
