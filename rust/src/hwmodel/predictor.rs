//! Roofline latency predictor — the stand-in for VIDUR's empirically
//! profiled single-node predictors (paper §3.1).
//!
//! DSD-Sim consumes latencies through the same narrow API the paper
//! describes, `predict(op, shape, hardware)`: see [`Predictor::predict`].
//! The surface is an analytical roofline — per-op latency is the max of
//! compute time and memory time, plus per-layer kernel overheads and
//! tensor-parallel collective costs. VIDUR's predictors are tabulated
//! measurements of exactly these quantities; any monotone surface with the
//! correct batch/context/model scaling exercises identical scheduler
//! dynamics (DESIGN.md §4 records this substitution).

use crate::cluster::{GpuSpec, ModelSpec};

/// An inference operation whose latency is being predicted.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Op {
    /// Prompt prefill: `batch` requests totalling `tokens` prompt tokens.
    Prefill { tokens: u32, batch: u32 },
    /// Autoregressive decode step: `batch` sequences, one new token each,
    /// mean context length `avg_ctx`.
    Decode { batch: u32, avg_ctx: u32 },
    /// Speculative verification: `batch` sequences, each scoring
    /// `window + 1` positions against mean context `avg_ctx`.
    /// Compute-wise this is a short prefill that reads weights once.
    Verify {
        batch: u32,
        window: u32,
        avg_ctx: u32,
    },
}

/// Hardware configuration an op executes on.
#[derive(Clone, Copy, Debug)]
pub struct Hardware<'a> {
    /// GPU SKU.
    pub gpu: &'a GpuSpec,
    /// Tensor-parallel degree (weights sharded across `tp` GPUs).
    pub tp: u32,
}

/// Tunable efficiency constants — the "fitted coefficients" of the
/// analytical model. Defaults are chosen to land in the regimes the
/// paper's plots show (tens of ms decode for 70B on A100, hundreds of ms
/// prefill, etc.).
#[derive(Clone, Debug)]
pub struct Efficiency {
    /// Achievable fraction of peak TFLOPs on large GEMMs (prefill).
    pub mfu_prefill: f64,
    /// Achievable fraction of peak TFLOPs on batched decode GEMMs.
    pub mfu_decode: f64,
    /// Achievable fraction of peak memory bandwidth (single GPU).
    pub bw_frac: f64,
    /// Sub-linear tensor-parallel bandwidth scaling exponent: aggregate
    /// effective bandwidth is `bw · bw_frac · tp^bw_tp_exp`. Real TP
    /// serving loses bandwidth efficiency to sync stalls and uneven
    /// shards (an A100 TP=4 70B decode is ~45–55 ms/token, not the
    /// ~22 ms a linear model predicts).
    pub bw_tp_exp: f64,
    /// Latency per tensor-parallel all-reduce, microseconds (per layer,
    /// on top of the bandwidth term).
    pub allreduce_lat_us: f64,
}

impl Default for Efficiency {
    fn default() -> Self {
        Efficiency {
            mfu_prefill: 0.52,
            mfu_decode: 0.35,
            bw_frac: 0.80,
            bw_tp_exp: 0.64,
            allreduce_lat_us: 20.0,
        }
    }
}

/// The predictor: stateless, cheap, callable millions of times per
/// simulated second.
#[derive(Clone, Debug, Default)]
pub struct Predictor {
    /// Efficiency constants (see [`Efficiency`]).
    pub eff: Efficiency,
}

impl Predictor {
    /// Predictor with default efficiency constants.
    pub fn new() -> Self {
        Self::default()
    }

    /// Predict the latency (milliseconds) of `op` for `model` on `hw`.
    ///
    /// This is the `predict(op, shape, hardware)` API of paper §3.1.
    pub fn predict(&self, op: Op, model: &ModelSpec, hw: Hardware) -> f64 {
        match op {
            Op::Prefill { tokens, batch } => self.prefill_ms(model, hw, tokens, batch),
            Op::Decode { batch, avg_ctx } => self.decode_ms(model, hw, batch, avg_ctx),
            Op::Verify {
                batch,
                window,
                avg_ctx,
            } => self.verify_ms(model, hw, batch, window, avg_ctx),
        }
    }

    /// Effective compute rate, FLOP/ms.
    fn flops_per_ms(&self, hw: Hardware, mfu: f64) -> f64 {
        hw.gpu.tflops * 1e12 * mfu * hw.tp as f64 / 1e3
    }

    /// Effective aggregate memory bandwidth, bytes/ms (sub-linear in TP).
    fn bytes_per_ms(&self, hw: Hardware) -> f64 {
        hw.gpu.mem_bw_gbps * 1e9 * self.eff.bw_frac * (hw.tp as f64).powf(self.eff.bw_tp_exp)
            / 1e3
    }

    /// Per-forward fixed costs: kernel launches for each layer (several
    /// kernels per layer) plus tensor-parallel all-reduces (2 per layer).
    fn fixed_ms(&self, model: &ModelSpec, hw: Hardware, act_bytes: f64) -> f64 {
        let layers = model.layers as f64;
        let launches_ms = layers * 4.0 * hw.gpu.kernel_overhead_us / 1e3;
        if hw.tp <= 1 {
            return launches_ms;
        }
        // Ring all-reduce: 2(p-1)/p of the activation crosses links, twice
        // per layer (attention out-proj + MLP down-proj).
        let p = hw.tp as f64;
        let ar_bytes = 2.0 * (p - 1.0) / p * act_bytes;
        let ar_bw_ms = ar_bytes / (hw.gpu.link_bw_gbps * 1e9 / 1e3);
        let ar_lat_ms = self.eff.allreduce_lat_us / 1e3;
        launches_ms + layers * 2.0 * (ar_lat_ms + ar_bw_ms)
    }

    /// Prefill latency (ms): compute-bound GEMMs over all prompt tokens,
    /// floored by one pass over the weights.
    pub fn prefill_ms(&self, model: &ModelSpec, hw: Hardware, tokens: u32, _batch: u32) -> f64 {
        let t = tokens as f64;
        let gemm_flops = t * model.flops_per_token();
        // Self-attention inside the prompt: ~T^2 term per request folded
        // into an average: attn_flops(T/2) per token.
        let attn_flops = t * model.attn_flops_per_token(t / 2.0);
        let compute_ms = (gemm_flops + attn_flops) / self.flops_per_ms(hw, self.eff.mfu_prefill);
        let mem_ms = model.weight_bytes() / self.bytes_per_ms(hw);
        let act_bytes = t * model.hidden as f64 * model.dtype_bytes;
        compute_ms.max(mem_ms) + self.fixed_ms(model, hw, act_bytes)
    }

    /// Decode latency (ms): memory-bound weight pass shared by the batch,
    /// plus KV-cache reads, vs the batched GEMM compute.
    pub fn decode_ms(&self, model: &ModelSpec, hw: Hardware, batch: u32, avg_ctx: u32) -> f64 {
        let b = batch.max(1) as f64;
        let weights_ms = model.weight_bytes() / self.bytes_per_ms(hw);
        let kv_ms = b * model.kv_bytes_per_token() * avg_ctx as f64 / self.bytes_per_ms(hw);
        let compute_ms = (b * model.flops_per_token()
            + b * model.attn_flops_per_token(avg_ctx as f64))
            / self.flops_per_ms(hw, self.eff.mfu_decode);
        let act_bytes = b * model.hidden as f64 * model.dtype_bytes;
        (weights_ms + kv_ms).max(compute_ms) + self.fixed_ms(model, hw, act_bytes)
    }

    /// Verification latency (ms): `batch` sequences each scoring
    /// `window + 1` positions — one weight pass, short-prefill compute.
    pub fn verify_ms(
        &self,
        model: &ModelSpec,
        hw: Hardware,
        batch: u32,
        window: u32,
        avg_ctx: u32,
    ) -> f64 {
        self.verify_ms_ragged(model, hw, batch, batch * (window + 1), avg_ctx)
    }

    /// Ragged verification batch (ORCA-style): mixed window sizes pack
    /// without padding, so cost is driven by the *total* scored tokens.
    pub fn verify_ms_ragged(
        &self,
        model: &ModelSpec,
        hw: Hardware,
        batch: u32,
        total_tokens: u32,
        avg_ctx: u32,
    ) -> f64 {
        let b = batch.max(1) as f64;
        let toks = total_tokens.max(1) as f64;
        let weights_ms = model.weight_bytes() / self.bytes_per_ms(hw);
        let kv_ms = b * model.kv_bytes_per_token() * avg_ctx as f64 / self.bytes_per_ms(hw);
        let compute_ms = (toks * model.flops_per_token()
            + toks * model.attn_flops_per_token(avg_ctx as f64))
            / self.flops_per_ms(hw, self.eff.mfu_decode);
        let act_bytes = toks * model.hidden as f64 * model.dtype_bytes;
        (weights_ms + kv_ms).max(compute_ms) + self.fixed_ms(model, hw, act_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::{A100, A40, H100};
    use crate::cluster::model::{LLAMA2_70B, LLAMA2_7B};

    fn hw<'a>(gpu: &'a GpuSpec, tp: u32) -> Hardware<'a> {
        Hardware { gpu, tp }
    }

    #[test]
    fn decode_is_memory_bound_at_small_batch() {
        let p = Predictor::new();
        // 7B on A40: one weight pass ≈ 13.5 GB / (0.78*696 GB/s) ≈ 25 ms.
        let ms = p.decode_ms(&LLAMA2_7B, hw(&A40, 1), 1, 256);
        assert!(ms > 15.0 && ms < 45.0, "ms={ms}");
    }

    #[test]
    fn decode_scales_sublinearly_with_batch() {
        let p = Predictor::new();
        let b1 = p.decode_ms(&LLAMA2_70B, hw(&A100, 4), 1, 512);
        let b16 = p.decode_ms(&LLAMA2_70B, hw(&A100, 4), 16, 512);
        assert!(b16 < 16.0 * b1, "batching must amortize weight reads");
        assert!(b16 > b1, "more work cannot be faster");
    }

    #[test]
    fn prefill_scales_with_tokens() {
        let p = Predictor::new();
        let t256 = p.prefill_ms(&LLAMA2_70B, hw(&A100, 4), 256, 1);
        let t2048 = p.prefill_ms(&LLAMA2_70B, hw(&A100, 4), 2048, 1);
        assert!(t2048 > 4.0 * t256, "t256={t256} t2048={t2048}");
    }

    #[test]
    fn faster_gpu_is_faster() {
        let p = Predictor::new();
        let a100 = p.predict(Op::Decode { batch: 8, avg_ctx: 512 }, &LLAMA2_70B, hw(&A100, 4));
        let h100 = p.predict(Op::Decode { batch: 8, avg_ctx: 512 }, &LLAMA2_70B, hw(&H100, 4));
        assert!(h100 < a100);
    }

    #[test]
    fn tp_reduces_latency_with_overhead() {
        let p = Predictor::new();
        let tp1_time = p.decode_ms(&LLAMA2_70B, hw(&A100, 1), 4, 512);
        let tp4_time = p.decode_ms(&LLAMA2_70B, hw(&A100, 4), 4, 512);
        assert!(tp4_time < tp1_time);
        assert!(tp4_time > tp1_time / 4.0, "collectives cost something");
    }

    #[test]
    fn verify_cheaper_than_window_decodes() {
        let p = Predictor::new();
        let verify = p.verify_ms(&LLAMA2_70B, hw(&A100, 4), 8, 4, 512);
        let five_decodes = 5.0 * p.decode_ms(&LLAMA2_70B, hw(&A100, 4), 8, 512);
        assert!(
            verify < five_decodes * 0.6,
            "parallel verification is the whole point: {verify} vs {five_decodes}"
        );
    }

    #[test]
    fn edge_decode_much_faster_than_cloud_decode() {
        // Drafting on the edge must beat a full 70B decode for SD to help
        // (cost ratio c < 1, paper Eq. 2).
        let p = Predictor::new();
        let draft = p.decode_ms(&LLAMA2_7B, hw(&A40, 1), 1, 256);
        let target = p.decode_ms(&LLAMA2_70B, hw(&A100, 4), 1, 256);
        assert!(
            draft < target * 0.85,
            "draft={draft} target={target} (c = {})",
            draft / target
        );
        // And the absolute levels are in the published serving regime.
        assert!(draft > 15.0 && draft < 40.0, "draft={draft}");
        assert!(target > 30.0 && target < 70.0, "target={target}");
    }
}
