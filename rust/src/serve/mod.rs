//! Long-running grid service — the network front half of the
//! cluster-scale sweep story (the back half is `sweep::shard`).
//!
//! `dsd serve --listen <addr>` runs a [`service::GridService`]: a TCP
//! listener speaking a line-delimited, versioned JSON protocol
//! ([`protocol`]) over which clients submit sweep grids, poll progress,
//! fetch finished summaries, cancel jobs, and pull a live `stats`
//! introspection snapshot (metrics registry + per-job phase timings,
//! surfaced by `dsd submit --stats`). Execution reuses the
//! content-addressed cell cache, so a service pointed at a warm cache
//! directory answers repeat submissions without re-simulating, and a
//! grid being chewed by `--shard` workers elsewhere benefits from the
//! shared `cells/` layout.
//!
//! Design constraints, in order:
//!
//! 1. **Validated parsing surface.** Every inbound line passes through
//!    [`protocol::parse_request`], which never panics and maps every
//!    malformed, unknown, over-version, or oversized input to a named
//!    [`protocol::RequestError`] code. Fuzz-style property tests live
//!    beside the parser.
//! 2. **Bounded everything.** Request lines are size-capped *while
//!    reading* (a 10 GB line never buffers), sockets carry read/write
//!    timeouts, and the job queue is bounded — submissions beyond the
//!    bound get a `queue-full` backpressure error instead of unbounded
//!    memory growth.
//! 3. **Deterministic outputs.** A fetched summary is the exact pretty
//!    text the single-process `dsd sweep` run writes (transmitted as a
//!    JSON string — string escaping is lossless, so no float
//!    re-serialization can drift the bytes).
//! 4. **Graceful drain.** A shutdown request stops intake, finishes the
//!    running job, answers in-flight connections, then exits.
//!
//! [`client::GridClient`] is the matching blocking client; `dsd submit`
//! wraps it on the CLI.

pub mod client;
pub mod job;
pub mod protocol;
pub mod service;

pub use client::GridClient;
pub use job::{JobQueue, JobState, JobStatus};
pub use protocol::{parse_request, Request, RequestError, PROTOCOL_VERSION};
pub use service::{GridService, ServeOptions};
