//! Bounded job queue shared between the service's connection threads
//! (producers) and its single sweep worker (consumer).
//!
//! The queue is a `Mutex<_>` + `Condvar` pair — no channels, no
//! dependencies — and is bounded by the number of *non-terminal* jobs
//! (queued + running): a full queue rejects submissions with
//! backpressure instead of buffering grids without limit. Terminal jobs
//! (completed / failed / cancelled) stay resident so late `poll` /
//! `fetch` requests can still be answered; they don't count against the
//! bound.

use crate::obs::registry;
use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Lifecycle of one submitted grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for the worker.
    Queued,
    /// The worker is executing its cells.
    Running,
    /// All cells done; summary available.
    Completed,
    /// The grid failed to parse/expand, or every path errored.
    Failed,
    /// Cancelled before completion (queued jobs skip execution;
    /// running jobs stop at the next chunk boundary).
    Cancelled,
}

impl JobState {
    /// Wire label.
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    fn terminal(&self) -> bool {
        matches!(
            self,
            JobState::Completed | JobState::Failed | JobState::Cancelled
        )
    }
}

/// Public progress snapshot of a job.
#[derive(Clone, Debug)]
pub struct JobStatus {
    /// Job id (assigned at submit, monotonically increasing from 0).
    pub id: u64,
    /// Current lifecycle state.
    pub state: JobState,
    /// Cells finished so far (executed + cache-served).
    pub done: usize,
    /// Total cells in the job's grid (0 until the worker expands it).
    pub total: usize,
    /// Cells that entered the simulator.
    pub executed: usize,
    /// Cells served from the cell cache.
    pub cache_hits: usize,
    /// Cells whose outcome is an error.
    pub failed_cells: usize,
    /// Failure reason (Failed state only).
    pub error: Option<String>,
}

impl JobStatus {
    /// Wire encoding of a `poll-progress` answer's payload.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("job", self.id.into())
            .with("state", self.state.label().into())
            .with("done", (self.done as u64).into())
            .with("total", (self.total as u64).into())
            .with("executed", (self.executed as u64).into())
            .with("cache_hits", (self.cache_hits as u64).into())
            .with("failed_cells", (self.failed_cells as u64).into());
        if let Some(e) = &self.error {
            j.set("error", e.as_str().into());
        }
        j
    }
}

/// One job's full record (internal).
struct Job {
    status: JobStatus,
    grid_yaml: String,
    streaming: Option<bool>,
    /// Exact pretty summary text (Completed only).
    summary: Option<String>,
    /// Wall-clock lifecycle stamps for the `stats` introspection
    /// surface. Wall-clock only — simulated time never appears here.
    t_submitted: Instant,
    t_started: Option<Instant>,
    t_finished: Option<Instant>,
}

/// What the worker receives for one unit of work.
pub struct ClaimedJob {
    /// Job id to report progress against.
    pub id: u64,
    /// The submitted grid YAML, verbatim.
    pub grid_yaml: String,
    /// Submit-time streaming override (`None` = grid decides).
    pub streaming: Option<bool>,
}

/// Why a submission was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bound on live (queued + running) jobs is reached.
    QueueFull { live: usize, max: usize },
    /// The service is draining; no new work is accepted.
    Draining,
}

impl SubmitError {
    /// Stable wire code (service-level, same namespace as parse codes).
    pub fn code(&self) -> &'static str {
        match self {
            SubmitError::QueueFull { .. } => "queue-full",
            SubmitError::Draining => "shutting-down",
        }
    }

    /// Human-readable description.
    pub fn message(&self) -> String {
        match self {
            SubmitError::QueueFull { live, max } => format!(
                "job queue is full ({live} live jobs, bound {max}); retry after a job finishes"
            ),
            SubmitError::Draining => "service is shutting down; no new jobs accepted".into(),
        }
    }
}

/// Why a summary fetch was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FetchError {
    /// No job with that id was ever submitted.
    UnknownJob,
    /// The job exists but hasn't completed yet.
    NotComplete { state: JobState },
    /// The job terminated without a summary.
    JobFailed { error: String },
    /// The job was cancelled.
    JobCancelled,
}

impl FetchError {
    /// Stable wire code.
    pub fn code(&self) -> &'static str {
        match self {
            FetchError::UnknownJob => "unknown-job",
            FetchError::NotComplete { .. } => "not-complete",
            FetchError::JobFailed { .. } => "job-failed",
            FetchError::JobCancelled => "job-cancelled",
        }
    }

    /// Human-readable description.
    pub fn message(&self) -> String {
        match self {
            FetchError::UnknownJob => "no such job".into(),
            FetchError::NotComplete { state } => format!(
                "job is {} — poll until it completes before fetching",
                state.label()
            ),
            FetchError::JobFailed { error } => format!("job failed: {error}"),
            FetchError::JobCancelled => "job was cancelled".into(),
        }
    }
}

struct QueueInner {
    jobs: Vec<Job>,
    /// Ids waiting for the worker, FIFO.
    pending: VecDeque<u64>,
    draining: bool,
}

/// The bounded FIFO job queue. All methods are `&self`; one instance is
/// shared via `Arc` between connection threads and the worker.
pub struct JobQueue {
    inner: Mutex<QueueInner>,
    /// Signals the worker (new job, or drain).
    wake: Condvar,
    max_live: usize,
}

impl JobQueue {
    /// A queue admitting at most `max_live` non-terminal jobs.
    pub fn new(max_live: usize) -> JobQueue {
        JobQueue {
            inner: Mutex::new(QueueInner {
                jobs: Vec::new(),
                pending: VecDeque::new(),
                draining: false,
            }),
            wake: Condvar::new(),
            max_live: max_live.max(1),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueInner> {
        // A connection thread that panics while holding the lock has
        // already been contained at the request level; the shared state
        // it touches here is monotonic counters, safe to keep serving.
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Enqueue a grid; returns the new job id or a named refusal.
    pub fn submit(&self, grid_yaml: String, streaming: Option<bool>) -> Result<u64, SubmitError> {
        let mut q = self.lock();
        if q.draining {
            return Err(SubmitError::Draining);
        }
        let live = q.jobs.iter().filter(|j| !j.status.state.terminal()).count();
        if live >= self.max_live {
            return Err(SubmitError::QueueFull {
                live,
                max: self.max_live,
            });
        }
        let id = q.jobs.len() as u64;
        q.jobs.push(Job {
            status: JobStatus {
                id,
                state: JobState::Queued,
                done: 0,
                total: 0,
                executed: 0,
                cache_hits: 0,
                failed_cells: 0,
                error: None,
            },
            grid_yaml,
            streaming,
            summary: None,
            t_submitted: Instant::now(),
            t_started: None,
            t_finished: None,
        });
        q.pending.push_back(id);
        registry::SERVE_JOBS_ACCEPTED.inc();
        registry::SERVE_QUEUE_DEPTH_HW.raise((live + 1) as u64);
        drop(q);
        self.wake.notify_all();
        Ok(id)
    }

    /// Progress snapshot; `None` for an id never issued.
    pub fn status(&self, id: u64) -> Option<JobStatus> {
        self.lock()
            .jobs
            .get(id as usize)
            .map(|j| j.status.clone())
    }

    /// Exact summary text of a completed job.
    pub fn summary(&self, id: u64) -> Result<String, FetchError> {
        let q = self.lock();
        let job = q.jobs.get(id as usize).ok_or(FetchError::UnknownJob)?;
        match job.status.state {
            JobState::Completed => Ok(job
                .summary
                .clone()
                .expect("completed job carries a summary")),
            JobState::Failed => Err(FetchError::JobFailed {
                error: job
                    .status
                    .error
                    .clone()
                    .unwrap_or_else(|| "unknown error".into()),
            }),
            JobState::Cancelled => Err(FetchError::JobCancelled),
            state => Err(FetchError::NotComplete { state }),
        }
    }

    /// Cancel a job. Queued jobs flip to Cancelled immediately (the
    /// worker skips them); a running job stops at its next chunk
    /// boundary. Terminal jobs are left as-is (idempotent). `false` for
    /// an unknown id.
    pub fn cancel(&self, id: u64) -> bool {
        let mut q = self.lock();
        let Some(job) = q.jobs.get_mut(id as usize) else {
            return false;
        };
        match job.status.state {
            JobState::Queued | JobState::Running => {
                job.status.state = JobState::Cancelled;
                job.t_finished = Some(Instant::now());
                registry::SERVE_JOBS_CANCELLED.inc();
                true
            }
            _ => true,
        }
    }

    /// Stop intake: pending submissions after this are refused, and
    /// [`JobQueue::next_job`] returns `None` once the pending queue is
    /// empty (letting the worker exit after finishing what's in flight).
    pub fn drain(&self) {
        self.lock().draining = true;
        self.wake.notify_all();
    }

    /// Whether a drain was requested.
    pub fn draining(&self) -> bool {
        self.lock().draining
    }

    /// Worker side: block until a job is available or the queue drains.
    /// Cancelled-while-queued jobs are skipped here. Returns `None`
    /// exactly when draining and nothing is pending — the worker's exit
    /// signal.
    pub fn next_job(&self) -> Option<ClaimedJob> {
        let mut q = self.lock();
        loop {
            while let Some(id) = q.pending.pop_front() {
                let job = &mut q.jobs[id as usize];
                if job.status.state != JobState::Queued {
                    continue; // cancelled while queued
                }
                job.status.state = JobState::Running;
                job.t_started = Some(Instant::now());
                return Some(ClaimedJob {
                    id,
                    grid_yaml: job.grid_yaml.clone(),
                    streaming: job.streaming,
                });
            }
            if q.draining {
                return None;
            }
            q = match self.wake.wait(q) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    /// Worker side: record the expanded cell count when execution starts.
    pub fn mark_running(&self, id: u64, total: usize) {
        if let Some(job) = self.lock().jobs.get_mut(id as usize) {
            job.status.total = total;
        }
    }

    /// Worker side: fold one finished chunk into the job's counters.
    pub fn progress(&self, id: u64, done: usize, executed: usize, hits: usize, failed: usize) {
        if let Some(job) = self.lock().jobs.get_mut(id as usize) {
            job.status.done += done;
            job.status.executed += executed;
            job.status.cache_hits += hits;
            job.status.failed_cells += failed;
        }
    }

    /// Worker side: has this job been cancelled? (Checked between
    /// chunks; also true for any other terminal state.)
    pub fn is_cancelled(&self, id: u64) -> bool {
        self.lock()
            .jobs
            .get(id as usize)
            .map(|j| j.status.state != JobState::Running)
            .unwrap_or(true)
    }

    /// Worker side: finish a job. `Ok(summary_text)` completes it with
    /// the exact summary bytes; `Err(why)` fails it. A job cancelled
    /// mid-run stays Cancelled.
    pub fn finish(&self, id: u64, outcome: Result<String, String>) {
        let mut q = self.lock();
        let Some(job) = q.jobs.get_mut(id as usize) else {
            return;
        };
        if job.status.state != JobState::Running {
            return; // cancelled while running: keep the Cancelled state
        }
        job.t_finished = Some(Instant::now());
        match outcome {
            Ok(text) => {
                job.status.state = JobState::Completed;
                job.summary = Some(text);
            }
            Err(why) => {
                job.status.state = JobState::Failed;
                job.status.error = Some(why);
            }
        }
    }

    /// Per-job wall-clock phase timings for the `stats` introspection
    /// message: how long each job queued and ran (milliseconds;
    /// still-open phases are measured up to now).
    pub fn phase_timings(&self) -> Json {
        let now = Instant::now();
        let ms = |a: Instant, b: Instant| b.duration_since(a).as_secs_f64() * 1e3;
        let q = self.lock();
        Json::Arr(
            q.jobs
                .iter()
                .map(|job| {
                    let queued_ms = ms(job.t_submitted, job.t_started.unwrap_or(now));
                    let mut j = Json::obj()
                        .with("job", job.status.id.into())
                        .with("state", job.status.state.label().into())
                        .with("queued_ms", queued_ms.into());
                    if let Some(started) = job.t_started {
                        j.set("run_ms", ms(started, job.t_finished.unwrap_or(now)).into());
                    }
                    j
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_claim_finish_lifecycle() {
        let q = JobQueue::new(4);
        let id = q.submit("base:\n".into(), None).unwrap();
        assert_eq!(q.status(id).unwrap().state, JobState::Queued);
        let claimed = q.next_job().unwrap();
        assert_eq!(claimed.id, id);
        assert_eq!(q.status(id).unwrap().state, JobState::Running);
        q.mark_running(id, 10);
        q.progress(id, 4, 3, 1, 0);
        let st = q.status(id).unwrap();
        assert_eq!((st.done, st.total, st.executed, st.cache_hits), (4, 10, 3, 1));
        q.finish(id, Ok("summary text".into()));
        assert_eq!(q.status(id).unwrap().state, JobState::Completed);
        assert_eq!(q.summary(id).unwrap(), "summary text");
    }

    #[test]
    fn bound_counts_only_live_jobs() {
        let q = JobQueue::new(2);
        let a = q.submit("a".into(), None).unwrap();
        let _b = q.submit("b".into(), None).unwrap();
        assert_eq!(
            q.submit("c".into(), None).unwrap_err().code(),
            "queue-full"
        );
        // Finishing a job frees a slot.
        let claimed = q.next_job().unwrap();
        assert_eq!(claimed.id, a);
        q.finish(a, Err("boom".into()));
        assert!(q.submit("c".into(), None).is_ok());
    }

    #[test]
    fn cancel_paths() {
        let q = JobQueue::new(4);
        let a = q.submit("a".into(), None).unwrap();
        let b = q.submit("b".into(), None).unwrap();
        // Cancel while queued: the worker never sees it.
        assert!(q.cancel(a));
        assert_eq!(q.status(a).unwrap().state, JobState::Cancelled);
        assert_eq!(q.next_job().unwrap().id, b);
        // Cancel while running: worker observes it between chunks and
        // finish() keeps the cancelled state.
        assert!(q.cancel(b));
        assert!(q.is_cancelled(b));
        q.finish(b, Ok("late".into()));
        assert_eq!(q.status(b).unwrap().state, JobState::Cancelled);
        assert_eq!(q.summary(b).unwrap_err().code(), "job-cancelled");
        // Unknown ids are reported, not panicked on.
        assert!(!q.cancel(99));
        assert!(q.status(99).is_none());
        assert_eq!(q.summary(99).unwrap_err().code(), "unknown-job");
    }

    #[test]
    fn drain_stops_intake_and_releases_worker() {
        let q = JobQueue::new(4);
        q.submit("a".into(), None).unwrap();
        q.drain();
        assert_eq!(q.submit("b".into(), None).unwrap_err().code(), "shutting-down");
        // Pending work is still handed out before the None.
        assert!(q.next_job().is_some());
        assert!(q.next_job().is_none());
    }

    #[test]
    fn fetch_before_completion_names_the_state() {
        let q = JobQueue::new(4);
        let id = q.submit("a".into(), None).unwrap();
        assert_eq!(q.summary(id).unwrap_err().code(), "not-complete");
        q.next_job().unwrap();
        assert_eq!(q.summary(id).unwrap_err().code(), "not-complete");
        q.finish(id, Err("grid did not parse".into()));
        let err = q.summary(id).unwrap_err();
        assert_eq!(err.code(), "job-failed");
        assert!(err.message().contains("grid did not parse"));
    }
}
