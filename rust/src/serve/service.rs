//! The grid service proper: TCP acceptor, per-connection request loop,
//! and the single sweep worker thread.
//!
//! Thread topology (mirroring the coordinator's explicit-thread idiom):
//!
//! ```text
//! acceptor ──spawns──▶ connection threads ──▶ JobQueue ◀── worker
//!    │                      │ (parse, respond)               │ (run cells)
//!    └── nonblocking poll   └── per-socket timeouts          └── chunked, cancellable
//! ```
//!
//! The worker executes one job at a time through the same
//! [`run_cells_cached`] path as `dsd sweep` — in chunks, so progress
//! advances and cancellation takes effect at chunk boundaries, and
//! against an optional shared cell cache, so repeat submissions and
//! externally sharded runs are served from disk.

use super::job::{ClaimedJob, JobQueue};
use super::protocol::{
    error_response, ok_response, parse_request, Request, RequestError,
    DEFAULT_MAX_REQUEST_BYTES, DEFAULT_REQUEST_TIMEOUT_MS,
};
use crate::obs::registry;
use crate::sweep::{run_cells_cached, CellCache, CellResult, SweepGrid, SweepSummary};
use crate::util::json::Json;
use crate::{log_error, log_warn};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Service tuning knobs (all bounded; all defaulted).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Worker threads per job's cell execution (0 = one per core).
    pub threads: usize,
    /// Run directory whose `cells/` subdirectory backs execution;
    /// `None` runs uncached.
    pub cache_dir: Option<PathBuf>,
    /// Bound on live (queued + running) jobs.
    pub max_jobs: usize,
    /// Bound on one request line, bytes.
    pub max_request_bytes: usize,
    /// Per-socket read/write timeout, ms.
    pub request_timeout_ms: u64,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            threads: 0,
            cache_dir: None,
            max_jobs: 16,
            max_request_bytes: DEFAULT_MAX_REQUEST_BYTES,
            request_timeout_ms: DEFAULT_REQUEST_TIMEOUT_MS,
        }
    }
}

/// A running grid service. Dropping it without [`GridService::join`]
/// leaves the threads running; the CLI and tests always join.
pub struct GridService {
    addr: SocketAddr,
    queue: Arc<JobQueue>,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl GridService {
    /// Bind `listen` (e.g. `127.0.0.1:7433`; port 0 picks a free port)
    /// and start the acceptor + worker threads.
    pub fn start(listen: &str, opts: ServeOptions) -> Result<GridService, String> {
        let listener =
            TcpListener::bind(listen).map_err(|e| format!("serve: bind {listen}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("serve: set_nonblocking: {e}"))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("serve: local_addr: {e}"))?;
        let cache = match &opts.cache_dir {
            Some(dir) => Some(CellCache::open(&dir.join("cells"))?),
            None => None,
        };
        let queue = Arc::new(JobQueue::new(opts.max_jobs));
        let shutdown = Arc::new(AtomicBool::new(false));

        let worker = {
            let queue = Arc::clone(&queue);
            let threads = opts.threads;
            std::thread::spawn(move || worker_loop(&queue, threads, cache.as_ref()))
        };
        let acceptor = {
            let queue = Arc::clone(&queue);
            let shutdown = Arc::clone(&shutdown);
            let opts = opts.clone();
            std::thread::spawn(move || {
                accept_loop(listener, queue, shutdown, opts);
            })
        };
        Ok(GridService {
            addr,
            queue,
            shutdown,
            acceptor: Some(acceptor),
            worker: Some(worker),
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Programmatic shutdown: same path as a `shutdown` request.
    pub fn shutdown(&self) {
        self.queue.drain();
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Wait for the drain to finish: the worker exits after the pending
    /// queue empties, then the acceptor notices the flag and exits.
    /// (Connection threads close with their sockets and are detached.)
    pub fn join(mut self) {
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
        // The worker only exits on drain, so the flag is already set
        // (either by a shutdown request or by `shutdown()`); the
        // acceptor sees it within one poll interval.
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

const ACCEPT_POLL: Duration = Duration::from_millis(25);

fn accept_loop(
    listener: TcpListener,
    queue: Arc<JobQueue>,
    shutdown: Arc<AtomicBool>,
    opts: ServeOptions,
) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let queue = Arc::clone(&queue);
                let shutdown = Arc::clone(&shutdown);
                let opts = opts.clone();
                std::thread::spawn(move || {
                    handle_connection(stream, &queue, &shutdown, &opts);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                log_warn!("[serve] accept error: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
}

/// Read one `\n`-terminated line, enforcing the byte cap *while
/// reading*: an over-long line is discarded as it streams in and
/// surfaces as `Oversized` without ever being buffered whole.
/// `Ok(None)` is a clean EOF; `Err(io)` covers timeouts and resets.
fn read_line_bounded(
    reader: &mut BufReader<TcpStream>,
    max: usize,
) -> std::io::Result<Option<Result<String, RequestError>>> {
    let mut line = String::new();
    let mut overflowed = false;
    let mut total = 0usize;
    loop {
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            // EOF mid-line: treat a non-empty partial as a final line.
            if line.is_empty() && !overflowed {
                return Ok(None);
            }
            break;
        }
        let (chunk, saw_newline) = match buf.iter().position(|&b| b == b'\n') {
            Some(i) => (&buf[..i], true),
            None => (buf, false),
        };
        let consume = chunk.len() + usize::from(saw_newline);
        total += chunk.len();
        if total > max {
            overflowed = true;
            line.clear();
        } else if !overflowed {
            line.push_str(&String::from_utf8_lossy(chunk));
        }
        reader.consume(consume);
        if saw_newline {
            break;
        }
    }
    registry::SERVE_BYTES_IN.add(total as u64);
    if overflowed {
        return Ok(Some(Err(RequestError::Oversized { len: total, max })));
    }
    Ok(Some(Ok(line)))
}

fn write_response(stream: &mut TcpStream, response: &Json) -> std::io::Result<()> {
    let mut text = response.to_string_compact();
    text.push('\n');
    registry::SERVE_BYTES_OUT.add(text.len() as u64);
    stream.write_all(text.as_bytes())?;
    stream.flush()
}

fn handle_connection(
    stream: TcpStream,
    queue: &JobQueue,
    shutdown: &AtomicBool,
    opts: &ServeOptions,
) {
    let timeout = Some(Duration::from_millis(opts.request_timeout_ms.max(1)));
    let _ = stream.set_read_timeout(timeout);
    let _ = stream.set_write_timeout(timeout);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_line_bounded(&mut reader, opts.max_request_bytes) {
            Ok(None) => return,          // clean EOF
            Err(_) => return,            // timeout / reset: drop quietly
            Ok(Some(Err(oversized))) => {
                let resp = error_response(oversized.code(), &oversized.message());
                let _ = write_response(&mut writer, &resp);
                continue; // the offending line was fully discarded
            }
            Ok(Some(Ok(line))) => line,
        };
        if line.trim().is_empty() {
            continue;
        }
        let response = match parse_request(&line, opts.max_request_bytes) {
            Err(e) => error_response(e.code(), &e.message()),
            Ok(req) => dispatch(req, queue, shutdown),
        };
        if write_response(&mut writer, &response).is_err() {
            return;
        }
    }
}

/// Answer one validated request against the shared queue.
fn dispatch(req: Request, queue: &JobQueue, shutdown: &AtomicBool) -> Json {
    match req {
        Request::Ping => ok_response("pong", vec![]),
        Request::SubmitGrid {
            grid_yaml,
            streaming,
        } => {
            // Validate the grid up front so a bad submission is a named
            // synchronous error, not a job that fails later.
            if let Err(e) = SweepGrid::from_yaml(&grid_yaml).and_then(|g| g.expand().map(|_| ()))
            {
                return error_response("grid-error", &e);
            }
            match queue.submit(grid_yaml, streaming) {
                Ok(id) => ok_response("job-accepted", vec![("job", id.into())]),
                Err(e) => error_response(e.code(), &e.message()),
            }
        }
        Request::PollProgress { job } => match queue.status(job) {
            Some(status) => merge_into(ok_response("progress", vec![]), status.to_json()),
            None => error_response("unknown-job", "no such job"),
        },
        Request::FetchSummary { job } => match queue.summary(job) {
            Ok(text) => ok_response(
                "summary",
                vec![("job", job.into()), ("summary", text.into())],
            ),
            Err(e) => error_response(e.code(), &e.message()),
        },
        Request::Cancel { job } => {
            if queue.cancel(job) {
                ok_response("cancelled", vec![("job", job.into())])
            } else {
                error_response("unknown-job", "no such job")
            }
        }
        Request::Stats => ok_response(
            "stats",
            vec![
                ("registry", registry::snapshot()),
                ("jobs", queue.phase_timings()),
            ],
        ),
        Request::Shutdown => {
            queue.drain();
            shutdown.store(true, Ordering::SeqCst);
            ok_response("draining", vec![])
        }
    }
}

/// Append every key of `extra` (an object) to the envelope.
fn merge_into(mut envelope: Json, extra: Json) -> Json {
    if let Json::Obj(pairs) = extra {
        for (k, v) in pairs {
            envelope.set(&k, v);
        }
    }
    envelope
}

/// The single sweep worker: claims jobs FIFO, executes their cells in
/// chunks, exits when the queue drains.
fn worker_loop(queue: &JobQueue, threads: usize, cache: Option<&CellCache>) {
    while let Some(job) = queue.next_job() {
        let outcome = run_job(&job, queue, threads, cache);
        // A failure used to surface only to whichever client polled the
        // job; count and log it server-side too so an unattended service
        // still shows the error (in `stats` and on stderr, with the same
        // named code a fetch would return).
        if queue.is_cancelled(job.id) {
            // Cancelled mid-run: already counted when the cancel landed.
        } else {
            match &outcome {
                Ok(_) => registry::SERVE_JOBS_COMPLETED.inc(),
                Err(why) => {
                    registry::SERVE_JOBS_FAILED.inc();
                    log_error!("[serve] job {} failed (job-failed): {why}", job.id);
                }
            }
        }
        queue.finish(job.id, outcome);
    }
}

fn run_job(
    job: &ClaimedJob,
    queue: &JobQueue,
    threads: usize,
    cache: Option<&CellCache>,
) -> Result<String, String> {
    let mut grid = SweepGrid::from_yaml(&job.grid_yaml)?;
    let streaming = job.streaming.unwrap_or(grid.streaming);
    grid.streaming = streaming;
    let cells = grid.expand()?;
    queue.mark_running(job.id, cells.len());
    let threads = if threads == 0 {
        crate::sweep::default_threads()
    } else {
        threads
    };
    // Chunked execution: big enough to keep every worker thread busy,
    // small enough that progress moves and cancellation lands promptly.
    let chunk = (threads.max(1) * 4).max(1);
    let mut results: Vec<CellResult> = Vec::with_capacity(cells.len());
    for batch in cells.chunks(chunk) {
        if queue.is_cancelled(job.id) {
            return Err("cancelled".into()); // finish() keeps Cancelled
        }
        let (mut rs, stats) = run_cells_cached(batch, streaming, threads, cache);
        let failed = rs.iter().filter(|r| r.outcome.is_err()).count();
        queue.progress(job.id, batch.len(), stats.executed, stats.cache_hits, failed);
        results.append(&mut rs);
    }
    // Exact single-process bytes: the same constructor and printer
    // `dsd sweep` uses (the file form appends one trailing newline;
    // [`crate::serve::GridClient`] restores it when writing to disk).
    let summary = SweepSummary::new(results, streaming);
    Ok(summary.to_json().to_string_pretty())
}
