//! Wire protocol of the grid service: one JSON object per line, each
//! carrying a protocol version, parsed through a surface that never
//! panics and names every rejection.
//!
//! Request shapes (compact JSON, `\n`-terminated):
//!
//! ```text
//! {"v":1,"type":"ping"}
//! {"v":1,"type":"submit-grid","grid":"<grid.yaml text>","streaming":true}
//! {"v":1,"type":"poll-progress","job":3}
//! {"v":1,"type":"fetch-summary","job":3}
//! {"v":1,"type":"cancel","job":3}
//! {"v":1,"type":"stats"}
//! {"v":1,"type":"shutdown"}
//! ```
//!
//! Responses: `{"v":1,"ok":true,"type":...,...}` on success,
//! `{"v":1,"ok":false,"error":{"code":"<kebab-name>","message":...}}`
//! on rejection. Summaries travel as a JSON *string* holding the exact
//! pretty summary text — string escaping round-trips losslessly, so the
//! client receives bytes identical to the single-process `dsd sweep`
//! output (re-encoding the summary as wire JSON would re-serialize
//! every float and risk drift).

use crate::util::json::Json;

/// Wire protocol version; every request and response carries it as
/// `"v"`. Bump on any incompatible shape change.
pub const PROTOCOL_VERSION: u64 = 1;

/// Default cap on one request line, bytes (grids are YAML text — 4 MiB
/// is roomy; the cap exists so a hostile or broken peer cannot make the
/// service buffer an unbounded line).
pub const DEFAULT_MAX_REQUEST_BYTES: usize = 4 << 20;

/// Default per-socket read/write timeout, ms.
pub const DEFAULT_REQUEST_TIMEOUT_MS: u64 = 30_000;

/// A validated inbound request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Liveness probe; answered immediately from the connection thread.
    Ping,
    /// Enqueue a sweep over `grid_yaml` (same schema as `dsd sweep
    /// --grid`). `streaming: None` defers to the grid's own
    /// `streaming:` key.
    SubmitGrid {
        grid_yaml: String,
        streaming: Option<bool>,
    },
    /// Progress snapshot of a job.
    PollProgress { job: u64 },
    /// Full summary text of a completed job.
    FetchSummary { job: u64 },
    /// Cancel a queued or running job.
    Cancel { job: u64 },
    /// Live introspection snapshot: metrics-registry state plus per-job
    /// phase timings. Answered from the connection thread without
    /// touching the worker.
    Stats,
    /// Stop intake, finish the running job, exit.
    Shutdown,
}

impl Request {
    /// Wire encoding (what [`crate::serve::GridClient`] sends).
    pub fn to_json(&self) -> Json {
        let base = Json::obj().with("v", PROTOCOL_VERSION.into());
        match self {
            Request::Ping => base.with("type", "ping".into()),
            Request::SubmitGrid {
                grid_yaml,
                streaming,
            } => {
                let mut j = base
                    .with("type", "submit-grid".into())
                    .with("grid", grid_yaml.as_str().into());
                if let Some(s) = streaming {
                    j.set("streaming", (*s).into());
                }
                j
            }
            Request::PollProgress { job } => base
                .with("type", "poll-progress".into())
                .with("job", (*job).into()),
            Request::FetchSummary { job } => base
                .with("type", "fetch-summary".into())
                .with("job", (*job).into()),
            Request::Cancel { job } => {
                base.with("type", "cancel".into()).with("job", (*job).into())
            }
            Request::Stats => base.with("type", "stats".into()),
            Request::Shutdown => base.with("type", "shutdown".into()),
        }
    }
}

/// Every way a request line can be rejected, each with a stable
/// kebab-case code clients can branch on. Parsing never panics: any
/// byte sequence maps to either a [`Request`] or one of these.
#[derive(Clone, Debug, PartialEq)]
pub enum RequestError {
    /// Line exceeded the configured byte cap (detected while reading —
    /// the overflow is never buffered).
    Oversized { len: usize, max: usize },
    /// Not parseable as JSON at all.
    MalformedJson { msg: String },
    /// Valid JSON, but not an object.
    NotAnObject,
    /// Missing or non-integer `"v"`, or a version this server doesn't
    /// speak.
    BadVersion { got: String },
    /// No `"type"` key.
    MissingType,
    /// A `"type"` this server doesn't know.
    UnknownType { got: String },
    /// A required field of the given request type is absent.
    MissingField {
        req: &'static str,
        field: &'static str,
    },
    /// A field is present but of the wrong shape.
    BadField {
        req: &'static str,
        field: &'static str,
        want: &'static str,
    },
}

impl RequestError {
    /// Stable machine-readable code.
    pub fn code(&self) -> &'static str {
        match self {
            RequestError::Oversized { .. } => "oversized",
            RequestError::MalformedJson { .. } => "malformed-json",
            RequestError::NotAnObject => "not-an-object",
            RequestError::BadVersion { .. } => "bad-version",
            RequestError::MissingType => "missing-type",
            RequestError::UnknownType { .. } => "unknown-type",
            RequestError::MissingField { .. } => "missing-field",
            RequestError::BadField { .. } => "bad-field",
        }
    }

    /// Human-readable description (goes in the error response).
    pub fn message(&self) -> String {
        match self {
            RequestError::Oversized { len, max } => {
                format!("request line of {len}+ bytes exceeds the {max}-byte cap")
            }
            RequestError::MalformedJson { msg } => format!("malformed JSON: {msg}"),
            RequestError::NotAnObject => "request must be a JSON object".into(),
            RequestError::BadVersion { got } => format!(
                "unsupported protocol version {got} (this server speaks v{PROTOCOL_VERSION})"
            ),
            RequestError::MissingType => "request has no 'type' key".into(),
            RequestError::UnknownType { got } => format!(
                "unknown request type '{got}' (known: ping, submit-grid, \
                 poll-progress, fetch-summary, cancel, stats, shutdown)"
            ),
            RequestError::MissingField { req, field } => {
                format!("{req} request is missing required field '{field}'")
            }
            RequestError::BadField { req, field, want } => {
                format!("{req} request field '{field}' must be {want}")
            }
        }
    }
}

/// Parse one request line. Never panics; every outcome is either a
/// [`Request`] or a named [`RequestError`]. `max_bytes` re-checks the
/// reader's cap so the parser is safe standalone (e.g. under fuzzing).
pub fn parse_request(line: &str, max_bytes: usize) -> Result<Request, RequestError> {
    if line.len() > max_bytes {
        return Err(RequestError::Oversized {
            len: line.len(),
            max: max_bytes,
        });
    }
    let doc = Json::parse(line.trim()).map_err(|e| RequestError::MalformedJson {
        msg: e.to_string(),
    })?;
    if !matches!(doc, Json::Obj(_)) {
        return Err(RequestError::NotAnObject);
    }
    match doc.get("v").and_then(Json::as_u64) {
        Some(PROTOCOL_VERSION) => {}
        _ => {
            return Err(RequestError::BadVersion {
                got: match doc.get("v") {
                    None => "<absent>".into(),
                    Some(v) => v.to_string_compact(),
                },
            })
        }
    }
    let ty = match doc.get("type") {
        None => return Err(RequestError::MissingType),
        Some(t) => t.as_str().ok_or(RequestError::BadField {
            req: "any",
            field: "type",
            want: "a string",
        })?,
    };
    let job_field = |req: &'static str| -> Result<u64, RequestError> {
        match doc.get("job") {
            None => Err(RequestError::MissingField { req, field: "job" }),
            Some(j) => j.as_u64().ok_or(RequestError::BadField {
                req,
                field: "job",
                want: "a non-negative integer",
            }),
        }
    };
    match ty {
        "ping" => Ok(Request::Ping),
        "submit-grid" => {
            let grid_yaml = match doc.get("grid") {
                None => {
                    return Err(RequestError::MissingField {
                        req: "submit-grid",
                        field: "grid",
                    })
                }
                Some(g) => g
                    .as_str()
                    .ok_or(RequestError::BadField {
                        req: "submit-grid",
                        field: "grid",
                        want: "a string of grid YAML",
                    })?
                    .to_string(),
            };
            let streaming = match doc.get("streaming") {
                None => None,
                Some(s) => Some(s.as_bool().ok_or(RequestError::BadField {
                    req: "submit-grid",
                    field: "streaming",
                    want: "a boolean",
                })?),
            };
            Ok(Request::SubmitGrid {
                grid_yaml,
                streaming,
            })
        }
        "poll-progress" => Ok(Request::PollProgress {
            job: job_field("poll-progress")?,
        }),
        "fetch-summary" => Ok(Request::FetchSummary {
            job: job_field("fetch-summary")?,
        }),
        "cancel" => Ok(Request::Cancel {
            job: job_field("cancel")?,
        }),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(RequestError::UnknownType { got: other.into() }),
    }
}

/// Success response envelope: `{"v":1,"ok":true,"type":<ty>,...fields}`.
pub fn ok_response(ty: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut j = Json::obj()
        .with("v", PROTOCOL_VERSION.into())
        .with("ok", true.into())
        .with("type", ty.into());
    for (k, v) in fields {
        j.set(k, v);
    }
    j
}

/// Error response envelope:
/// `{"v":1,"ok":false,"error":{"code":...,"message":...}}`.
pub fn error_response(code: &str, message: &str) -> Json {
    Json::obj()
        .with("v", PROTOCOL_VERSION.into())
        .with("ok", false.into())
        .with(
            "error",
            Json::obj()
                .with("code", code.into())
                .with("message", message.into()),
        )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, Gen};

    const MAX: usize = DEFAULT_MAX_REQUEST_BYTES;

    #[test]
    fn valid_requests_roundtrip_through_their_wire_encoding() {
        let reqs = [
            Request::Ping,
            Request::SubmitGrid {
                grid_yaml: "base:\n  seed: 3\nsweep:\n  rtt_ms: [5, 40]\n".into(),
                streaming: Some(true),
            },
            Request::SubmitGrid {
                grid_yaml: "".into(),
                streaming: None,
            },
            Request::PollProgress { job: 0 },
            Request::FetchSummary { job: 42 },
            Request::Cancel { job: 7 },
            Request::Stats,
            Request::Shutdown,
        ];
        for r in reqs {
            let line = r.to_json().to_string_compact();
            assert_eq!(parse_request(&line, MAX), Ok(r.clone()), "{line}");
        }
    }

    #[test]
    fn every_rejection_is_a_named_error() {
        let cases: [(&str, &str); 10] = [
            ("", "malformed-json"),
            ("not json at all", "malformed-json"),
            ("[1,2,3]", "not-an-object"),
            ("42", "not-an-object"),
            ("{\"type\":\"ping\"}", "bad-version"),
            ("{\"v\":99,\"type\":\"ping\"}", "bad-version"),
            ("{\"v\":1}", "missing-type"),
            ("{\"v\":1,\"type\":\"frobnicate\"}", "unknown-type"),
            ("{\"v\":1,\"type\":\"submit-grid\"}", "missing-field"),
            ("{\"v\":1,\"type\":\"poll-progress\",\"job\":\"x\"}", "bad-field"),
        ];
        for (line, want) in cases {
            let err = parse_request(line, MAX).unwrap_err();
            assert_eq!(err.code(), want, "'{line}' → {err:?}");
            assert!(!err.message().is_empty());
        }
    }

    #[test]
    fn oversized_lines_are_rejected_by_length_alone() {
        let line = format!("{{\"v\":1,\"type\":\"ping\",\"pad\":\"{}\"}}", "x".repeat(64));
        assert_eq!(
            parse_request(&line, 32).unwrap_err().code(),
            "oversized",
            "cap applies before parsing"
        );
        assert!(parse_request(&line, MAX).is_ok());
    }

    #[test]
    fn non_integer_and_negative_versions_are_bad_version() {
        for line in [
            "{\"v\":\"1\",\"type\":\"ping\"}",
            "{\"v\":1.5,\"type\":\"ping\"}",
            "{\"v\":-1,\"type\":\"ping\"}",
            "{\"v\":null,\"type\":\"ping\"}",
        ] {
            assert_eq!(parse_request(line, MAX).unwrap_err().code(), "bad-version");
        }
    }

    #[test]
    fn duplicate_keys_resolve_to_first_occurrence_without_panicking() {
        // The in-repo JSON decoder keeps duplicate keys and `get`
        // returns the first — the parser must stay deterministic and
        // panic-free on such input, whatever it resolves to.
        let line = "{\"v\":1,\"v\":99,\"type\":\"ping\",\"type\":\"shutdown\"}";
        assert_eq!(parse_request(line, MAX), Ok(Request::Ping));
    }

    /// ISSUE satellite: random, truncated, duplicate-key, and oversized
    /// inputs never panic and always yield a named error (or a valid
    /// request).
    #[test]
    fn prop_arbitrary_bytes_never_panic() {
        run_prop("parse_request total on arbitrary input", 300, |g: &mut Gen| {
            let len = g.usize_in(0, 200);
            let line: String = (0..len)
                .map(|_| {
                    // Mix of JSON-ish punctuation, letters, and controls.
                    let pool = b"{}[]\":,truefalsenull0123456789.vtypejob \t\x7f\x01";
                    *g.pick(pool) as char
                })
                .collect();
            match parse_request(&line, 128) {
                Ok(_) => {}
                Err(e) => {
                    assert!(!e.code().is_empty());
                    assert!(!e.message().is_empty());
                }
            }
        });
    }

    #[test]
    fn prop_truncations_of_valid_requests_never_panic() {
        run_prop("parse_request total on truncated requests", 100, |g: &mut Gen| {
            let full = Request::SubmitGrid {
                grid_yaml: "base:\n  seed: 1\n".into(),
                streaming: Some(false),
            }
            .to_json()
            .to_string_compact();
            let cut = g.usize_in(0, full.len());
            // Cut at a char boundary (the wire encoding here is ASCII).
            let line = &full[..cut];
            match parse_request(line, MAX) {
                Ok(r) => assert!(cut == full.len() && matches!(r, Request::SubmitGrid { .. })),
                Err(e) => assert!(!e.code().is_empty()),
            }
        });
    }

    #[test]
    fn response_envelopes_have_the_documented_shape() {
        let ok = ok_response("pong", vec![("jobs", 3u64.into())]);
        assert_eq!(ok.get("v").and_then(Json::as_u64), Some(PROTOCOL_VERSION));
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(ok.get("type").and_then(Json::as_str), Some("pong"));
        assert_eq!(ok.get("jobs").and_then(Json::as_u64), Some(3));
        let err = error_response("queue-full", "try later");
        assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            err.path(&["error", "code"]).and_then(Json::as_str),
            Some("queue-full")
        );
    }
}
