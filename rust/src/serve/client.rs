//! Blocking line-protocol client for the grid service — the library
//! behind `dsd submit`, and the harness the end-to-end service tests
//! drive.

use super::job::JobState;
use super::protocol::{Request, PROTOCOL_VERSION};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// One connection to a [`crate::serve::GridService`].
pub struct GridClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl GridClient {
    /// Connect with a per-operation socket timeout.
    pub fn connect(addr: &str, timeout_ms: u64) -> Result<GridClient, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("submit: connect {addr}: {e}"))?;
        let timeout = Some(Duration::from_millis(timeout_ms.max(1)));
        stream
            .set_read_timeout(timeout)
            .map_err(|e| format!("submit: set timeout: {e}"))?;
        stream
            .set_write_timeout(timeout)
            .map_err(|e| format!("submit: set timeout: {e}"))?;
        let writer = stream
            .try_clone()
            .map_err(|e| format!("submit: clone stream: {e}"))?;
        Ok(GridClient {
            writer,
            reader: BufReader::new(stream),
        })
    }

    /// Send one raw line and read one response line — the hatch the
    /// malformed-input tests use to bypass [`Request`]'s typed encoding.
    pub fn request_line(&mut self, line: &str) -> Result<Json, String> {
        self.writer
            .write_all(line.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| format!("submit: send: {e}"))?;
        let mut resp = String::new();
        self.reader
            .read_line(&mut resp)
            .map_err(|e| format!("submit: recv: {e}"))?;
        if resp.is_empty() {
            return Err("submit: server closed the connection".into());
        }
        Json::parse(resp.trim()).map_err(|e| format!("submit: bad response: {e}"))
    }

    /// Send a typed request, return the decoded response object.
    pub fn request(&mut self, req: &Request) -> Result<Json, String> {
        self.request_line(&req.to_json().to_string_compact())
    }

    /// Send a typed request and demand success; protocol-level errors
    /// come back as `Err("<code>: <message>")`.
    fn request_ok(&mut self, req: &Request) -> Result<Json, String> {
        let resp = self.request(req)?;
        if resp.get("v").and_then(Json::as_u64) != Some(PROTOCOL_VERSION) {
            return Err(format!(
                "submit: response carries wrong protocol version: {}",
                resp.to_string_compact()
            ));
        }
        match resp.get("ok").and_then(Json::as_bool) {
            Some(true) => Ok(resp),
            _ => {
                let code = resp
                    .path(&["error", "code"])
                    .and_then(Json::as_str)
                    .unwrap_or("unknown");
                let msg = resp
                    .path(&["error", "message"])
                    .and_then(Json::as_str)
                    .unwrap_or("malformed error response");
                Err(format!("{code}: {msg}"))
            }
        }
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<(), String> {
        self.request_ok(&Request::Ping).map(|_| ())
    }

    /// Submit grid YAML text; returns the job id.
    pub fn submit_grid_text(
        &mut self,
        grid_yaml: &str,
        streaming: Option<bool>,
    ) -> Result<u64, String> {
        let resp = self.request_ok(&Request::SubmitGrid {
            grid_yaml: grid_yaml.to_string(),
            streaming,
        })?;
        resp.get("job")
            .and_then(Json::as_u64)
            .ok_or_else(|| "submit: job-accepted response carries no job id".into())
    }

    /// Poll a job; returns `(state, done, total, failed_cells)`.
    pub fn poll(&mut self, job: u64) -> Result<(JobState, usize, usize, usize), String> {
        let resp = self.request_ok(&Request::PollProgress { job })?;
        let state = match resp.get("state").and_then(Json::as_str) {
            Some("queued") => JobState::Queued,
            Some("running") => JobState::Running,
            Some("completed") => JobState::Completed,
            Some("failed") => JobState::Failed,
            Some("cancelled") => JobState::Cancelled,
            other => return Err(format!("submit: unknown job state {other:?}")),
        };
        let n = |k: &str| resp.get(k).and_then(Json::as_usize).unwrap_or(0);
        Ok((state, n("done"), n("total"), n("failed_cells")))
    }

    /// Fetch the exact summary text of a completed job.
    pub fn fetch_summary(&mut self, job: u64) -> Result<String, String> {
        let resp = self.request_ok(&Request::FetchSummary { job })?;
        resp.get("summary")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| "submit: summary response carries no summary".into())
    }

    /// Fetch the live introspection snapshot: the server's metrics
    /// registry plus per-job wall-clock phase timings.
    pub fn fetch_stats(&mut self) -> Result<Json, String> {
        self.request_ok(&Request::Stats)
    }

    /// Cancel a job.
    pub fn cancel(&mut self, job: u64) -> Result<(), String> {
        self.request_ok(&Request::Cancel { job }).map(|_| ())
    }

    /// Ask the server to drain and exit.
    pub fn shutdown_server(&mut self) -> Result<(), String> {
        self.request_ok(&Request::Shutdown).map(|_| ())
    }

    /// Poll `job` every `poll_ms` until it leaves the queued/running
    /// states or `timeout_ms` elapses. Returns the terminal state and
    /// the final progress numbers.
    pub fn wait(
        &mut self,
        job: u64,
        poll_ms: u64,
        timeout_ms: u64,
    ) -> Result<(JobState, usize, usize, usize), String> {
        let deadline = Instant::now() + Duration::from_millis(timeout_ms);
        loop {
            let snap = self.poll(job)?;
            match snap.0 {
                JobState::Queued | JobState::Running => {}
                _ => return Ok(snap),
            }
            if Instant::now() >= deadline {
                return Err(format!(
                    "submit: job {job} still {} after {timeout_ms} ms",
                    snap.0.label()
                ));
            }
            std::thread::sleep(Duration::from_millis(poll_ms.max(1)));
        }
    }
}
