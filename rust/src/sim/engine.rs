//! Deterministic discrete-event engine (the SimPy role in paper §3.1).
//!
//! A binary heap of `(time, seq)`-ordered events; `seq` breaks ties in
//! insertion order so simulations are bit-reproducible regardless of
//! floating-point coincidences.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event.
struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour on BinaryHeap (max-heap).
        // total_cmp, not partial_cmp: a NaN time must still occupy a
        // fixed place in the order (IEEE total order puts it past +∞)
        // rather than collapsing to Equal and corrupting sift paths.
        other
            .time
            .total_cmp(&self.time)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Event queue with a simulation clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// Empty queue at time 0.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            processed: 0,
        }
    }

    /// Current simulation time, ms.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at absolute time `at` (clamped to now —
    /// scheduling in the past is a bug in debug builds).
    ///
    /// Non-finite times are rejected: ±∞ would freeze or teleport the
    /// clock, and a NaN `at` — while no longer able to corrupt heap
    /// order now that `Entry::cmp` uses `f64::total_cmp` — would sort
    /// past every finite event and stall the queue. Debug builds
    /// assert; release builds clamp to `now` so one bad arithmetic
    /// result cannot poison the whole simulation.
    pub fn schedule(&mut self, at: f64, payload: E) {
        debug_assert!(at.is_finite(), "non-finite event time: {at}");
        debug_assert!(
            !(at < self.now - 1e-9),
            "scheduling into the past: {at} < {}",
            self.now
        );
        let time = if at.is_finite() { at.max(self.now) } else { self.now };
        let entry = Entry {
            time,
            seq: self.seq,
            payload,
        };
        self.seq += 1;
        self.heap.push(entry);
    }

    /// Schedule `payload` after a relative delay.
    pub fn schedule_in(&mut self, delay: f64, payload: E) {
        let at = self.now + delay.max(0.0);
        self.schedule(at, payload);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        self.processed += 1;
        Some((e.time, e.payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{run_prop, Gen};

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(5.0, "c");
        q.schedule(1.0, "a");
        q.schedule(3.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(2.0, ());
        q.schedule(1.0, ());
        let (t1, _) = q.pop().unwrap();
        assert_eq!(q.now(), t1);
        q.schedule_in(0.5, ());
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2, 1.5);
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 2.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn negative_delay_clamps_to_now() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "x");
        q.pop();
        q.schedule_in(-5.0, "y");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 1.0);
    }

    // Regression tests for non-finite schedule times: the NaN path used
    // to rely on `f64::max` quietly discarding the NaN while the debug
    // assertion fired with a misleading "scheduling into the past"
    // message. Debug builds now reject non-finite times explicitly;
    // release builds clamp them to `now` and keep the heap ordered.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn nan_time_rejected_in_debug() {
        let mut q = EventQueue::new();
        q.schedule(f64::NAN, ());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn infinite_time_rejected_in_debug() {
        let mut q = EventQueue::new();
        q.schedule(f64::INFINITY, ());
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn non_finite_times_clamp_in_release() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "later");
        q.pop(); // now = 2.0
        q.schedule(f64::NAN, "nan");
        q.schedule(f64::INFINITY, "inf");
        q.schedule(f64::NEG_INFINITY, "ninf");
        q.schedule(3.0, "fine");
        // All non-finite events clamp to now (2.0) and pop, in insertion
        // order, before the finite 3.0 event; total order stays intact.
        let order: Vec<(f64, &str)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            order,
            vec![(2.0, "nan"), (2.0, "inf"), (2.0, "ninf"), (3.0, "fine")]
        );
    }

    /// Regression (ISSUE 8 satellite): `Entry::cmp` used to fall back to
    /// `Ordering::Equal` via `partial_cmp` when either time was NaN,
    /// which violates the strict-weak-ordering contract `BinaryHeap`
    /// relies on and could silently corrupt sift paths. With
    /// `f64::total_cmp` a NaN time keeps a fixed rank (past +∞), so even
    /// entries pushed straight into the heap — bypassing `schedule`'s
    /// clamp — pop in a deterministic total order.
    #[test]
    fn entry_ordering_is_total_under_nan_times() {
        let mut heap = BinaryHeap::new();
        for (seq, time) in [
            (0u64, f64::NAN),
            (1, 1.0),
            (2, f64::INFINITY),
            (3, f64::NAN),
            (4, 0.0),
            (5, f64::NEG_INFINITY),
        ] {
            heap.push(Entry { time, seq, payload: () });
        }
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|e| e.seq)).collect();
        // IEEE total order: -∞ < 0 < 1 < +∞ < NaN, NaN ties by seq.
        assert_eq!(order, vec![5, 4, 1, 2, 0, 3]);
        // NaN compares unequal-and-ordered against itself and finite
        // times — never Equal (the old bug collapsed all of these).
        let nan = Entry { time: f64::NAN, seq: 7, payload: () };
        let fin = Entry { time: 3.0, seq: 7, payload: () };
        assert_ne!(nan.cmp(&fin), Ordering::Equal);
        assert_ne!(fin.cmp(&nan), Ordering::Equal);
        assert_eq!(nan.cmp(&fin).reverse(), fin.cmp(&nan));
    }

    #[test]
    fn prop_global_time_order() {
        run_prop("event queue total order", 100, |g: &mut Gen| {
            let mut q = EventQueue::new();
            let n = g.usize_in(1, 200);
            for i in 0..n {
                q.schedule(g.f64_in(0.0, 100.0), i);
            }
            let mut last = -1.0;
            while let Some((t, _)) = q.pop() {
                assert!(t >= last);
                last = t;
            }
            assert_eq!(q.processed(), n as u64);
        });
    }
}
